"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (data x model);
multi-pod: 2x16x16 = 512 chips with a leading "pod" axis (DCI links).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (tests / examples): (n_devices,) 'data' mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))

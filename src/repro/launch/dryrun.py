import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline terms from the compiled
artifact (DESIGN §5, EXPERIMENTS §Dry-run).

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any other import so the host platform
exposes 512 placeholder devices.

Per cell this prints/records:
  * memory_analysis  — bytes per device (proves the config fits),
  * cost_analysis    — HLO FLOPs / bytes accessed,
  * collective bytes — parsed from the optimized HLO module text,
  * roofline terms   — compute / memory / collective seconds on TPU v5e
                       constants (197 bf16 TFLOP/s, 819 GB/s HBM,
                       ~50 GB/s/link ICI).
"""
import argparse
import json
import re
import time
from typing import Dict, Optional

import jax

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    get_config,
    input_axes,
    input_specs,
    shape_applicable,
)
from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    expert_parallel_rules,
    multi_pod_rules,
    serve_rules,
    sharding_context,
    single_pod_rules,
    tree_shardings,
)

def _kvq(cfg):
    import dataclasses
    return dataclasses.replace(cfg, kv_quant=True)


def _dots(cfg):
    import dataclasses
    return dataclasses.replace(cfg, remat="dots")


# variant -> (rules transform, cfg transform)
RULE_VARIANTS = {
    "baseline": (lambda r: r, lambda c: c),
    "ep": (expert_parallel_rules, lambda c: c),     # §Perf: expert parallel
    "serve": (serve_rules, lambda c: c),            # §Perf: decode TP + EP
    "kvq": (lambda r: r, _kvq),                     # §Perf: int8 KV cache
    "serve_kvq": (serve_rules, _kvq),
    "dots": (lambda r: r, _dots),                   # §Perf: remat policy
}
from repro.launch.mesh import make_production_mesh
from repro.models.common import abstract_init
from repro.models.model import decode_step, init_model, prefill_step
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per chip, one direction)

_COLLECTIVE_RE = re.compile(
    r"=\s+(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    This counts the *per-device output* of each collective — a conservative
    proxy for link traffic (ring all-gather moves ~(n-1)/n of the output per
    device; reduce ops move ~2x operand for ring reduce-scatter+gather).
    """
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        out[op] = out.get(op, 0) + _shape_bytes(shape_txt)
    return out


def _opt_axes(params_axes):
    return {"step": (), "mu": params_axes, "nu": params_axes}


def _lower_cell(cfg: ModelConfig, shape, mesh, rules):
    """jit + lower + compile one cell's step function on a mesh."""
    params_abs, params_axes = abstract_init(init_model, cfg)
    batch_abs = input_specs(cfg, shape)
    batch_axes = input_axes(cfg, shape)
    p_sh = tree_shardings(params_axes, params_abs, mesh, rules)
    b_sh = tree_shardings(batch_axes, batch_abs, mesh, rules)
    with sharding_context(mesh, rules):
        if shape.kind == "train":
            opt_abs = jax.eval_shape(init_opt_state, params_abs)
            o_sh = tree_shardings(_opt_axes(params_axes), opt_abs, mesh,
                                  rules)
            step = make_train_step(cfg, AdamWConfig())
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            def pf(params, batch):
                return prefill_step(cfg, params, batch)
            lowered = jax.jit(pf, in_shardings=(p_sh, b_sh)).lower(
                params_abs, batch_abs)
        else:
            def dec(params, tokens, cache):
                return decode_step(cfg, params, tokens, cache)
            lowered = jax.jit(dec, in_shardings=(
                p_sh, b_sh["tokens"], b_sh["cache"])).lower(
                params_abs, batch_abs["tokens"], batch_abs["cache"])
        return lowered.compile()


def _probe_cfg(cfg: ModelConfig, n_super: int) -> ModelConfig:
    import dataclasses
    k = cfg.moe.interleave if cfg.moe else 1
    return dataclasses.replace(
        cfg, n_layers=n_super * k,
        n_encoder_layers=(n_super if cfg.is_encdec else 0),
        unroll=True)


def _probe_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(collective_bytes(compiled.as_text()).values())),
    }


def analytic_hbm_bytes(cfg: ModelConfig, shape, n_chips: int) -> float:
    """First-order per-chip HBM traffic model (roofline memory term).

    XLA's "bytes accessed" counts every operand of every HLO op — on TPU
    most of that stays in VMEM/registers after fusion, so it wildly
    over-counts HBM traffic (reported separately as an upper bound). This
    model counts the unavoidable streams: weights (with optimizer state for
    train), boundary activations (with remat), KV-cache reads/writes.
    """
    P = float(cfg.param_count())
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    dt = 2.0  # bf16
    kvd = cfg.n_kv_heads * cfg.head_dim_
    from repro.configs.shapes import effective_cache_len
    C = effective_cache_len(cfg, S)
    if shape.kind == "train":
        tokens = B * S
        # fwd read + bwd read + param write (bf16) ; grads + m + v in fp32
        weights = P * (3 * dt + 3 * 4.0)
        # remat=block: save x at each layer boundary (write + bwd read) and
        # recompute intermediates (~2 more tensor streams per layer)
        acts = tokens * D * L * dt * 4.0
        kv = 0.0
    elif shape.kind == "prefill":
        tokens = B * S
        weights = P * dt
        acts = tokens * D * L * dt * 2.0
        kv = L * B * C * kvd * 2 * dt            # cache writes
    else:  # decode: stream all weights + read the whole cache each step
        tokens = B
        weights = P * dt
        acts = tokens * D * L * dt * 4.0
        kv_elt = 1.0 if cfg.kv_quant else dt     # int8 cache halves traffic
        kv = L * B * C * kvd * 2 * kv_elt
        if cfg.kv_quant:
            kv += L * B * C * cfg.n_kv_heads * 2 * dt   # scales
        if cfg.family in ("ssm", "hybrid") and cfg.ssm:
            kv += L * B * cfg.n_ssm_heads * cfg.ssm.head_dim \
                * cfg.ssm.state_size * 4.0 * 2   # fp32 state read+write
    return (weights + acts + kv) / n_chips


def _ssm_recurrence_flops(cfg: ModelConfig, shape) -> float:
    """Analytic per-token recurrence FLOPs that scan-bodies hide (global)."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    inner = hd * hd if cfg.family == "ssm" else hd * cfg.ssm.state_size
    per_tok = cfg.n_layers * H * 8 * inner
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    return tokens * per_tok * mult


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                variant: str = "baseline",
                mesh_shape: Optional[tuple] = None,
                verbose: bool = True) -> Dict:
    cfg: ModelConfig = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape_name, "variant": variant,
            "mesh": "2x16x16" if multi_pod else
            ("x".join(map(str, mesh_shape)) if mesh_shape else "16x16")}
    if skip:
        cell["skipped"] = skip
        return cell

    if mesh_shape is not None:
        assert not multi_pod
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules_fn, cfg_fn = RULE_VARIANTS[variant]
    rules = rules_fn(multi_pod_rules() if multi_pod else single_pod_rules())
    cfg = cfg_fn(cfg)
    n_chips = 512 if multi_pod else 256

    # 1) full-depth compile (lax.scan over layers): validates the sharding,
    #    gives memory_analysis and the collective schedule
    t0 = time.time()
    compiled = _lower_cell(cfg, shape, mesh, rules)
    cell["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                cell[attr] = int(v)
    cell["collectives"] = collective_bytes(compiled.as_text())

    # 2) cost probes: XLA cost_analysis counts a while-loop body once, so
    #    per-(super)layer cost is measured from two small UNROLLED models
    #    (2 and 4 super-layers: depth-1 models fuse anomalously) and
    #    extrapolated: per = (c4-c2)/2 >= 0; total = c2 + (L/k-2)*per
    k = cfg.moe.interleave if cfg.moe else 1
    L = cfg.n_layers
    t0 = time.time()
    c2p = _probe_costs(_lower_cell(_probe_cfg(cfg, 2), shape, mesh, rules))
    c4p = _probe_costs(_lower_cell(_probe_cfg(cfg, 4), shape, mesh, rules))
    cell["probe_compile_s"] = round(time.time() - t0, 1)
    n_extra = (L / k) - 2

    def extra(key):
        per = max((c4p[key] - c2p[key]) / 2.0, 0.0)
        return c2p[key] + n_extra * per

    flops = extra("flops")
    bytes_acc = extra("bytes")
    coll_total = extra("coll")
    # analytic correction for recurrence steps hidden inside SSM scans
    flops += _ssm_recurrence_flops(cfg, shape) / n_chips
    cell["hlo_flops"] = flops
    cell["hlo_bytes"] = bytes_acc          # upper bound on HBM traffic
    cell["hbm_bytes"] = analytic_hbm_bytes(cfg, shape, n_chips)
    cell["collective_bytes"] = coll_total

    # Roofline terms. cost_analysis on SPMD modules reports PER-DEVICE
    # numbers (the module is the per-device program), so divide by per-chip
    # peaks only; collective bytes are per-device output -> ICI link.
    # memory term uses the analytic HBM model; the HLO byte figure is kept
    # as t_memory_upper_s.
    cell["t_compute_s"] = flops / PEAK_FLOPS
    cell["t_memory_s"] = cell["hbm_bytes"] / HBM_BW
    cell["t_memory_upper_s"] = bytes_acc / HBM_BW
    cell["t_collective_s"] = coll_total / ICI_BW
    dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
              key=lambda k: cell[k])
    cell["bottleneck"] = dom.replace("t_", "").replace("_s", "")

    # model FLOPs (6ND forward+backward for train; 2ND per token for decode)
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        model_flops = 6 * n_active * B * S
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * B * S
    else:
        model_flops = 2 * n_active * B  # one token per row
    cell["model_flops_total"] = float(model_flops)
    cell["model_flops_per_chip"] = float(model_flops) / n_chips
    cell["useful_flop_ratio"] = (
        float(model_flops) / n_chips / flops if flops else 0.0)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(RULE_VARIANTS))
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 8x32")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    cell = dryrun_cell(arch, shape, multi_pod=mp,
                                       variant=args.variant,
                                       mesh_shape=mesh_shape)
                except Exception as e:  # a failure here is a sharding bug
                    cell = {"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16",
                            "error": f"{type(e).__name__}: {e}"}
                tag = ("SKIP" if "skipped" in cell
                       else "FAIL" if "error" in cell else "OK")
                msg = cell.get("skipped") or cell.get("error") or (
                    f"flops/dev={cell['hlo_flops']:.3e} "
                    f"bytes/dev={cell['hlo_bytes']:.3e} "
                    f"coll={cell['collective_bytes']:.3e} "
                    f"bottleneck={cell['bottleneck']} "
                    f"useful={cell['useful_flop_ratio']:.2f} "
                    f"compile={cell['compile_s']}s")
                print(f"[{tag}] {arch} x {shape} x {cell['mesh']}: {msg}",
                      flush=True)
                results.append(cell)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_fail = sum("error" in c for c in results)
    print(f"\n{len(results)} cells, {n_fail} failures -> {args.out}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --preset smoke
    PYTHONPATH=src python -m repro.launch.train --arch dcache-agent-150m \
        --preset full --steps 300 --batch 8 --seq 256

``--preset smoke`` trains the arch's reduced config on CPU; ``--preset
full`` uses the real config (TPU-scale — on this container only sensible
for dcache-agent-150m). Checkpoints, fault-tolerance hooks, and the
prefetching data pipeline are all active in both presets.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import ALL_IDS, get_config
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.models.common import Init, unbox
from repro.models.model import init_model
from repro.training.data import Prefetcher, TokenStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dcache-agent-150m", choices=ALL_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={jax.device_count()}")

    ini = Init(jax.random.PRNGKey(0), dtype=cfg.jnp_dtype)
    params, _ = unbox(init_model(ini, cfg))

    stream = TokenStream(cfg, batch=args.batch, seq=args.seq, seed=0)
    data = Prefetcher(stream, depth=2)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    mon = HeartbeatMonitor()
    ck = Checkpointer(args.ckpt_dir, keep=2)
    loop = TrainLoop(cfg, opt_cfg, params, data, checkpointer=ck,
                     ckpt_every=args.ckpt_every, accum_steps=args.accum,
                     monitor=mon)
    if args.resume and loop.restore_if_available():
        print(f"resumed from step {loop.step_idx}")

    t0 = time.time()
    metrics = loop.run(args.steps)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done: {metrics}  ({dt:.1f}s, {tok_s:.0f} tok/s, "
          f"loss {loop.history[0]:.3f} -> {loop.history[-1]:.3f}, "
          f"stragglers={len(mon.stragglers)})")
    data.close()


if __name__ == "__main__":
    main()

"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch dcache-agent-150m \
        --requests 8 --max-new 24
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import ALL_IDS, get_config
from repro.models.common import Init, unbox
from repro.models.model import init_model
from repro.serving.engine import ServingEngine

PROMPTS = [
    "Plot the xview1 images from 2022 around Newport Beach",
    "Detect airplanes in this area",
    "Show fair1m and xview1 imagery from 2022",
    "Classify the land cover near Houston",
    "How many ships were detected in Miami in 2021?",
    "Render a heatmap of detections for Seattle",
    "What does the Denver area look like?",
    "Count the cloudy scenes in sentinel2-2020",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dcache-agent-150m", choices=ALL_IDS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg.reduced(), vocab_size=512)
    ini = Init(jax.random.PRNGKey(0), dtype=cfg.jnp_dtype)
    params, _ = unbox(init_model(ini, cfg))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_len=args.max_len)
    reqs = [eng.submit(PROMPTS[i % len(PROMPTS)], max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run_until_done()
    for r in reqs:
        print(f"[{r.rid}] {eng.tok.decode(r.prompt_ids)!r} -> "
              f"{eng.tok.decode(r.out_ids)!r}")
    print("stats:", eng.stats())


if __name__ == "__main__":
    main()

"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on TPU they
compile to Mosaic. ``interpret`` is selected from the backend automatically.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rms
from repro.kernels import rwkv_wkv as _wkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    chunk: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               chunk=chunk, block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def decode_attention(q, k, v, pos, *, window: Optional[int] = None,
                     chunk: Optional[int] = None, block_k: int = 512):
    return _dec.decode_attention(q, k, v, pos, window=window, chunk=chunk,
                                 block_k=block_k, interpret=_interpret())


def wkv(r, k, v, w, u, *, chunk: int = 64):
    return _wkv.wkv(r, k, v, w, u, chunk=chunk, interpret=_interpret())


def rmsnorm(x, gain, *, eps: float = 1e-5, block_rows: int = 256):
    return _rms.rmsnorm(x, gain, eps=eps, block_rows=block_rows,
                        interpret=_interpret())

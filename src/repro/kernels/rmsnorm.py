"""Fused RMSNorm Pallas TPU kernel (memory-bound elementwise + reduction).

One (rows x d) tile per grid step: read once, rsqrt-normalise in fp32,
scale, write once — fusing what XLA would otherwise split into a reduce and
a multiply pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype) * g_ref[...])


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, gain: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); gain: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = 1
    for s in x.shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    block_rows = min(block_rows, n)
    if n % block_rows:
        block_rows = 1
    grid = (n // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, gain)
    return out.reshape(orig_shape)

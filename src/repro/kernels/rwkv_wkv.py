"""RWKV6 WKV recurrence Pallas TPU kernel.

The per-head recurrent state S (hd_k x hd_v, fp32) lives in VMEM scratch and
is carried across the time-chunk grid dimension (innermost, "arbitrary"),
so HBM traffic is exactly one pass over r/k/v/w plus one y write — the
memory-optimal schedule for an attention-free layer. Inside the kernel each
chunk runs a ``fori_loop`` of rank-1 state updates:

    y_t = r_t (S + u * k_t^T v_t);   S <- diag(w_t) S + k_t^T v_t

Grid = (B, H, n_chunks); hd is 64 for rwkv6-7b, so the (64, 64) state tile
is sublane/lane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)                      # (hd,)

    def step(t, _):
        rt = r_ref[0, 0, t].astype(jnp.float32)           # (hd,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        s = state_ref[...]                                # (hd, hd) fp32
        kv = kt[:, None] * vt[None, :]                    # rank-1 outer
        y = jnp.einsum("i,ij->j", rt, s + u[:, None] * kv)
        state_ref[...] = wt[:, None] * s + kv
        o_ref[0, 0, t] = y.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_final_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: jax.Array, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/w: (B, H, S, hd); u: (H, hd). Returns (y (B,H,S,hd), s (B,H,hd,hd))."""
    B, H, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_final

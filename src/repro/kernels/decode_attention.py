"""Single-token decode attention Pallas TPU kernel.

Decode is HBM-bandwidth bound: the whole KV cache streams through VMEM once
per step while the query row stays resident. Grid = (B, Hkv, n_kv_blocks)
with the kv-block dimension innermost ("arbitrary") carrying the streaming
softmax state in VMEM scratch. All q heads of one KV group (GQA) are
processed together as a (group x d) tile — turning the memory-bound dot
into a small MXU matmul and amortising each KV byte across the group.

Masking covers the ring-buffer layout: slot j holds position
``pos - ((pos - j) mod C)``; slots outside [pos-window, pos] (or the current
attention chunk) are masked.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, window: Optional[int],
                   chunk: Optional[int], block_k: int, n_kv_blocks: int,
                   cache_len: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (group, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    pos = pos_ref[0]                                     # () current position
    j = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    pslot = pos - jax.lax.rem(pos - j + cache_len * 2, cache_len)
    ok = pslot >= 0
    if window is not None:
        ok &= (pos - pslot) < window
    if chunk is not None:
        ok &= (pslot // chunk) == (pos // chunk)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "chunk", "block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     chunk: Optional[int] = None, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, d); k/v: (B, Hkv, C, d) ring buffers; pos: (B,) int32.

    Returns (B, Hq, d). Ring layout: token t lives in slot t %% C and the
    current token's K/V must already be written at slot pos %% C.
    """
    B, Hq, d = q.shape
    _, Hkv, C, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_k = min(block_k, C)
    assert C % block_k == 0, (C, block_k)
    nk = C // block_k
    scale = d ** -0.5
    qg = q.reshape(B, Hkv, group, d)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, chunk=chunk,
        block_k=block_k, n_kv_blocks=nk, cache_len=C)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, qg, k, v)
    return out.reshape(B, Hq, d)

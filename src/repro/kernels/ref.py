"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None) -> jax.Array:
    """q: (B,Hq,S,d); k/v: (B,Hkv,S,d). Full softmax attention."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= (qp - kp) < window
    if chunk is not None:
        ok &= (qp // chunk) == (kp // chunk)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ref_decode_attention(q, k, v, pos, *, window: Optional[int] = None,
                         chunk: Optional[int] = None) -> jax.Array:
    """q: (B,Hq,d); k/v: (B,Hkv,C,d) ring buffers; pos: (B,)."""
    B, Hq, d = q.shape
    _, Hkv, C, _ = k.shape
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhcd->bhc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    j = jnp.arange(C)[None, :]
    p = pos[:, None].astype(jnp.int32)
    pslot = p - jnp.mod(p - j, C)
    ok = pslot >= 0
    if window is not None:
        ok &= (p - pslot) < window
    if chunk is not None:
        ok &= (pslot // chunk) == (p // chunk)
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhc,bhcd->bhd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ref_wkv(r, k, v, w, u):
    """Naive WKV scan. r/k/v/w: (B,H,S,hd); u: (H,hd)."""
    B, H, S, hd = r.shape

    def step(s, ts):
        rt, kt, vt, wt = ts
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       s + u[None, :, :, None].astype(jnp.float32) * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(t.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32)
               for t in (r, k, v, w))  # (S,B,H,hd)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(1, 0).swapaxes(1, 2)  # back to (B,H,S,hd)
    return y.astype(r.dtype), s_final


def ref_rmsnorm(x, gain, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gain

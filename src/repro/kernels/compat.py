"""jax/pallas toolchain compatibility shims.

The TPU compiler-params dataclass was renamed across jax releases:
``pltpu.TPUCompilerParams`` (<= 0.4.x) became ``pltpu.CompilerParams``
(newer releases, as used in the pallas guide). All kernels build their
params through :func:`tpu_compiler_params` so they run on either
toolchain without touching kernel code.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct the installed toolchain's TPU compiler-params object."""
    return _COMPILER_PARAMS_CLS(**kwargs)

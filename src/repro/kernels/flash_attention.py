"""Flash attention (prefill) Pallas TPU kernel.

Streaming-softmax attention with explicit VMEM tiling: (block_q x d) query
tiles stay resident while (block_k x d) K/V tiles stream from HBM; the
running max / normalizer / output accumulator live in VMEM scratch across
the kv-block grid dimension (the innermost, "arbitrary" one). Causal,
sliding-window and chunked-local masking are applied inside the kernel, and
fully-masked kv blocks are skipped (no MXU work issued).

GQA is handled with *no* K/V materialisation: the K/V BlockSpec index maps
query head h -> kv head h // group.

Block sizes default to 128x128 — MXU-aligned (128 lanes, 8|16 sublanes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  chunk: Optional[int], block_q: int, block_k: int,
                  n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # visibility pre-check: skip blocks that are fully masked
    visible = True
    if causal:
        visible = jnp.logical_and(
            visible, k_start <= q_start + block_q - 1)
    if window is not None:
        visible = jnp.logical_and(
            visible, (q_start - (k_start + block_k - 1)) < window)
    if chunk is not None:
        visible = jnp.logical_and(
            visible, (q_start + block_q - 1) // chunk >= k_start // chunk)
        visible = jnp.logical_and(
            visible, q_start // chunk <= (k_start + block_k - 1) // chunk)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= (qpos - kpos) < window
        if chunk is not None:
            ok &= (qpos // chunk) == (kpos // chunk)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]                            # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)        # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        # rows with no visible key this block: p=exp(NEG_INF - m) ~ 0, fine
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    chunk: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, d); k/v: (B, Hkv, S, d); Hq %% Hkv == 0. -> (B, Hq, S, d)."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        chunk=chunk, block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    decode_attention,
    flash_attention,
    rmsnorm,
    wkv,
)

"""The localized data cache (paper §III, "Cache specifications").

Key = ``dataset-year`` string (temporal granularity — the paper found
long-lat keys too spatially skewed); value = the per-year imagery-metadata
frame (a ``GeoFrame``, 50-100 MB in the paper); capacity = 5 entries.

The cache itself is mechanism only: *who decides* reads/updates is the
controller layer (``repro.core.controller``) — programmatic, or GPT-driven
via prompting (the paper's contribution).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional

DEFAULT_CAPACITY = 5


@dataclasses.dataclass
class CacheEntry:
    key: str
    value: Any
    size_bytes: int
    created_at: float
    last_access: float
    access_count: int = 0
    insert_order: int = 0
    # datastore version this copy was read at (ISSUE-8 mutable data plane).
    # 0 everywhere until a MutationPlan is wired; the coherence layer
    # compares it against the key's current version at every consume.
    version: int = 0


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    puts: int = 0
    drops: int = 0   # explicit removals (replica demotion) — not evictions
    # admission accounting (zero unless an AdmissionPolicy is wired in the
    # controller): full-cache decisions to install vs bypass. A bypassed
    # load streams to the caller without evicting any resident.
    admitted: int = 0
    bypassed: int = 0
    # GPT-hit accounting (paper Table III): decisions where the LLM correctly
    # used the cache when it should have (and main memory when it should have)
    llm_correct_decisions: int = 0
    llm_total_decisions: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def gpt_hit_rate(self) -> float:
        if not self.llm_total_decisions:
            return 1.0
        return self.llm_correct_decisions / self.llm_total_decisions


class DataCache:
    """Capacity-bounded key-value cache over tool data."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock: Optional[Callable[[], float]] = None):
        self.capacity = capacity
        self._clock = clock or (lambda: float(self._ticks))
        self._ticks = 0
        self._entries: Dict[str, CacheEntry] = {}
        self._insert_counter = 0
        self.stats = CacheStats()

    # -- time ---------------------------------------------------------------
    def _now(self) -> float:
        # strictly monotonic even when the sim clock has not advanced
        # between operations (unique last_access -> deterministic LRU order
        # for both the programmatic policy and the LLM grader)
        self._ticks += 1
        return self._clock() + 1e-9 * self._ticks

    # -- queries ------------------------------------------------------------
    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> List[str]:
        return list(self._entries.keys())

    def entries(self) -> Dict[str, CacheEntry]:
        return dict(self._entries)

    def peek(self, key: str):
        """Read without touching recency/frequency metadata."""
        e = self._entries.get(key)
        return None if e is None else e.value

    def get(self, key: str):
        """Cache read (the ``read_cache`` tool). Raises KeyError on miss —
        a miss surfaces as a failed tool call that the agent re-plans around
        (paper: 'the LLM is prompted to reassess its tool sequence')."""
        e = self._entries.get(key)
        if e is None:
            self.stats.misses += 1
            raise KeyError(f"cache miss: {key!r} not in cache "
                           f"(contents: {sorted(self._entries)})")
        self.stats.hits += 1
        e.last_access = self._now()
        e.access_count += 1
        return e.value

    # -- updates ------------------------------------------------------------
    def entry(self, key: str) -> Optional[CacheEntry]:
        """The live entry object (or None) WITHOUT touching recency or
        frequency metadata — the coherence layer's version/age probe."""
        return self._entries.get(key)

    def put(self, key: str, value: Any, size_bytes: int = 0,
            victim: Optional[str] = None, version: int = 0) -> Optional[str]:
        """Insert ``key``; if full, evict ``victim`` (caller-chosen — the
        controller decides, per the paper's prompt-driven update policy).
        Returns the evicted key, if any."""
        evicted = None
        if key not in self._entries and len(self._entries) >= self.capacity:
            if victim is None or victim not in self._entries:
                raise ValueError(
                    f"cache full and victim {victim!r} invalid "
                    f"(contents: {sorted(self._entries)})")
            del self._entries[victim]
            self.stats.evictions += 1
            evicted = victim
        now = self._now()
        self._insert_counter += 1
        prev = self._entries.get(key)
        self._entries[key] = CacheEntry(
            key=key, value=value, size_bytes=size_bytes, created_at=now,
            last_access=now,
            access_count=prev.access_count if prev else 0,
            insert_order=prev.insert_order if prev else self._insert_counter,
            version=version)
        self.stats.puts += 1
        return evicted

    def drop(self, key: str) -> bool:
        """Explicitly remove ``key`` (replica demotion — distinct from a
        capacity eviction in the stats). Returns whether it was present."""
        if key not in self._entries:
            return False
        del self._entries[key]
        self.stats.drops += 1
        return True

    def apply_state(self, keys: List[str], loader: Callable[[str], Any],
                    size_of: Callable[[Any], int]):
        """Force the cache to exactly ``keys`` (the GPT-driven update path:
        the LLM returns the new cache state as JSON; we reconcile). Invalid
        states (too many keys, dropped-but-needed data) are the LLM's errors
        and are visible in metrics."""
        keys = list(dict.fromkeys(keys))[: self.capacity]
        for k in list(self._entries):
            if k not in keys:
                del self._entries[k]
                self.stats.evictions += 1
        for k in keys:
            if k not in self._entries:
                v = loader(k)
                self.put(k, v, size_of(v))

    # -- serialization for prompts -------------------------------------------
    def contents_json(self) -> str:
        return json.dumps({
            k: {"last_access": e.last_access,
                "access_count": e.access_count,
                "insert_order": e.insert_order,
                "size_mb": round(e.size_bytes / 1e6, 1)}
            for k, e in sorted(self._entries.items())
        }, sort_keys=True)

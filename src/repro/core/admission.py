"""Cross-session cache admission (TinyLFU-style) for the shared pod cache.

The concurrent engine installs *every* loaded key into its owning pod; under
contention many sessions stream one-shot keys through the cache and churn
out each other's hot residents (at 16 sessions / 4 pods the bench shows
~27% local hits). Admission fixes that: before a loaded key may evict a
resident, an :class:`AdmissionPolicy` compares the candidate against the
eviction victim and either **admits** it (evict + install) or **bypasses**
the cache — the value still streams through to the requesting session, but
no resident is evicted (bypass-on-miss semantics).

Frequency evidence comes from a :class:`FrequencySketch` — a vectorized
count-min sketch (numpy) shared across *all* sessions, aged by periodically
halving every counter on the simulation clock so stale popularity decays
(the TinyLFU reset). Every logical cache access touches the sketch, so an
entry's estimate approximates its recent global popularity regardless of
which session produced the traffic.

Mirroring ``repro.core.policies``, each admission policy carries both a
programmatic ``admit()`` and a natural-language ``describe()``; the
GPT-driven path (:class:`LLMAdmission`) renders ``describe()`` plus the
sketch estimates into a prompt and lets the LLM make the call — exactly how
the paper's prompted eviction works, extended to admission.

Batched hot path (ISSUE 4): touches are *deferred* — ``touch``/``touch_many``
append interned key ids to a buffer, and the buffer is flushed (applied in
exact arrival order, preserving conservative-update semantics bit-for-bit)
only at a read boundary: an ``estimate``/``estimate_many``/``top_k`` call, an
aging epoch, or buffer overflow. Between boundaries the per-access cost is an
append plus one dict lookup instead of a blake2 hash and three numpy
small-array ops, which is what lets the concurrent engine scale to 256
sessions. Counters live in a flat Python list (scalar reads beat numpy
fancy-indexing at depth=4); ``table`` materialises the numpy view on demand
and aging/top-k remain vectorized.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_WIDTH = 1024
DEFAULT_DEPTH = 4
DEFAULT_AGE_PERIOD_S = 180.0
FLUSH_BUFFER_MAX = 8192      # flush the deferred-touch buffer at this size


class FrequencySketch:
    """Count-min sketch with conservative update and time-driven aging.

    ``touch(key, now)`` records one access; ``estimate(key)`` returns the
    (over-)estimate of the key's access count since roughly the last aging
    window. Aging halves every counter each ``age_period_s`` simulated
    seconds — callers pass ``now`` from their sim clock (the concurrent
    engine passes session clocks, which only execute at the global-minimum
    time, so touches arrive in nondecreasing order) or construct with a
    ``clock`` callable. Hashing is blake2b so estimates are deterministic
    across runs and machines.

    Touches are buffered and applied lazily (see module docstring): every
    read (``estimate*``/``top_k``) and every aging boundary flushes the
    buffer first, in arrival order, so observable estimates are exactly
    those of the old touch-immediately implementation.
    """

    def __init__(self, width: int = DEFAULT_WIDTH, depth: int = DEFAULT_DEPTH,
                 age_period_s: float = DEFAULT_AGE_PERIOD_S, clock=None):
        assert width > 0 and depth > 0
        self.width = width
        self.depth = depth
        self.age_period_s = age_period_s
        self._clock = clock
        # authoritative counters: flat Python ints (row * width + col).
        # Scalar list ops are ~10x cheaper than numpy fancy-indexing for
        # depth-sized reads; aging round-trips through numpy (vectorized).
        self._flat: List[int] = [0] * (depth * width)
        self._rows = np.arange(depth)
        # interning: key -> dense id; per-id flat cell indices (tuple for the
        # flush loop) + a lazily rebuilt (n_keys, depth) matrix for top_k
        self._key_id: Dict[str, int] = {}
        self._id_key: List[str] = []
        self._id_cells: List[Tuple[int, ...]] = []
        self._idx_matrix: Optional[np.ndarray] = None
        self._buf: List[int] = []
        self._last_age = 0.0
        self.touches = 0
        self.ages = 0
        self.flushes = 0

    # -- interning / hashing --------------------------------------------------
    def _intern(self, key: str) -> int:
        kid = self._key_id.get(key)
        if kid is None:
            h = hashlib.blake2b(key.encode(),
                                digest_size=8 * self.depth).digest()
            cols = np.frombuffer(h, dtype=np.uint64) % np.uint64(self.width)
            kid = len(self._id_key)
            self._key_id[key] = kid
            self._id_key.append(key)
            self._id_cells.append(tuple(
                int(r) * self.width + int(c) for r, c in zip(self._rows, cols)))
            self._idx_matrix = None      # stale; rebuilt on next top_k
        return kid

    def _indices(self, key: str) -> np.ndarray:
        """Per-row column indices of ``key`` (kept for tests/diagnostics)."""
        cells = self._id_cells[self._intern(key)]
        return np.array([c % self.width for c in cells], dtype=np.int64)

    # -- deferred-touch buffer ------------------------------------------------
    def flush(self) -> None:
        """Apply buffered touches in exact arrival order (conservative
        update: only the minimum cells increment — order-exact, so estimates
        match the touch-immediately implementation bit-for-bit)."""
        buf = self._buf
        if not buf:
            return
        flat = self._flat
        cells_of = self._id_cells
        for kid in buf:
            cells = cells_of[kid]
            vals = [flat[c] for c in cells]
            lo = min(vals)
            for c, v in zip(cells, vals):
                if v == lo:
                    flat[c] = v + 1
        buf.clear()
        self.flushes += 1

    def _maybe_age(self, now: Optional[float]) -> None:
        if now is None:
            now = self._clock() if self._clock is not None else None
        if now is None or self.age_period_s <= 0:
            return
        while now - self._last_age >= self.age_period_s:
            self.age()
            self._last_age += self.age_period_s

    def age(self) -> None:
        """TinyLFU reset: halve every counter (vectorized). Flushes first —
        buffered touches arrived before this aging boundary."""
        self.flush()
        arr = np.asarray(self._flat, dtype=np.uint64) >> 1
        self._flat = arr.tolist()
        self.ages += 1

    def touch(self, key: str, now: Optional[float] = None) -> None:
        """Record one access (deferred; see ``flush``)."""
        self._maybe_age(now)
        self._buf.append(self._intern(key))
        self.touches += 1
        if len(self._buf) >= FLUSH_BUFFER_MAX:
            self.flush()

    def touch_many(self, keys: Sequence[str],
                   now: Optional[float] = None) -> None:
        """Record one access per key, in order (single aging check — the
        batch shares one timestamp, like a read plan's key walk)."""
        self._maybe_age(now)
        intern = self._intern
        self._buf.extend(intern(k) for k in keys)
        self.touches += len(keys)
        if len(self._buf) >= FLUSH_BUFFER_MAX:
            self.flush()

    # -- reads (flush boundaries) ---------------------------------------------
    def _estimate_interned(self, kid: int) -> int:
        flat = self._flat
        return min(flat[c] for c in self._id_cells[kid])

    def estimate(self, key: str) -> int:
        self.flush()
        return self._estimate_interned(self._intern(key))

    def estimate_many(self, keys: Sequence[str]) -> List[int]:
        """Batched estimates: one flush, then scalar reads per key."""
        self.flush()
        return [self._estimate_interned(self._intern(k)) for k in keys]

    def estimate_peek(self, key: str) -> int:
        """Estimate WITHOUT interning: a never-touched key queried here
        does not join the ``top_k`` candidate population (diagnostic
        surfaces like the ``cache_replicate`` tool must be side-effect
        free)."""
        kid = self._key_id.get(key)
        if kid is not None:
            self.flush()
            return self._estimate_interned(kid)
        h = hashlib.blake2b(key.encode(),
                            digest_size=8 * self.depth).digest()
        cols = np.frombuffer(h, dtype=np.uint64) % np.uint64(self.width)
        self.flush()
        flat = self._flat
        return min(flat[int(r) * self.width + int(c)]
                   for r, c in zip(self._rows, cols))

    def top_k(self, k: int = 8) -> List[Tuple[str, int]]:
        """The ``k`` hottest *interned* keys by estimate, hottest first
        (ties broken by key for determinism). Only keys ever touched or
        estimated are candidates — exactly the population the admission
        and replication layers care about. Vectorized over the interned
        index matrix; this is the replicator's epoch feed."""
        self.flush()
        n = len(self._id_key)
        if n == 0 or k <= 0:
            return []
        if self._idx_matrix is None or len(self._idx_matrix) != n:
            self._idx_matrix = np.asarray(self._id_cells, dtype=np.int64)
        est = np.asarray(self._flat, dtype=np.int64)[
            self._idx_matrix].min(axis=1)
        k = min(k, n)
        order = np.lexsort((np.array(self._id_key), -est))[:k]
        return [(self._id_key[i], int(est[i])) for i in order]

    @property
    def table(self) -> np.ndarray:
        """Materialised (depth, width) numpy view of the counters (flushes
        pending touches first; intended for tests/diagnostics, not the hot
        path)."""
        self.flush()
        return np.asarray(self._flat, dtype=np.uint32).reshape(
            self.depth, self.width)


def entries_json(entries) -> str:
    """Cache contents serialized for the admission prompt (the same shape
    ``DataCache.contents_json`` uses, minus values)."""
    return json.dumps({
        k: {"last_access": e.last_access, "access_count": e.access_count}
        for k, e in sorted(entries.items())
    }, sort_keys=True)


# ---------------------------------------------------------------------------
# Admission policies (mirror of repro.core.policies: programmatic + prompt)
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Decides whether a loaded key may evict ``victim`` or must bypass.

    Called only when the owning cache is full (an insert into free capacity
    is always admitted). ``admit`` returning ``False`` means bypass: the
    value streams through to the caller without installing or evicting.
    """

    name = "base"

    def admit(self, key: str, victim: str, sketch: Optional[FrequencySketch],
              entries, size_bytes: Optional[int] = None) -> bool:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


class AdmitAll(AdmissionPolicy):
    """The pre-admission behavior: every load installs (and evicts)."""

    name = "always"

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        return True

    def describe(self):
        return ("Always-admit: every key loaded from the database is "
                "installed into the cache; when full, evict the update "
                "policy's victim to make room. Never bypass.")


class TinyLFU(AdmissionPolicy):
    """Frequency-based admission (TinyLFU): the candidate must be more
    popular than the entry it would evict."""

    name = "tinylfu"

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        if sketch is None:
            return True
        kf, vf = sketch.estimate_many((key, victim))   # one buffer flush
        return kf > vf

    def describe(self):
        return ("TinyLFU admission: when the cache is full, compare the "
                "candidate key's estimated access frequency against the "
                "eviction victim's. ADMIT (evict the victim, install the "
                "candidate) only if the candidate's frequency is STRICTLY "
                "HIGHER; otherwise BYPASS the cache — pass the loaded data "
                "through to the caller without caching it, leaving every "
                "resident entry untouched.")


class TinyLFUCost(AdmissionPolicy):
    """Cost-aware admission (GDSF-inspired, adapted to slot capacity):
    weight frequency by the entry's modeled *miss penalty*.

    Classic GDSF divides frequency by size because its cache is
    byte-bounded — small hot objects pack better. Ours is ENTRY-bounded
    (the paper's 5-slot cache): size buys no packing, but it does set the
    cost of every future miss (DB load time grows with frame size). The
    slot-value of an entry is therefore ``frequency x miss_penalty``, with
    ``miss_penalty ~ BASE_BYTES + size_bytes`` (the fixed per-load overhead
    — network/round-trip, ~0.62 s at 0.003 s/MB, i.e. ~200 MB-equivalent —
    plus the size-proportional transfer). Admit only when the candidate's
    slot-value strictly beats the victim's; exact integer cross-multiply,
    no float division. When either size is unknown it degrades to the
    plain TinyLFU frequency comparison. The ablation only has signal once
    frame sizes diverge (see the engine's ``rows_range`` widened band).
    """

    name = "tinylfu-cost"
    BASE_BYTES = 200_000_000     # fixed per-load overhead, in size units

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        if sketch is None:
            return True
        kf, vf = sketch.estimate_many((key, victim))
        ventry = entries.get(victim) if entries else None
        vsize = getattr(ventry, "size_bytes", 0) if ventry else 0
        if not size_bytes or not vsize:
            return kf > vf                 # sizes unknown: plain TinyLFU
        return (kf * (self.BASE_BYTES + size_bytes)
                > vf * (self.BASE_BYTES + vsize))

    def describe(self):
        return ("Cost-aware TinyLFU admission: when the cache is full, "
                "compare SLOT VALUE — the candidate's estimated access "
                "frequency times its miss penalty (a fixed per-load "
                "overhead plus its size in bytes) against the eviction "
                "victim's frequency times the victim's miss penalty. ADMIT "
                "(evict the victim, install the candidate) only if the "
                "candidate's slot value is STRICTLY HIGHER; otherwise "
                "BYPASS the cache — stream the loaded data through to the "
                "caller without caching it, leaving every resident entry "
                "untouched. Intuition: with slot-bounded capacity, a large "
                "hot frame is worth MORE than a small equally-hot one — "
                "every miss on it costs a longer database load.")


class Doorkeeper(AdmissionPolicy):
    """Second-chance admission: one-shot keys never evict a resident; a key
    is admitted once it has been seen at least twice in the aging window."""

    name = "doorkeeper"

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        if sketch is None:
            return True
        return sketch.estimate(key) >= 2

    def describe(self):
        return ("Doorkeeper admission: when the cache is full, ADMIT the "
                "candidate (evicting the victim) only if it has been seen "
                "at least twice within the current aging window (estimated "
                "frequency of 2 or more); a first-time key must BYPASS the "
                "cache — its data passes through to the caller and no "
                "resident is evicted.")


class ScanTinyLFU(TinyLFU):
    """Scan-resistant TinyLFU (carried follow-up from PR 3/4).

    TinyLFU's strictly-higher gate is exactly wrong during a sequential
    scan: the convoy of sessions sweeps the key space in lockstep, so the
    *next* keys — not the frequent ones — are the ones about to be read,
    and install-everything beats TinyLFU (30.5% vs 22.8% local hits on the
    ``scan`` scenario). The stride detector rides the admission candidate
    stream (no sketch change — sketch behavior is digest-locked): each
    key is assigned a position the first time it shows up as a candidate,
    so a sweep — which first visits keys in a stable order and then
    revisits them in that same order — produces successive candidate
    positions with small deltas (``|delta| <= window``; the convoy's
    interleaving and task-level reuse jitter the delta around 0/1, never
    far). A skewed workload's candidates are tail keys in popularity
    order, uncorrelated with first-seen order, so deltas are uniform over
    the keyspace. An EWMA of the small-delta indicator with hysteresis
    opens the gate (admit everything, LRU-like) while the stream is
    scan-shaped and closes it when skew returns. Measured gate-open share
    on the candidate stream: ~0.99 on ``scan`` vs <= 0.07 on ``working``
    / ``zipf`` / ``hotspot``."""

    name = "scan-tinylfu"

    def __init__(self, window: int = 8, open_at: float = 0.6,
                 close_at: float = 0.4, alpha: float = 0.1):
        assert window >= 1
        assert 0.0 < close_at < open_at < 1.0 and 0.0 < alpha <= 1.0
        self.window = window
        self.open_at = open_at
        self.close_at = close_at
        self.alpha = alpha
        self._pos: Dict[str, int] = {}    # key -> first-seen position
        self._prev: Optional[int] = None
        # seeded between the thresholds: the gate starts closed (pure
        # TinyLFU) and a scan opens it within a few candidates
        self._ewma = 0.5
        self.gate_open = False
        self.gate_opens = 0
        self.gate_closes = 0

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        pos = self._pos.setdefault(key, len(self._pos))
        delta = pos - self._prev if self._prev is not None else 1
        self._prev = pos
        signal = 1.0 if abs(delta) <= self.window else 0.0
        self._ewma += self.alpha * (signal - self._ewma)
        if self.gate_open:
            if self._ewma < self.close_at:
                self.gate_open = False
                self.gate_closes += 1
        elif self._ewma >= self.open_at:
            self.gate_open = True
            self.gate_opens += 1
        if self.gate_open:
            return True        # scan detected: admit (evict LRU-style)
        return super().admit(key, victim, sketch, entries, size_bytes)

    def describe(self):
        return ("Scan-resistant TinyLFU admission: normally ADMIT the "
                "candidate (evicting the victim) only if its estimated "
                "frequency is STRICTLY HIGHER than the victim's, otherwise "
                "BYPASS. But when the recent candidate stream looks like a "
                "sequential scan — successive candidates visiting the key "
                "space in a stable sweep order instead of popularity-random "
                "tail keys — open the gate and ADMIT everything until the "
                "stream stops looking sequential.")


class LLMAdmission(AdmissionPolicy):
    """GPT-driven admission: the base policy's ``describe()`` text plus the
    sketch estimates are rendered into a prompt and the LLM answers
    admit/bypass in natural language (the paper's prompted-eviction twist
    applied to admission). Graded against the programmatic base decision;
    unparseable completions fall back to it.

    Like the paper's prompted *update*, the decision runs off the critical
    path (post-round bookkeeping — Table III shows ~0 latency delta), so it
    costs tokens but not user-perceived latency: each call accumulates
    ``prompt_tokens``/``completion_tokens``, which the single-session
    controllers fold into the task trace and the engine surfaces as
    ``admission_tokens``.
    """

    def __init__(self, base: AdmissionPolicy, llm, few_shot: bool = True):
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.name = f"llm-{base.name}"
        self.llm_total = 0
        self.llm_correct = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0
        # resilience fallbacks to the programmatic base (ungraded): garbled
        # prompt/completion vs endpoint pool down (ISSUE 9)
        self.parse_fallbacks = 0
        self.degraded = 0
        # locality evidence source (repro.core.locality.LocalityModel):
        # wired by the concurrent engine under session->pod affinity; the
        # prompt then exposes the candidate's remote consumer demand.
        # None (the default) keeps the prompt byte-identical to PR-3/4.
        self.locality = None

    def describe(self):
        return self.base.describe()

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def _home_demand_json(self, key) -> Optional[str]:
        if self.locality is None or self.locality.penalty <= 1.0:
            return None
        demand = self.locality.remote_demand.get(key)
        return json.dumps(demand, sort_keys=True) if demand else None

    def admit(self, key, victim, sketch, entries, size_bytes=None):
        from repro.core.endpoints import LLMUnavailableError
        from repro.core.prompts import LLMParseError, \
            admission_decision_prompt, parse_json_tail
        kf, vf = (sketch.estimate_many((key, victim))
                  if sketch is not None else (0, 0))
        prompt = admission_decision_prompt(
            self.base.describe(), key, victim, kf, vf,
            entries_json(entries), self.few_shot,
            home_demand_json=self._home_demand_json(key))
        expected = self.base.admit(key, victim, sketch, entries,
                                   size_bytes=size_bytes)
        try:
            completion = self.llm.complete(prompt)
        except LLMUnavailableError:
            # endpoint pool down: programmatic twin, ungraded (the router
            # already billed the wasted retry tokens)
            self.degraded += 1
            return expected
        except LLMParseError:
            self.parse_fallbacks += 1
            self.prompt_tokens += len(prompt) // 4
            return expected
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(completion) // 4
        try:
            raw = parse_json_tail(completion)
            decision = raw.get("decision") if isinstance(raw, dict) else None
        except ValueError:
            decision = None
        if decision not in ("admit", "bypass"):
            # garbled/meaningless completion: programmatic twin, ungraded
            self.parse_fallbacks += 1
            return expected
        got = decision == "admit"
        self.llm_total += 1
        self.llm_correct += int(got == expected)
        return got


ADMISSIONS = {"always": AdmitAll, "tinylfu": TinyLFU,
              "tinylfu-cost": TinyLFUCost, "doorkeeper": Doorkeeper,
              "scan-tinylfu": ScanTinyLFU}


def make_admission(name: str, *, impl: str = "python", llm=None,
                   few_shot: bool = True, **kw) -> AdmissionPolicy:
    """Build an admission policy; ``impl="llm"`` wraps it in the GPT-driven
    path (requires an ``llm`` backend with ``complete(prompt) -> str``)."""
    base = ADMISSIONS[name](**kw)
    if impl == "llm":
        assert llm is not None, "LLM-driven admission needs an llm backend"
        return LLMAdmission(base, llm, few_shot=few_shot)
    return base

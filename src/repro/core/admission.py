"""Cross-session cache admission (TinyLFU-style) for the shared pod cache.

The concurrent engine installs *every* loaded key into its owning pod; under
contention many sessions stream one-shot keys through the cache and churn
out each other's hot residents (at 16 sessions / 4 pods the bench shows
~27% local hits). Admission fixes that: before a loaded key may evict a
resident, an :class:`AdmissionPolicy` compares the candidate against the
eviction victim and either **admits** it (evict + install) or **bypasses**
the cache — the value still streams through to the requesting session, but
no resident is evicted (bypass-on-miss semantics).

Frequency evidence comes from a :class:`FrequencySketch` — a vectorized
count-min sketch (numpy) shared across *all* sessions, aged by periodically
halving every counter on the simulation clock so stale popularity decays
(the TinyLFU reset). Every logical cache access touches the sketch, so an
entry's estimate approximates its recent global popularity regardless of
which session produced the traffic.

Mirroring ``repro.core.policies``, each admission policy carries both a
programmatic ``admit()`` and a natural-language ``describe()``; the
GPT-driven path (:class:`LLMAdmission`) renders ``describe()`` plus the
sketch estimates into a prompt and lets the LLM make the call — exactly how
the paper's prompted eviction works, extended to admission.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Optional

import numpy as np

DEFAULT_WIDTH = 1024
DEFAULT_DEPTH = 4
DEFAULT_AGE_PERIOD_S = 180.0


class FrequencySketch:
    """Count-min sketch with conservative update and time-driven aging.

    ``touch(key, now)`` records one access; ``estimate(key)`` returns the
    (over-)estimate of the key's access count since roughly the last aging
    window. Aging halves every counter each ``age_period_s`` simulated
    seconds — callers pass ``now`` from their sim clock (the concurrent
    engine passes session clocks, which only execute at the global-minimum
    time, so touches arrive in nondecreasing order) or construct with a
    ``clock`` callable. All table operations are vectorized numpy; hashing
    is blake2b so estimates are deterministic across runs and machines.
    """

    def __init__(self, width: int = DEFAULT_WIDTH, depth: int = DEFAULT_DEPTH,
                 age_period_s: float = DEFAULT_AGE_PERIOD_S, clock=None):
        assert width > 0 and depth > 0
        self.width = width
        self.depth = depth
        self.age_period_s = age_period_s
        self._clock = clock
        self.table = np.zeros((depth, width), dtype=np.uint32)
        self._rows = np.arange(depth)
        self._idx_memo: Dict[str, np.ndarray] = {}
        self._last_age = 0.0
        self.touches = 0
        self.ages = 0

    def _indices(self, key: str) -> np.ndarray:
        idx = self._idx_memo.get(key)
        if idx is None:
            h = hashlib.blake2b(key.encode(),
                                digest_size=8 * self.depth).digest()
            idx = (np.frombuffer(h, dtype=np.uint64)
                   % np.uint64(self.width)).astype(np.int64)
            self._idx_memo[key] = idx
        return idx

    def _maybe_age(self, now: Optional[float]) -> None:
        if now is None:
            now = self._clock() if self._clock is not None else None
        if now is None or self.age_period_s <= 0:
            return
        while now - self._last_age >= self.age_period_s:
            self.age()
            self._last_age += self.age_period_s

    def age(self) -> None:
        """TinyLFU reset: halve every counter (vectorized)."""
        self.table >>= 1
        self.ages += 1

    def touch(self, key: str, now: Optional[float] = None) -> None:
        """Record one access. Conservative update: only the minimum cells
        increment, which tightens estimates without losing the count-min
        overestimate guarantee."""
        self._maybe_age(now)
        idx = self._indices(key)
        cells = self.table[self._rows, idx]
        lo = cells.min()
        self.table[self._rows, idx] = np.where(cells == lo, cells + 1, cells)
        self.touches += 1

    def estimate(self, key: str) -> int:
        return int(self.table[self._rows, self._indices(key)].min())


def entries_json(entries) -> str:
    """Cache contents serialized for the admission prompt (the same shape
    ``DataCache.contents_json`` uses, minus values)."""
    return json.dumps({
        k: {"last_access": e.last_access, "access_count": e.access_count}
        for k, e in sorted(entries.items())
    }, sort_keys=True)


# ---------------------------------------------------------------------------
# Admission policies (mirror of repro.core.policies: programmatic + prompt)
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Decides whether a loaded key may evict ``victim`` or must bypass.

    Called only when the owning cache is full (an insert into free capacity
    is always admitted). ``admit`` returning ``False`` means bypass: the
    value streams through to the caller without installing or evicting.
    """

    name = "base"

    def admit(self, key: str, victim: str, sketch: Optional[FrequencySketch],
              entries) -> bool:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


class AdmitAll(AdmissionPolicy):
    """The pre-admission behavior: every load installs (and evicts)."""

    name = "always"

    def admit(self, key, victim, sketch, entries):
        return True

    def describe(self):
        return ("Always-admit: every key loaded from the database is "
                "installed into the cache; when full, evict the update "
                "policy's victim to make room. Never bypass.")


class TinyLFU(AdmissionPolicy):
    """Frequency-based admission (TinyLFU): the candidate must be more
    popular than the entry it would evict."""

    name = "tinylfu"

    def admit(self, key, victim, sketch, entries):
        if sketch is None:
            return True
        return sketch.estimate(key) > sketch.estimate(victim)

    def describe(self):
        return ("TinyLFU admission: when the cache is full, compare the "
                "candidate key's estimated access frequency against the "
                "eviction victim's. ADMIT (evict the victim, install the "
                "candidate) only if the candidate's frequency is STRICTLY "
                "HIGHER; otherwise BYPASS the cache — pass the loaded data "
                "through to the caller without caching it, leaving every "
                "resident entry untouched.")


class Doorkeeper(AdmissionPolicy):
    """Second-chance admission: one-shot keys never evict a resident; a key
    is admitted once it has been seen at least twice in the aging window."""

    name = "doorkeeper"

    def admit(self, key, victim, sketch, entries):
        if sketch is None:
            return True
        return sketch.estimate(key) >= 2

    def describe(self):
        return ("Doorkeeper admission: when the cache is full, ADMIT the "
                "candidate (evicting the victim) only if it has been seen "
                "at least twice within the current aging window (estimated "
                "frequency of 2 or more); a first-time key must BYPASS the "
                "cache — its data passes through to the caller and no "
                "resident is evicted.")


class LLMAdmission(AdmissionPolicy):
    """GPT-driven admission: the base policy's ``describe()`` text plus the
    sketch estimates are rendered into a prompt and the LLM answers
    admit/bypass in natural language (the paper's prompted-eviction twist
    applied to admission). Graded against the programmatic base decision;
    unparseable completions fall back to it.

    Like the paper's prompted *update*, the decision runs off the critical
    path (post-round bookkeeping — Table III shows ~0 latency delta), so it
    costs tokens but not user-perceived latency: each call accumulates
    ``prompt_tokens``/``completion_tokens``, which the single-session
    controllers fold into the task trace and the engine surfaces as
    ``admission_tokens``.
    """

    def __init__(self, base: AdmissionPolicy, llm, few_shot: bool = True):
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.name = f"llm-{base.name}"
        self.llm_total = 0
        self.llm_correct = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0

    def describe(self):
        return self.base.describe()

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def admit(self, key, victim, sketch, entries):
        from repro.core.prompts import admission_decision_prompt, \
            parse_json_tail
        kf = sketch.estimate(key) if sketch is not None else 0
        vf = sketch.estimate(victim) if sketch is not None else 0
        prompt = admission_decision_prompt(
            self.base.describe(), key, victim, kf, vf,
            entries_json(entries), self.few_shot)
        completion = self.llm.complete(prompt)
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(completion) // 4
        expected = self.base.admit(key, victim, sketch, entries)
        try:
            raw = parse_json_tail(completion)
            decision = raw.get("decision") if isinstance(raw, dict) else None
        except ValueError:
            decision = None
        if decision not in ("admit", "bypass"):
            decision = "admit" if expected else "bypass"
        got = decision == "admit"
        self.llm_total += 1
        self.llm_correct += int(got == expected)
        return got


ADMISSIONS = {"always": AdmitAll, "tinylfu": TinyLFU,
              "doorkeeper": Doorkeeper}


def make_admission(name: str, *, impl: str = "python", llm=None,
                   few_shot: bool = True, **kw) -> AdmissionPolicy:
    """Build an admission policy; ``impl="llm"`` wraps it in the GPT-driven
    path (requires an ``llm`` backend with ``complete(prompt) -> str``)."""
    base = ADMISSIONS[name](**kw)
    if impl == "llm":
        assert llm is not None, "LLM-driven admission needs an llm backend"
        return LLMAdmission(base, llm, few_shot=few_shot)
    return base

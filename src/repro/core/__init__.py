"""LLM-dCache core: the paper's contribution.

Cache mechanism (``cache``), eviction policies with natural-language
descriptions (``policies``), cross-session admission with a shared
frequency sketch (``admission``), cache ops as callable tools (``tools``),
programmatic vs GPT-driven controllers (``controller``), prompt templates
(``prompts``), multi-pod localized caching (``distributed_cache``), and
open-loop session-arrival processes (``traffic``).
"""
from repro.core.admission import (  # noqa: F401
    ADMISSIONS,
    AdmissionPolicy,
    AdmitAll,
    Doorkeeper,
    FrequencySketch,
    LLMAdmission,
    TinyLFU,
    make_admission,
)
from repro.core.cache import CacheEntry, CacheStats, DataCache  # noqa: F401
from repro.core.controller import (  # noqa: F401
    LLMController,
    ProgrammaticController,
    ReadPlan,
    make_controller,
)
from repro.core.distributed_cache import PodLocalCacheRouter  # noqa: F401
from repro.core.policies import POLICIES, Policy, make_policy  # noqa: F401
from repro.core.tools import (  # noqa: F401
    ToolRegistry,
    ToolResult,
    ToolSpec,
    make_cache_tools,
)
from repro.core.traffic import (  # noqa: F401
    ArrivalProcess,
    ClosedLoopTraffic,
    DiurnalTraffic,
    MMPPTraffic,
    PoissonTraffic,
    SessionArrival,
    TrafficStats,
    find_knee,
    make_traffic,
    slo_attainment,
)

"""Session→pod affinity + consumer-side locality cost model (ISSUE 5).

The paper's headline is *localized* data caching: a read served from the pod
a session lives on is cheap, a read served across pods is not. Until now the
simulator charged every pod-local read the same, so cross-pod replication
only ever won through queueing relief. This module makes locality real:

* an :class:`AffinityPolicy` assigns every session a **home pod** (sticky
  hashing, round-robin, least-loaded, or per-task migration), and
* a :class:`LocalityModel` charges a ``remote_read_penalty`` whenever the
  pod *serving* a value is not the consuming session's home pod: the read
  pays an extra cross-pod **hop** of ``(penalty - 1) x cache_read(size)``
  seconds, optionally serialized on the home pod's ingress link
  (``link_queue=True`` — concurrent remote reads into one pod queue FCFS on
  its bandwidth, exactly like demand loads queue on the owner's).

Degeneracy contract (locked by tests/test_locality.py): with
``penalty == 1.0`` the hop is zero seconds, the link never accumulates a
busy window, and every engine trace is bit-identical to the affinity-free
engine — the model then only *classifies* reads (local vs remote), which is
what the differential harness and the partition invariant check.

The model also keeps the replicator's consumer evidence: every penalized
remote read increments ``remote_demand[key][home_pod]``, so promotion can
target the pods whose sessions are actually paying hops (placement
arbitrage gains a locality term — see
:meth:`PodLocalCacheRouter.replicate`). The map is drained each
replication epoch alongside ``demand_counts``; when no replicator is
wired, the engine sets ``demand_window_s`` and the map self-drains on
that simulated-time window instead.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional


# ---------------------------------------------------------------------------
# Affinity: which pod is a session's home?
# ---------------------------------------------------------------------------

class AffinityPolicy:
    """Maps ``(session id, task index)`` to a home-pod index.

    Policies are deterministic in their constructor arguments; ``home`` is
    called at every task boundary, so a policy may migrate a session over
    its task stream (see :class:`MigratingAffinity`).
    """

    name = "base"

    def __init__(self, n_pods: int):
        assert n_pods >= 1
        self.n_pods = n_pods

    def home(self, sid: int, task_index: int) -> int:
        raise NotImplementedError


class StickyAffinity(AffinityPolicy):
    """Hash the session id onto a pod once; the session never moves. The
    blake2 spread is uniform but not round-robin — neighbouring sessions
    can share a home, like real sticky load-balancing."""

    name = "sticky"

    def home(self, sid, task_index):
        h = hashlib.blake2b(f"sess{sid}".encode(), digest_size=8).digest()
        return int.from_bytes(h, "big") % self.n_pods


class RoundRobinAffinity(AffinityPolicy):
    """Session ``sid`` homes on ``sid % n_pods`` — perfectly even by
    construction (the scheduler-assigns-in-order model)."""

    name = "round_robin"

    def home(self, sid, task_index):
        return sid % self.n_pods


class LoadBalancedAffinity(AffinityPolicy):
    """Assign each session, at first sight, to the pod currently homing the
    fewest sessions (ties break by pod index). With sessions created in id
    order this equals round-robin; it diverges once session populations do
    (e.g. a later wave of sessions joining mid-episode)."""

    name = "load_balanced"

    def __init__(self, n_pods: int):
        super().__init__(n_pods)
        self._counts = [0] * n_pods
        self._assigned: Dict[int, int] = {}

    def home(self, sid, task_index):
        pod = self._assigned.get(sid)
        if pod is None:
            pod = min(range(self.n_pods), key=lambda p: (self._counts[p], p))
            self._counts[pod] += 1
            self._assigned[sid] = pod
        return pod


class MigratingAffinity(AffinityPolicy):
    """The session's home drifts one pod every ``period`` tasks (rebalancer
    moving sessions mid-episode): a resident hot set built for one home
    turns remote after a migration — the adversarial case for placement."""

    name = "migrating"

    def __init__(self, n_pods: int, period: int = 5):
        super().__init__(n_pods)
        assert period >= 1
        self.period = period

    def home(self, sid, task_index):
        return (sid + task_index // self.period) % self.n_pods


AFFINITIES = {"sticky": StickyAffinity, "round_robin": RoundRobinAffinity,
              "load_balanced": LoadBalancedAffinity,
              "migrating": MigratingAffinity}


def make_affinity(name: str, n_pods: int, **kw) -> AffinityPolicy:
    return AFFINITIES[name](n_pods, **kw)


# ---------------------------------------------------------------------------
# Locality cost model: the cross-pod read penalty
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LocalityStats:
    """Consumer-side read classification. Invariant (tests): with affinity
    enabled, ``local_reads + remote_reads`` equals the router's ``routed``
    logical-access count — every consumed value is served from exactly one
    pod, home or not."""
    local_reads: int = 0
    remote_reads: int = 0
    remote_hop_s: float = 0.0     # cross-pod transfer seconds charged
    link_stall_s: float = 0.0     # FCFS waits on home-pod ingress links

    @property
    def remote_share(self) -> float:
        total = self.local_reads + self.remote_reads
        return self.remote_reads / total if total else 0.0


class LocalityModel:
    """Charges the consumer-side cost of every value a session consumes.

    ``charge`` is called once per logical access, *after* the serving path's
    base latency (cache read / DB dwell / join wait) has been charged, with
    the session clock's post-advance time. It classifies the read, records
    the replicator's consumer evidence, and returns the extra seconds the
    session must additionally wait for the cross-pod hop (0.0 when local,
    and exactly 0.0 at ``penalty == 1.0`` — the degeneracy contract).
    """

    def __init__(self, latency, penalty: float = 1.0,
                 link_queue: bool = False):
        assert penalty >= 1.0, penalty
        self.latency = latency
        self.penalty = penalty
        self.link_queue = link_queue
        self.stats = LocalityStats()
        # per-home-pod ingress link busy window (only with link_queue)
        self._link_busy: Dict[str, float] = {}
        # key -> {home pod -> remote reads since the last drain}. Only
        # populated under a penalty (it is placement evidence — at 1x a
        # consumer-pod copy buys nothing, and nothing reads the map).
        # Drained by the HotKeyReplicator's epoch when one is wired;
        # otherwise the engine sets ``demand_window_s`` and the map
        # self-drains on that sim-time window, so prompt evidence (LLM
        # admission, cache_admit) stays a recent-demand signal instead of
        # an all-time count.
        self.remote_demand: Dict[str, Dict[str, int]] = {}
        self.demand_window_s = 0.0      # 0 = drained externally
        self._last_drain = 0.0

    def hop_s(self, size_mb: float) -> float:
        """Cross-pod transfer time for one value: the read pays ``penalty``
        times the pod-local read, i.e. an extra ``(penalty - 1) x
        cache_read(size)`` on top of the base latency already charged."""
        return (self.penalty - 1.0) * self.latency.cache_read(size_mb)

    def charge(self, key: str, serving_pod: str, home_pod: Optional[str],
               size_mb: float, now: float) -> float:
        """Classify + charge one consumed value; returns extra seconds."""
        st = self.stats
        if home_pod is None or serving_pod == home_pod:
            st.local_reads += 1
            return 0.0
        st.remote_reads += 1
        hop = self.hop_s(size_mb)
        if hop <= 0.0:
            return 0.0              # penalty 1x: classification only
        if self.demand_window_s > 0.0 and \
                now - self._last_drain >= self.demand_window_s:
            # no replicator is draining the consumer evidence: window it
            # on sim time so it stays a recent-demand signal
            self.remote_demand.clear()
            while now - self._last_drain >= self.demand_window_s:
                self._last_drain += self.demand_window_s
        d = self.remote_demand.get(key)
        if d is None:
            d = self.remote_demand[key] = {}
        d[home_pod] = d.get(home_pod, 0) + 1
        wait = 0.0
        if self.link_queue:
            # the value crosses into the consumer's home pod over its
            # ingress link. Transfers are serialized in the scheduler's
            # global EXECUTION order — which equals the order the reads
            # were issued (sessions execute at the global-minimum event
            # time) — while ``now`` is the value-READY time (the caller's
            # post-base-latency clock), so a transfer never starts before
            # its value exists nor before the link frees. Ready times are
            # not globally monotone across sessions (a read issued later
            # can be ready earlier), so this is request-order FCFS, not
            # ready-time FCFS: a transfer can wait on a predecessor whose
            # value became ready after its own, by at most one base
            # read/dwell. Deterministic either way.
            busy = self._link_busy.get(home_pod, 0.0)
            start = max(now, busy)
            wait = start - now
            self._link_busy[home_pod] = start + hop
            st.link_stall_s += wait
        st.remote_hop_s += hop
        return wait + hop

"""Cache-decision controllers: programmatic (the paper's upper bound) and
GPT-driven via prompting (the paper's contribution, Table III rows 2-4).

The two decision points are factored exactly as in the paper:
  * read  — read_cache vs load_db per required key;
  * update — new cache state after this round's loads (policy-by-prompt).

Either side can independently be "python" or "llm", reproducing the four
Table III configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.cache import DataCache
from repro.core.policies import Policy
from repro.core.prompts import (
    parse_json_tail,
    read_decision_prompt,
    update_decision_prompt,
)


@dataclasses.dataclass
class ReadPlan:
    """Per-key tool choice ("read_cache" | "load_db").

    A ReadPlan "lands" at plan time — before the planning LLM round is
    charged (see ``AgentRunner.iter_task``). Schedulers subscribe to that
    moment via the runner's ``on_plan`` hook and may start the
    :meth:`load_keys` asynchronously, overlapping DB service with the
    planning round (the concurrent engine's prefetcher does exactly this).
    """
    choices: Dict[str, str]
    prompt_tokens: int = 0
    completion_tokens: int = 0

    def load_keys(self) -> List[str]:
        """Keys this plan will acquire via ``load_db``, in plan order —
        the prefetcher's work list."""
        return [k for k, c in self.choices.items() if c == "load_db"]


class ProgrammaticController:
    """Direct Python implementation (Table III row 1 / 'upper bound')."""

    kind = "python"

    def __init__(self, cache: DataCache, policy: Policy):
        self.cache = cache
        self.policy = policy

    # -- read ---------------------------------------------------------------
    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: bool = False) -> ReadPlan:
        return ReadPlan({k: ("read_cache" if k in self.cache else "load_db")
                         for k in required_keys})

    # -- update -------------------------------------------------------------
    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> None:
        for k in loads:
            if k in self.cache:
                continue
            victim = None
            if len(self.cache) >= self.cache.capacity:
                victim = self.policy.victim(self.cache.entries())
            v = loader(k)
            self.cache.put(k, v, size_of(v), victim=victim)


class LLMController:
    """GPT-driven cache operations: both decisions made by prompting an LLM.

    ``read_impl`` / ``update_impl`` select "llm" or "python" per decision
    point (the Table III grid). The LLM is any backend with
    ``complete(prompt) -> str`` (SimLLM offline, JaxLLM for the real served
    model).
    """

    kind = "llm"

    def __init__(self, cache: DataCache, policy: Policy, llm,
                 read_impl: str = "llm", update_impl: str = "llm",
                 few_shot: bool = True):
        self.cache = cache
        self.policy = policy
        self.llm = llm
        self.read_impl = read_impl
        self.update_impl = update_impl
        self.few_shot = few_shot
        self._fallback = ProgrammaticController(cache, policy)

    # -- read ---------------------------------------------------------------
    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: Optional[bool] = None) -> ReadPlan:
        if self.read_impl == "python" or not required_keys:
            return self._fallback.plan_reads(query, required_keys)
        fs = self.few_shot if few_shot is None else few_shot
        prompt = read_decision_prompt(query, required_keys,
                                      self.cache.contents_json(), fs)
        completion = self.llm.complete(prompt)
        stats = self.cache.stats
        try:
            raw = parse_json_tail(completion)
        except ValueError:
            raw = {}
        choices: Dict[str, str] = {}
        for k in required_keys:
            c = raw.get(k) if isinstance(raw, dict) else None
            if c not in ("read_cache", "load_db"):
                c = "load_db"  # malformed decision -> safe slow path
            correct = (c == "read_cache") == (k in self.cache)
            stats.llm_total_decisions += 1
            stats.llm_correct_decisions += int(correct)
            choices[k] = c
        return ReadPlan(choices,
                        prompt_tokens=len(prompt) // 4,
                        completion_tokens=len(completion) // 4)

    # -- update -------------------------------------------------------------
    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> Dict[str, int]:
        if self.update_impl == "python":
            self._fallback.update(loads, loader, size_of)
            return {"prompt_tokens": 0, "completion_tokens": 0}
        new_loads = [k for k in loads if k not in self.cache]
        if not new_loads:
            # still refresh recency metadata for reused keys
            return {"prompt_tokens": 0, "completion_tokens": 0}
        prompt = update_decision_prompt(
            self.policy.describe(), new_loads, self.cache.contents_json(),
            self.cache.capacity, self.few_shot)
        completion = self.llm.complete(prompt)
        stats = self.cache.stats
        try:
            new_state = parse_json_tail(completion)
            assert isinstance(new_state, list)
            new_state = [str(k) for k in new_state]
        except (ValueError, AssertionError):
            new_state = None
        # grade the LLM's update against the programmatic policy
        expected = self._expected_state(new_loads)
        stats.llm_total_decisions += 1
        stats.llm_correct_decisions += int(
            new_state is not None and set(new_state) == set(expected))
        if new_state is None:
            new_state = expected  # unparseable -> programmatic fallback
        self.cache.apply_state(new_state, loader, size_of)
        return {"prompt_tokens": len(prompt) // 4,
                "completion_tokens": len(completion) // 4}

    def _expected_state(self, new_loads: Sequence[str]) -> List[str]:
        keys = list(self.cache.keys())
        entries = dict(self.cache.entries())
        for k in new_loads:
            if k in keys:
                continue
            if len(keys) >= self.cache.capacity:
                victim = self.policy.victim(
                    {kk: entries[kk] for kk in keys if kk in entries})
                keys.remove(victim)
            keys.append(k)
        return keys


def make_controller(cache: DataCache, policy: Policy, *, llm=None,
                    read_impl: str = "python", update_impl: str = "python",
                    few_shot: bool = True):
    if read_impl == "python" and update_impl == "python":
        return ProgrammaticController(cache, policy)
    assert llm is not None, "LLM-driven cache ops need an llm backend"
    return LLMController(cache, policy, llm, read_impl=read_impl,
                         update_impl=update_impl, few_shot=few_shot)

"""Cache-decision controllers: programmatic (the paper's upper bound) and
GPT-driven via prompting (the paper's contribution, Table III rows 2-4).

The two decision points are factored exactly as in the paper:
  * read  — read_cache vs load_db per required key;
  * update — new cache state after this round's loads (policy-by-prompt).

Either side can independently be "python" or "llm", reproducing the four
Table III configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.admission import AdmissionPolicy, FrequencySketch
from repro.core.cache import DataCache
from repro.core.endpoints import LLMUnavailableError
from repro.core.policies import Policy
from repro.core.prompts import (
    LLMParseError,
    parse_json_tail,
    read_decision_prompt,
    update_decision_prompt,
)


def _admission_tokens(admission, since=(0, 0)):
    """Prompt/completion tokens an LLM-driven admission policy has consumed
    beyond ``since`` (zeros for programmatic policies — they have no token
    counters). Lets the controllers fold GPT-admission cost into the same
    update-round accounting the runner already charges (off the critical
    path, like the paper's prompted update)."""
    pt = getattr(admission, "prompt_tokens", 0) - since[0]
    ct = getattr(admission, "completion_tokens", 0) - since[1]
    return pt, ct


def admit_loads(cache: DataCache, policy: Policy,
                admission: Optional[AdmissionPolicy],
                sketch: Optional[FrequencySketch],
                loads: Sequence[str],
                sizer: Optional[Callable[[str], int]] = None) -> List[str]:
    """Admission pre-filter for the LLM update path: drop this round's
    loads that must *bypass* (no eviction; the caller already holds the
    loaded value) before the update prompt is built, counting them in
    ``cache.stats.bypassed``. Victims are estimated against the pre-round
    cache snapshot — the same snapshot the LLM sees in its prompt. With no
    admission policy this reduces to the pre-admission new-loads filter,
    so default behavior is bit-identical to pre-admission code. ``sizer``
    (optional) supplies the candidate's byte size for cost-aware policies."""
    if admission is None:
        return [k for k in loads if k not in cache]
    kept: List[str] = []
    stats = cache.stats
    occupancy = len(cache)
    for k in loads:
        if k in cache or k in kept:
            continue
        if occupancy + len(kept) >= cache.capacity:
            victim = policy.victim(cache.entries())
            if not admission.admit(k, victim, sketch, cache.entries(),
                                   size_bytes=sizer(k) if sizer else None):
                stats.bypassed += 1
                continue
            # admitted/bypassed count only consulted (full-cache)
            # decisions, matching ProgrammaticController and the router
            stats.admitted += 1
        kept.append(k)
    return kept


@dataclasses.dataclass
class ReadPlan:
    """Per-key tool choice ("read_cache" | "load_db").

    A ReadPlan "lands" at plan time — before the planning LLM round is
    charged (see ``AgentRunner.iter_task``). Schedulers subscribe to that
    moment via the runner's ``on_plan`` hook and may start the
    :meth:`load_keys` asynchronously, overlapping DB service with the
    planning round (the concurrent engine's prefetcher does exactly this).
    """
    choices: Dict[str, str]
    prompt_tokens: int = 0
    completion_tokens: int = 0

    def load_keys(self) -> List[str]:
        """Keys this plan will acquire via ``load_db``, in plan order —
        the prefetcher's work list."""
        return [k for k, c in self.choices.items() if c == "load_db"]


class ProgrammaticController:
    """Direct Python implementation (Table III row 1 / 'upper bound').

    ``admission``/``sketch`` (both optional) add the cross-session admission
    gate: a full cache consults the policy before evicting for a new load;
    rejected keys bypass (no eviction, the value streams to the caller).
    Defaults keep the pre-admission behavior bit-identical.
    """

    kind = "python"

    def __init__(self, cache: DataCache, policy: Policy,
                 admission: Optional[AdmissionPolicy] = None,
                 sketch: Optional[FrequencySketch] = None):
        self.cache = cache
        self.policy = policy
        self.admission = admission
        self.sketch = sketch

    # -- read ---------------------------------------------------------------
    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: bool = False) -> ReadPlan:
        if self.sketch is not None:
            for k in required_keys:      # every planned access is evidence
                self.sketch.touch(k)
        return ReadPlan({k: ("read_cache" if k in self.cache else "load_db")
                         for k in required_keys})

    # -- update -------------------------------------------------------------
    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> Dict[str, int]:
        bypassed = 0
        tok0 = _admission_tokens(self.admission)
        for k in loads:
            if k in self.cache:
                continue
            victim = None
            v = None
            if len(self.cache) >= self.cache.capacity:
                victim = self.policy.victim(self.cache.entries())
                if self.admission is not None:
                    # loader is a latency-free peek; reading the value up
                    # front (for its byte size, which cost-aware admission
                    # weighs) does not change any clock or RNG stream
                    v = loader(k)
                    if not self.admission.admit(k, victim, self.sketch,
                                                self.cache.entries(),
                                                size_bytes=size_of(v)):
                        self.cache.stats.bypassed += 1
                        bypassed += 1
                        continue
                    self.cache.stats.admitted += 1
            if v is None:
                v = loader(k)
            self.cache.put(k, v, size_of(v), victim=victim)
        pt, ct = _admission_tokens(self.admission, since=tok0)
        return {"prompt_tokens": pt, "completion_tokens": ct,
                "bypassed": bypassed}


class LLMController:
    """GPT-driven cache operations: both decisions made by prompting an LLM.

    ``read_impl`` / ``update_impl`` select "llm" or "python" per decision
    point (the Table III grid). The LLM is any backend with
    ``complete(prompt) -> str`` (SimLLM offline, JaxLLM for the real served
    model).
    """

    kind = "llm"

    def __init__(self, cache: DataCache, policy: Policy, llm,
                 read_impl: str = "llm", update_impl: str = "llm",
                 few_shot: bool = True,
                 admission: Optional[AdmissionPolicy] = None,
                 sketch: Optional[FrequencySketch] = None):
        self.cache = cache
        self.policy = policy
        self.llm = llm
        self.read_impl = read_impl
        self.update_impl = update_impl
        self.few_shot = few_shot
        self.admission = admission
        self.sketch = sketch
        self._fallback = ProgrammaticController(cache, policy,
                                                admission=admission,
                                                sketch=sketch)
        # resilience fallbacks (ungraded -- there is no LLM answer to
        # grade): unparseable prompt/completion vs endpoint pool down
        self.parse_fallbacks = 0
        self.degraded = 0

    def _programmatic_plan(self, required_keys: Sequence[str],
                           prompt_tokens: int = 0,
                           completion_tokens: int = 0) -> ReadPlan:
        # inline twin of ProgrammaticController.plan_reads WITHOUT the
        # sketch touch (plan_reads already touched these keys before the
        # LLM call failed)
        return ReadPlan({k: ("read_cache" if k in self.cache else "load_db")
                         for k in required_keys},
                        prompt_tokens=prompt_tokens,
                        completion_tokens=completion_tokens)

    # -- read ---------------------------------------------------------------
    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: Optional[bool] = None) -> ReadPlan:
        if self.read_impl == "python" or not required_keys:
            return self._fallback.plan_reads(query, required_keys)
        if self.sketch is not None:
            for k in required_keys:      # every planned access is evidence
                self.sketch.touch(k)
        fs = self.few_shot if few_shot is None else few_shot
        prompt = read_decision_prompt(query, required_keys,
                                      self.cache.contents_json(), fs)
        try:
            completion = self.llm.complete(prompt)
        except LLMUnavailableError:
            # endpoint pool down: degrade to the programmatic plan (the
            # router already charged the wasted retry tokens)
            self.degraded += 1
            return self._programmatic_plan(required_keys)
        except LLMParseError:
            self.parse_fallbacks += 1
            return self._programmatic_plan(required_keys,
                                           prompt_tokens=len(prompt) // 4)
        stats = self.cache.stats
        try:
            raw = parse_json_tail(completion)
        except LLMParseError:
            # garbled completion: every key falls back programmatically,
            # ungraded (there is no per-key decision to grade)
            self.parse_fallbacks += 1
            return self._programmatic_plan(
                required_keys, prompt_tokens=len(prompt) // 4,
                completion_tokens=len(completion) // 4)
        choices: Dict[str, str] = {}
        for k in required_keys:
            c = raw.get(k) if isinstance(raw, dict) else None
            if c not in ("read_cache", "load_db"):
                c = "load_db"  # malformed decision -> safe slow path
            correct = (c == "read_cache") == (k in self.cache)
            stats.llm_total_decisions += 1
            stats.llm_correct_decisions += int(correct)
            choices[k] = c
        return ReadPlan(choices,
                        prompt_tokens=len(prompt) // 4,
                        completion_tokens=len(completion) // 4)

    # -- update -------------------------------------------------------------
    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> Dict[str, int]:
        if self.update_impl == "python":
            return self._fallback.update(loads, loader, size_of)
        before = self.cache.stats.bypassed
        tok0 = _admission_tokens(self.admission)
        new_loads = admit_loads(self.cache, self.policy, self.admission,
                                self.sketch, loads,
                                sizer=lambda k: size_of(loader(k)))
        bypassed = self.cache.stats.bypassed - before
        adm_pt, adm_ct = _admission_tokens(self.admission, since=tok0)
        if not new_loads:
            # still refresh recency metadata for reused keys
            return {"prompt_tokens": adm_pt, "completion_tokens": adm_ct,
                    "bypassed": bypassed}
        prompt = update_decision_prompt(
            self.policy.describe(), new_loads, self.cache.contents_json(),
            self.cache.capacity, self.few_shot)
        try:
            completion = self.llm.complete(prompt)
        except (LLMParseError, LLMUnavailableError) as exc:
            if isinstance(exc, LLMUnavailableError):
                self.degraded += 1
                pt = 0  # nothing served; the router billed the retries
            else:
                self.parse_fallbacks += 1
                pt = len(prompt) // 4
            self.cache.apply_state(self._expected_state(new_loads),
                                   loader, size_of)
            return {"prompt_tokens": pt + adm_pt,
                    "completion_tokens": adm_ct, "bypassed": bypassed}
        stats = self.cache.stats
        try:
            new_state = parse_json_tail(completion)
            assert isinstance(new_state, list)
            new_state = [str(k) for k in new_state]
        except (ValueError, AssertionError):
            new_state = None
        expected = self._expected_state(new_loads)
        if new_state is None:
            # unparseable completion -> programmatic fallback, ungraded
            self.parse_fallbacks += 1
            new_state = expected
        else:
            # grade the LLM's update against the programmatic policy
            stats.llm_total_decisions += 1
            stats.llm_correct_decisions += int(
                set(new_state) == set(expected))
        self.cache.apply_state(new_state, loader, size_of)
        return {"prompt_tokens": len(prompt) // 4 + adm_pt,
                "completion_tokens": len(completion) // 4 + adm_ct,
                "bypassed": bypassed}

    def _expected_state(self, new_loads: Sequence[str]) -> List[str]:
        keys = list(self.cache.keys())
        entries = dict(self.cache.entries())
        for k in new_loads:
            if k in keys:
                continue
            if len(keys) >= self.cache.capacity:
                victim = self.policy.victim(
                    {kk: entries[kk] for kk in keys if kk in entries})
                keys.remove(victim)
            keys.append(k)
        return keys


def make_controller(cache: DataCache, policy: Policy, *, llm=None,
                    read_impl: str = "python", update_impl: str = "python",
                    few_shot: bool = True, admission=None, sketch=None):
    if read_impl == "python" and update_impl == "python":
        return ProgrammaticController(cache, policy, admission=admission,
                                      sketch=sketch)
    assert llm is not None, "LLM-driven cache ops need an llm backend"
    return LLMController(cache, policy, llm, read_impl=read_impl,
                         update_impl=update_impl, few_shot=few_shot,
                         admission=admission, sketch=sketch)

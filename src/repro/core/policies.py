"""Cache update (eviction) policies.

The paper's key twist is that the *policy is described to the LLM in natural
language* and the LLM executes it; each policy therefore carries both a
programmatic ``victim`` implementation (the paper's "upper bound", Table III)
and a ``describe()`` prompt text (the GPT-driven path). LRU is primary; LFU,
RR, FIFO are the Table II ablations; Belady is a beyond-paper oracle bound.
"""
from __future__ import annotations

import random
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence

from repro.core.cache import CacheEntry


class Policy:
    name = "base"

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


class LRU(Policy):
    name = "lru"

    def victim(self, entries):
        return min(entries.values(), key=lambda e: e.last_access).key

    def describe(self):
        return ("Least Recently Used (LRU): when the cache is full, evict the "
                "entry whose last access is the OLDEST. Each entry below lists "
                "its last_access timestamp; remove the one with the smallest "
                "last_access, then insert the new key.")


class LFU(Policy):
    name = "lfu"

    def victim(self, entries):
        return min(entries.values(),
                   key=lambda e: (e.access_count, e.last_access)).key

    def describe(self):
        return ("Least Frequently Used (LFU): when the cache is full, evict "
                "the entry with the SMALLEST access_count (break ties by "
                "oldest last_access), then insert the new key.")


class FIFO(Policy):
    name = "fifo"

    def victim(self, entries):
        return min(entries.values(), key=lambda e: e.insert_order).key

    def describe(self):
        return ("First In First Out (FIFO): when the cache is full, evict the "
                "entry that was INSERTED first (smallest insert_order), then "
                "insert the new key.")


class RR(Policy):
    name = "rr"

    def __init__(self, seed: int = 0):
        self._rng = random.Random(seed)

    def victim(self, entries):
        return self._rng.choice(sorted(entries.keys()))

    def describe(self):
        return ("Random Replacement (RR): when the cache is full, evict a "
                "uniformly random entry, then insert the new key.")


class Belady(Policy):
    """Oracle (beyond-paper upper bound): evicts the entry whose next use is
    farthest in the future. Requires the future key sequence.

    Assigning :attr:`future` indexes it once into per-key sorted position
    lists; each eviction then bisects against :attr:`cursor` — O(cache ×
    log future) per victim instead of the old O(cache × future) linear
    rescan of the remaining request stream. Advance ``cursor`` as requests
    are consumed rather than re-assigning a sliced ``future``.
    """
    name = "belady"

    def __init__(self, future: Optional[Sequence[str]] = None):
        self.cursor = 0
        self.future = list(future or [])   # property: builds the index

    @property
    def future(self) -> List[str]:
        return self._future

    @future.setter
    def future(self, seq: Sequence[str]) -> None:
        self._future = list(seq)
        positions: Dict[str, List[int]] = {}
        for i, k in enumerate(self._future):
            positions.setdefault(k, []).append(i)
        self._positions = positions
        self.cursor = 0

    def victim(self, entries):
        def next_use(key: str) -> int:
            pos = self._positions.get(key)
            if pos:
                j = bisect_left(pos, self.cursor)
                if j < len(pos):
                    return pos[j]
            return 1 << 30
        return max(entries.values(), key=lambda e: next_use(e.key)).key

    def describe(self):
        return ("Belady/MIN oracle: evict the entry whose next use lies "
                "farthest in the future (the provided upcoming-request list "
                "tells you future accesses).")


POLICIES = {"lru": LRU, "lfu": LFU, "fifo": FIFO, "rr": RR, "belady": Belady}


def make_policy(name: str, **kw) -> Policy:
    return POLICIES[name](**kw)

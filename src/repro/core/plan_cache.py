"""Plan-cache tier (ISSUE 10): cache the LLM planning round itself.

The engine caches *data* aggressively, yet every task still pays a full
GPT planning round — the single largest sim-time item — even when the
same task template over the same context keys was planned moments ago by
another session. This module adds a shared, capacity-bounded **plan
cache** with request-level semantics (the related repos' model:
``llm-cache``'s hashed request→response store, ``mnimi``'s request-level
caching + retry-correctness warnings):

* **key model** — ``(task_template_id, context_digest)``. The template id
  is the task's step-kind chain (its "shape"); the context digest hashes
  the sorted required keys *with their current datastore versions* (via
  :class:`~repro.agent.concurrency.CoherenceRuntime` when a mutable data
  plane is wired, version 0 otherwise) *and their current cache
  residency* (a read plan is a pure function of keys × residency × eps
  noise, so residency IS request context — without it a cold-start
  all-``load_db`` plan would replay redundant DB loads all episode). A
  write to any covered key bumps its version, so every digest that
  included the key moves and the old plan becomes unreachable — **no
  stale plan is ever served**, by construction, under any coherence
  policy. Under an invalidating policy the write additionally evicts the
  dead entries eagerly (counted as ``invalidations``);
* **request-level semantics** — a hit serves the stored
  :class:`~repro.core.controller.ReadPlan` verbatim and the planning LLM
  round is skipped entirely: no endpoint latency, no retry/hedge
  exposure, zero plan tokens. Only a small sim-time lookup cost is
  charged (a pod-local metadata read). A miss goes through
  ``SimLLM.complete()`` exactly as before and installs on the way back;
* **admission/invalidation policy** — programmatic TTL + frequency
  (:class:`PlanCachePolicy` over the cache's own
  :class:`~repro.core.admission.FrequencySketch` of plan keys: entries
  expire after ``ttl_s``; a full cache only evicts its LRU entry for a
  candidate at least as frequent), or the GPT-prompted path
  (:class:`LLMPlanCache`, graded agreement + PR-9's degraded-mode
  contract — unavailable → programmatic twin, ungraded; garbled →
  parse fallback).

Replay correctness (mnimi's "caching changes semantics" warning, locked
by tests/test_plan_cache.py): serving a stored plan must not shift the
session's decision-noise RNG stream, or every later task's answers would
diverge from a forced-miss replay. The engine therefore burns the exact
eps draws a fresh plan would have consumed on every hit
(:meth:`~repro.agent.concurrency.SharedCacheController.consume_plan_noise`).
A stored plan may still mispredict *current* residency — that surfaces
through the existing failed-``read_cache`` → re-plan path (time/tokens
shift, answers never do), exactly like an eps-flipped fresh decision.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.admission import FrequencySketch
from repro.core.controller import ReadPlan


def task_template_id(task) -> str:
    """Stable task-template identity: the step-kind chain plus the number
    of context keys. Pure in the task's structure, so every session that
    samples the same template computes the same id (cross-session
    sharing); the data context itself lives in the digest."""
    kinds = ">".join(s.kind for s in task.steps)
    return f"{kinds}#{len(task.required_keys)}"


@dataclasses.dataclass
class PlanCacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0           # includes expired lookups
    expired: int = 0          # TTL lapses observed at lookup time
    installs: int = 0
    rejected: int = 0         # admission bypasses (policy said no)
    evictions: int = 0        # LRU victims displaced by an admit
    invalidations: int = 0    # entries dropped by a covered-key write
    # paranoid serve-time guard: a served entry whose recorded key
    # versions no longer match the store. Structurally impossible (the
    # digest embeds the versions), counted so the safety lock can assert
    # zero instead of trusting the construction
    stale_served: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class PlanCachePolicy:
    """Programmatic admission/invalidation: TTL + frequency.

    An entry expires ``ttl_s`` after install (checked at lookup; expired
    entries count as misses and are dropped). Admission requires the
    candidate plan key's sketch frequency to reach ``min_freq``, and —
    when the cache is full — to be at least the LRU victim's frequency
    (the TinyLFU shape over plan keys instead of data keys)."""

    kind = "python"
    name = "ttl-lfu"

    def __init__(self, ttl_s: float = 180.0, min_freq: int = 1):
        if ttl_s <= 0.0:
            raise ValueError(f"ttl_s must be > 0, got {ttl_s}")
        if min_freq < 1:
            raise ValueError(f"min_freq must be >= 1, got {min_freq}")
        self.ttl_s = ttl_s
        self.min_freq = min_freq

    def admit(self, freq: int, victim_freq: Optional[int]) -> bool:
        """Cache the candidate plan? ``victim_freq`` is None while a free
        slot exists (only the frequency floor applies)."""
        if freq < self.min_freq:
            return False
        if victim_freq is None:
            return True
        return freq >= victim_freq

    def describe(self) -> str:
        return (f"TTL + frequency (a cached plan expires {self.ttl_s:g} "
                f"seconds after install; CACHE a new plan only if its "
                f"request frequency is at least {self.min_freq} and, when "
                f"the cache is full, at least the evicted plan's "
                f"frequency).")


class LLMPlanCache:
    """GPT-prompted plan-cache admission (the paper's prompted cache ops
    extended to the decision plane), graded against the programmatic twin.

    Shares PR-9's degraded-mode contract: ``LLMUnavailableError`` answers
    from the programmatic policy without tokens or grading
    (``degraded``); a garbled prompt/completion charges the prompt and
    falls back (``parse_fallbacks``); a parsed-but-foreign decision falls
    back ungraded. Free-slot installs skip the prompt entirely — like
    LLMAdmission, the GPT is only consulted when caching costs an
    eviction."""

    kind = "llm"

    def __init__(self, base: PlanCachePolicy, llm, few_shot: bool = True):
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.llm_total = 0
        self.llm_correct = 0
        self.degraded = 0
        self.parse_fallbacks = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0

    # TTL enforcement reads through the wrapper
    @property
    def ttl_s(self) -> float:
        return self.base.ttl_s

    @property
    def min_freq(self) -> int:
        return self.base.min_freq

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def describe(self) -> str:
        return self.base.describe()

    def admit(self, freq: int, victim_freq: Optional[int],
              template: str = "", victim_template: str = "") -> bool:
        expected = self.base.admit(freq, victim_freq)
        if victim_freq is None:
            return expected          # free slot: no eviction to reason about
        from repro.core.endpoints import LLMUnavailableError
        from repro.core.prompts import (
            LLMParseError,
            parse_json_tail,
            plan_cache_decision_prompt,
        )
        prompt = plan_cache_decision_prompt(
            self.base.describe(), template, victim_template, freq,
            victim_freq, self.base.ttl_s, self.few_shot)
        try:
            completion = self.llm.complete(prompt)
        except LLMUnavailableError:
            self.degraded += 1
            return expected
        except LLMParseError:
            self.parse_fallbacks += 1
            self.prompt_tokens += len(prompt) // 4
            return expected
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(completion) // 4
        try:
            raw = parse_json_tail(completion)
            decision = raw.get("decision") if isinstance(raw, dict) else None
        except ValueError:
            decision = None
        if decision not in ("cache", "bypass"):
            self.parse_fallbacks += 1
            return expected
        got = decision == "cache"
        self.llm_total += 1
        self.llm_correct += int(got == expected)
        return got


@dataclasses.dataclass
class PlanEntry:
    plan: ReadPlan
    template: str
    digest: str
    keys: Tuple[str, ...]
    versions: Tuple[Tuple[str, int], ...]
    installed_at: float
    last_used: float
    uses: int = 0


class PlanCache:
    """Shared, capacity-bounded plan cache keyed on
    ``(task_template_id, context_digest)``.

    One instance serves every session of an episode (like the admission
    sketch): a plan installed by one session is a hit for any session
    planning the same template over the same context. Recency is the
    entry dict's insertion order (a hit reinserts — exact LRU); the
    frequency evidence is the cache's own plan-key sketch, touched on
    every lookup."""

    def __init__(self, capacity: int = 128, policy=None,
                 version_of: Optional[Callable[[str], int]] = None,
                 sketch_kw: Optional[Dict] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.policy = policy if policy is not None else PlanCachePolicy()
        # current datastore version per key; the engine points this at
        # CoherenceRuntime.current_version when a mutable data plane is
        # wired. Version 0 everywhere otherwise (digests never move).
        self.version_of: Callable[[str], int] = version_of or (lambda k: 0)
        # current cache residency per key; the engine points this at the
        # pod router's locate(). A read plan is a pure function of
        # (keys, residency, eps noise), so residency is part of the
        # request context: folding it into the digest means a stored plan
        # is only served against the cache state it was computed for —
        # a cold-start all-load_db plan stops hitting the moment the
        # fleet warms up, instead of replaying redundant DB loads all
        # episode. None (standalone use) pins the bit to False.
        self.resident_of: Optional[Callable[[str], bool]] = None
        self.sketch = FrequencySketch(**(sketch_kw or {}))
        self.entries: Dict[Tuple[str, str], PlanEntry] = {}
        self.by_key: Dict[str, Set[Tuple[str, str]]] = {}
        self.stats = PlanCacheStats()

    # -- key model -----------------------------------------------------------
    def context_versions(self, keys: Sequence[str]
                         ) -> Tuple[Tuple[str, int, bool], ...]:
        res = self.resident_of
        return tuple((k, self.version_of(k), bool(res(k)) if res else False)
                     for k in sorted(keys))

    def context_digest(self, keys: Sequence[str]) -> str:
        parts = "|".join(f"{k}@{v}@{int(r)}"
                         for k, v, r in self.context_versions(keys))
        return hashlib.blake2b(parts.encode(), digest_size=8).hexdigest()

    # -- request path --------------------------------------------------------
    def lookup(self, template: str, keys: Sequence[str],
               now: float) -> Optional[ReadPlan]:
        """Serve the stored plan for ``(template, digest(keys))`` or None.
        Counts the lookup, touches the plan-key sketch (the admission
        evidence), enforces TTL, and keeps LRU order."""
        st = self.stats
        st.lookups += 1
        digest = self.context_digest(keys)
        ck = (template, digest)
        self.sketch.touch(f"{template}|{digest}", now)
        entry = self.entries.get(ck)
        if entry is None:
            st.misses += 1
            return None
        ttl = self.policy.ttl_s
        if now - entry.installed_at > ttl:
            st.expired += 1
            st.misses += 1
            self._drop(ck)
            return None
        if entry.versions != self.context_versions(keys):
            # structurally unreachable (the digest embeds the versions);
            # counted so the zero-stale-served lock measures, not trusts
            st.stale_served += 1
            st.misses += 1
            self._drop(ck)
            return None
        st.hits += 1
        entry.last_used = now
        entry.uses += 1
        del self.entries[ck]          # reinsert: dict order is recency
        self.entries[ck] = entry
        return entry.plan

    def install(self, template: str, keys: Sequence[str], plan: ReadPlan,
                now: float) -> bool:
        """Offer a freshly planned ``ReadPlan`` after a miss. The policy
        (programmatic or GPT-prompted) decides cache vs bypass; a full
        cache evicts its LRU entry on admit."""
        digest = self.context_digest(keys)
        ck = (template, digest)
        if ck in self.entries:
            return False               # racing sessions: first install wins
        freq = int(self.sketch.estimate(f"{template}|{digest}"))
        victim_ck = victim_freq = victim_template = None
        if len(self.entries) >= self.capacity:
            victim_ck = next(iter(self.entries))
            victim_freq = int(self.sketch.estimate("|".join(victim_ck)))
            victim_template = victim_ck[0]
        pol = self.policy
        if isinstance(pol, LLMPlanCache):
            ok = pol.admit(freq, victim_freq, template=template,
                           victim_template=victim_template or "")
        else:
            ok = pol.admit(freq, victim_freq)
        if not ok:
            self.stats.rejected += 1
            return False
        if victim_ck is not None:
            self._drop(victim_ck)
            self.stats.evictions += 1
        entry = PlanEntry(plan=plan, template=template, digest=digest,
                          keys=tuple(keys),
                          versions=self.context_versions(keys),
                          installed_at=now, last_used=now)
        self.entries[ck] = entry
        for k in entry.keys:
            self.by_key.setdefault(k, set()).add(ck)
        self.stats.installs += 1
        return True

    # -- write coupling ------------------------------------------------------
    def note_write(self, key: str, invalidate: bool) -> int:
        """A datastore write landed on ``key``. The version bump already
        moved every digest covering it (old plans are unreachable); under
        an invalidating coherence policy the dead entries are additionally
        dropped now (capacity hygiene, counted)."""
        if not invalidate:
            return 0
        dropped = 0
        for ck in list(self.by_key.get(key, ())):
            self._drop(ck)
            dropped += 1
        if dropped:
            self.stats.invalidations += dropped
        return dropped

    # -- internals -----------------------------------------------------------
    def _drop(self, ck: Tuple[str, str]) -> None:
        entry = self.entries.pop(ck, None)
        if entry is None:
            return
        for k in entry.keys:
            covers = self.by_key.get(k)
            if covers is not None:
                covers.discard(ck)
                if not covers:
                    del self.by_key[k]

    # -- reporting -----------------------------------------------------------
    @property
    def agreement(self) -> float:
        return getattr(self.policy, "agreement", 1.0)

    @property
    def tokens(self) -> int:
        return (getattr(self.policy, "prompt_tokens", 0)
                + getattr(self.policy, "completion_tokens", 0))

    def covered_entries(self, key: str) -> List[Tuple[str, str]]:
        """Plan-cache keys whose context digest covers ``key``
        (diagnostics + the ``cache_plan`` probe)."""
        return sorted(self.by_key.get(key, ()))


def make_plan_cache(impl: str = "python", *, llm=None, few_shot: bool = True,
                    capacity: int = 128, ttl_s: float = 180.0,
                    min_freq: int = 1,
                    sketch_kw: Optional[Dict] = None) -> PlanCache:
    """Factory mirroring ``make_admission``/``make_coherence``:
    ``impl="python"`` (or ``"programmatic"``) builds the TTL+frequency
    policy, ``impl="llm"`` wraps it in the graded GPT-prompted path."""
    base = PlanCachePolicy(ttl_s=ttl_s, min_freq=min_freq)
    if impl in ("python", "programmatic"):
        policy = base
    elif impl == "llm":
        assert llm is not None, "impl='llm' requires an llm"
        policy = LLMPlanCache(base, llm, few_shot=few_shot)
    else:
        raise ValueError(
            f"unknown plan-cache impl {impl!r} "
            f"(expected 'python', 'programmatic' or 'llm')")
    return PlanCache(capacity=capacity, policy=policy, sketch_kw=sketch_kw)

"""Cache operations as callable API tools (the paper's key design choice).

``read_cache`` / ``load_db`` are ordinary :class:`ToolSpec` entries exposed
in the function-calling schema *alongside every other platform tool*, so the
LLM plans cache usage exactly the way it plans any tool call, and a cache
miss is just a failed tool call it re-plans around.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class ToolSpec:
    name: str
    description: str
    parameters: Dict[str, Any]          # JSON-schema properties
    fn: Callable[..., Any]
    latency_s: float = 0.0              # modeled execution latency (SimClock)

    def schema(self) -> Dict[str, Any]:
        """OpenAI-style function-calling schema entry."""
        return {
            "type": "function",
            "function": {
                "name": self.name,
                "description": self.description,
                "parameters": {"type": "object", "properties": self.parameters,
                               "required": list(self.parameters)},
            },
        }


@dataclasses.dataclass
class ToolResult:
    name: str
    ok: bool
    value: Any = None
    error: str = ""
    latency_s: float = 0.0


class ToolError(Exception):
    pass


def make_cache_tools(cache, datastore, clock) -> List[ToolSpec]:
    """The two dCache tools. ``datastore`` is "main memory" (5-10x slower,
    paper §IV); ``clock`` is the SimClock that accumulates modeled latency."""

    def read_cache(key: str):
        t0 = clock.now()
        value = cache.get(key)          # raises KeyError on miss
        clock.advance(datastore.cache_read_latency(key))
        return value

    def load_db(key: str):
        value = datastore.load(key)     # advances clock by DB latency itself
        return value

    return [
        ToolSpec(
            name="read_cache",
            description=("Read imagery metadata for a `dataset-year` key "
                         "from the LOCAL CACHE. Fast (local). Fails if the "
                         "key is not currently cached."),
            parameters={"key": {"type": "string",
                                "description": "dataset-year, e.g. xview1-2022"}},
            fn=read_cache),
        ToolSpec(
            name="load_db",
            description=("Load imagery metadata for a `dataset-year` key "
                         "from the REMOTE DATABASE. Slow (network + storage)."),
            parameters={"key": {"type": "string",
                                "description": "dataset-year, e.g. xview1-2022"}},
            fn=load_db),
    ]


def make_admission_tool(admission, sketch, entries_of, victim_of,
                        capacity_of, locality=None) -> ToolSpec:
    """Admission as a callable cache op: ``cache_admit(key)`` answers
    whether a freshly loaded ``key`` would be installed or bypassed, with
    the evidence (victim + sketch estimates) the decision is based on.

    Exposed in the same function-calling schema as ``read_cache`` /
    ``load_db`` so the agent — or the GPT-driven controller — can query the
    admission verdict like any other tool. ``entries_of(key)`` returns the
    owning cache's entries, ``victim_of(key, entries)`` the would-be
    eviction victim, ``capacity_of(key)`` the owning cache's capacity;
    factoring these out lets the single-cache runtime and the pod-sharded
    router share one implementation. With a ``locality`` model wired
    (session->pod affinity), the verdict additionally reports the key's
    remote consumer demand by home pod — the evidence the locality-aware
    prompt path reasons over.
    """

    def cache_admit(key: str):
        entries = entries_of(key)
        kf = sketch.estimate(key) if sketch is not None else 0
        out = {"key": key, "decision": "admit", "victim": None,
               "key_freq": kf, "victim_freq": 0, "reason": "cache not full"}
        if len(entries) >= capacity_of(key):
            victim = victim_of(key, entries)
            ok = admission.admit(key, victim, sketch, entries)
            vf = sketch.estimate(victim) if sketch is not None else 0
            out.update(decision="admit" if ok else "bypass", victim=victim,
                       victim_freq=vf, reason=admission.name)
        if locality is not None and locality.penalty > 1.0:
            # only under a penalty — at 1x nothing populates the map (the
            # same gate every other locality surface uses)
            out["remote_demand"] = dict(locality.remote_demand.get(key, {}))
        return out

    return ToolSpec(
        name="cache_admit",
        description=("Ask the cache ADMISSION policy whether loading "
                     "`dataset-year` from the database would install it "
                     "into the cache (evicting the named victim) or bypass "
                     "the cache entirely (data streams through, residents "
                     "untouched)."),
        parameters={"key": {"type": "string",
                            "description": "dataset-year, e.g. xview1-2022"}},
        fn=cache_admit)


def make_replication_tool(replicator) -> ToolSpec:
    """Hot-key replication as a callable cache op: ``cache_replicate(key)``
    answers whether the replication policy would REPLICATE the key to every
    pod, DROP its existing replicas, or HOLD the current placement — with
    the evidence (sketch estimate, thresholds, current replica state) the
    decision is based on.

    Exposed in the same function-calling schema as ``read_cache`` /
    ``load_db`` / ``cache_admit`` so the agent — or the GPT-driven
    controller — can query the placement verdict like any other tool (the
    paper's cache-ops-as-tools design extended to placement). Querying is
    side-effect-free: actual promotion/demotion happens on the
    replicator's epoch, and the sketch is read without interning (a
    queried-but-never-accessed key must not join the top-k candidate
    population). The verdict is always the programmatic base rule — a
    diagnostic probe must not consume LLM tokens or grading samples."""

    def cache_replicate(key: str):
        pol = replicator.policy
        base = getattr(pol, "base", pol)     # LLM wrapper: probe the rule
        freq = replicator.sketch.estimate_peek(key)
        replicated = key in replicator.replicated
        decision = base.decide(key, freq, replicated)
        out = {"key": key, "decision": decision, "key_freq": freq,
               "replicated": replicated,
               "promote_min": pol.promote_min,
               "demote_min": pol.demote_min,
               "reason": pol.name}
        locality = getattr(replicator.router, "locality", None)
        if locality is not None and locality.penalty > 1.0:
            # under a cross-pod penalty, the verdict surfaces WHO is
            # paying hops for this key — the placement evidence
            out["remote_demand"] = dict(
                locality.remote_demand.get(key, {}))
        return out

    return ToolSpec(
        name="cache_replicate",
        description=("Ask the hot-key REPLICATION policy whether "
                     "`dataset-year` should be replicated to every pod "
                     "(converting remote joins into local hits at the cost "
                     "of cache capacity), have its replicas dropped, or "
                     "keep its current placement."),
        parameters={"key": {"type": "string",
                            "description": "dataset-year, e.g. xview1-2022"}},
        fn=cache_replicate)


def make_recovery_tool(recovery, sketch) -> ToolSpec:
    """Post-failover recovery as a callable cache op: ``cache_recover(key)``
    answers whether the recovery policy would RE-WARM the key now (one
    background DB load onto its new rendezvous owner) or refill it LAZILY
    on the next demand access — with the evidence (sketch estimate,
    re-warm threshold) the decision is based on.

    Exposed in the same function-calling schema as ``read_cache`` /
    ``load_db`` / ``cache_admit`` / ``cache_replicate`` (the paper's
    cache-ops-as-tools design extended to failover handling). Querying is
    side-effect-free: actual re-warms happen in the fault runtime's
    failover handler, and the sketch is read without interning. The
    verdict is always the programmatic base rule — a diagnostic probe
    must not consume LLM tokens or grading samples."""

    def cache_recover(key: str):
        base = getattr(recovery, "base", recovery)   # LLM wrapper: the rule
        freq = (int(sketch.estimate_peek(key)) if sketch is not None else 0)
        return {"key": key, "decision": base.decide(key, freq),
                "key_freq": freq, "rewarm_min": base.rewarm_min,
                "reason": recovery.name}

    return ToolSpec(
        name="cache_recover",
        description=("Ask the failover RECOVERY policy whether a "
                     "`dataset-year` key lost in a pod failure should be "
                     "re-warmed now (one background database load onto its "
                     "new owner pod) or refilled lazily by the next demand "
                     "access."),
        parameters={"key": {"type": "string",
                            "description": "dataset-year, e.g. xview1-2022"}},
        fn=cache_recover)


def make_coherence_tool(runtime, sketch) -> ToolSpec:
    """Cache coherence as a callable cache op: ``cache_update(key)``
    answers what the coherence policy would do with the key's cached copy
    RIGHT NOW — fresh (versions match), refresh (reload before consuming)
    or serve_stale (the lagging copy is within the declared bound) — with
    the evidence (current datastore version, the copy's version, its
    staleness, the bound) the decision is based on.

    This is the paper's *cache update* op surfaced as a tool (the read op
    has been one since PR 1). Exposed in the same function-calling schema
    as ``read_cache`` / ``load_db`` / ``cache_admit`` /
    ``cache_replicate`` / ``cache_recover``. Querying is side-effect-free:
    real verdicts happen at the consume checkpoint inside the engine's
    read path, and the probe always answers with the programmatic base
    rule — a diagnostic must not consume LLM tokens or grading samples."""

    def cache_update(key: str):
        current = runtime.current_version(key)
        pol = runtime.policy
        base = getattr(pol, "base", pol)     # LLM wrapper: probe the rule
        out = {"key": key, "version": current, "copy_version": None,
               "decision": "fresh", "staleness_s": 0.0,
               "bound_s": base.bound_s, "reason": base.name}
        placed = runtime.router.locate(key)
        if placed is None:
            out["reason"] = f"{base.name} (no cached copy)"
            return out
        entry = runtime.router.pods[placed].entry(key)
        out["copy_version"] = entry.version
        if entry.version >= current:
            return out
        now = runtime.clock_now()
        freq = (int(sketch.estimate_peek(key)) if sketch is not None else 0)
        staleness = runtime.staleness_of(key, entry.version, now)
        # the engine enforces TTL on staleness, which lower-bounds age
        # (the missed write postdates the install) — same contract, no
        # dependence on the pod caches' tick-order recency clock
        decision = base.on_stale_read(key, staleness, staleness, freq)
        if decision == "serve_stale" and staleness > base.bound_s:
            decision = "refresh"             # the engine's hard clamp
        out.update(decision=decision, staleness_s=round(staleness, 6))
        return out

    return ToolSpec(
        name="cache_update",
        description=("Ask the cache COHERENCE policy what to do with the "
                     "cached copy of a `dataset-year` key whose data may "
                     "have been updated in the database since it was "
                     "cached: serve it as-is (fresh or stale-within-bound) "
                     "or refresh it from the database before use."),
        parameters={"key": {"type": "string",
                            "description": "dataset-year, e.g. xview1-2022"}},
        fn=cache_update)


def make_plan_cache_tool(plan_cache) -> ToolSpec:
    """The plan-cache tier as a callable cache op: ``cache_plan(key)``
    answers whether a fresh plan whose context covers `dataset-year` would
    currently be CACHED or BYPASSED by the plan-cache admission policy —
    with the evidence (the key's covering entries, the cache's occupancy,
    the LRU victim's plan frequency) the decision is based on.

    Exposed in the same function-calling schema as ``read_cache`` /
    ``load_db`` / ``cache_admit`` / ``cache_replicate`` / ``cache_recover``
    / ``cache_update`` (the paper's cache-ops-as-tools design extended to
    the decision plane). Querying is side-effect-free: real admissions
    happen on the install path after a planning round, the plan-key sketch
    is read without interning, and the probe always answers with the
    programmatic base rule — a diagnostic must not consume LLM tokens or
    grading samples."""

    def cache_plan(key: str):
        pol = plan_cache.policy
        base = getattr(pol, "base", pol)     # LLM wrapper: probe the rule
        covered = plan_cache.covered_entries(key)
        out = {"key": key, "decision": "cache",
               "covered_plans": ["|".join(ck) for ck in covered],
               "entries": len(plan_cache.entries),
               "capacity": plan_cache.capacity,
               "victim": None, "victim_freq": 0,
               "ttl_s": base.ttl_s, "min_freq": base.min_freq,
               "reason": "plan cache not full"}
        if len(plan_cache.entries) >= plan_cache.capacity:
            victim_ck = next(iter(plan_cache.entries))
            vf = int(plan_cache.sketch.estimate_peek("|".join(victim_ck)))
            # probe verdict for a typical repeat (frequency = min_freq):
            # would a plan exactly at the floor displace the LRU victim?
            ok = base.admit(base.min_freq, vf)
            out.update(decision="cache" if ok else "bypass",
                       victim="|".join(victim_ck), victim_freq=vf,
                       reason=base.name)
        return out

    return ToolSpec(
        name="cache_plan",
        description=("Ask the PLAN-CACHE admission policy whether a fresh "
                     "planning round over a context covering `dataset-year` "
                     "would currently be cached (evicting the named victim "
                     "plan when full) or bypassed, and which cached plans "
                     "already cover the key."),
        parameters={"key": {"type": "string",
                            "description": "dataset-year, e.g. xview1-2022"}},
        fn=cache_plan)


class ToolRegistry:
    """Function-calling registry: schemas for the prompt, dispatch at runtime."""

    def __init__(self, tools: Optional[List[ToolSpec]] = None):
        self._tools: Dict[str, ToolSpec] = {}
        for t in tools or []:
            self.register(t)

    def register(self, tool: ToolSpec):
        if tool.name in self._tools:
            raise ValueError(f"duplicate tool {tool.name}")
        self._tools[tool.name] = tool

    def names(self) -> List[str]:
        return sorted(self._tools)

    def schemas(self) -> List[Dict[str, Any]]:
        return [self._tools[n].schema() for n in self.names()]

    def __contains__(self, name: str) -> bool:
        return name in self._tools

    def get(self, name: str) -> ToolSpec:
        return self._tools[name]

    def call(self, name: str, clock=None, **kwargs) -> ToolResult:
        # dispatch is the engine's innermost loop (every tool call of every
        # session goes through here): one dict lookup, no wall-clock timing
        # — latency accounting is *modeled* (SimClock), and ToolResult's
        # latency_s field reports the modeled charge
        spec = self._tools.get(name)
        if spec is None:
            return ToolResult(name=name, ok=False,
                              error=f"unknown tool {name!r}; available: "
                                    f"{self.names()}")
        if clock is not None and spec.latency_s:
            clock.advance(spec.latency_s)
        try:
            return ToolResult(name=name, ok=True, value=spec.fn(**kwargs),
                              latency_s=spec.latency_s)
        except (ToolError, KeyError, ValueError) as e:
            return ToolResult(name=name, ok=False, error=str(e),
                              latency_s=spec.latency_s)

"""Process-wide cumulative performance counters (``benchmarks.run
--profile``).

A deliberately tiny facility: components bump named counters in bulk at
natural boundaries (an engine run's end, a memo lookup), never per-event in
a hot loop, so the counters are always on and cost nothing measurable. The
benchmark driver snapshots the table before/after each section and writes
the per-phase deltas into the JSON record (schema ``bench_dcache/v3``),
which is what lets a perf regression be localised to a phase *and* a
mechanism (e.g. "the admission table's wall grew because sketch flushes
tripled") without rerunning under a profiler.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict

COUNTERS: Dict[str, float] = defaultdict(float)
_LOCK = threading.Lock()     # --parallel runs cells on a thread pool


def add(name: str, value: float = 1.0) -> None:
    """Accumulate ``value`` into the named counter (thread-safe: the
    read-modify-write must not lose increments under ``--parallel``)."""
    with _LOCK:
        COUNTERS[name] += value


def snapshot() -> Dict[str, float]:
    """Point-in-time copy of every counter."""
    with _LOCK:
        return dict(COUNTERS)


def delta(before: Dict[str, float],
          after: Dict[str, float]) -> Dict[str, float]:
    """Counter increments between two snapshots (zero-delta keys omitted;
    values rounded for stable JSON)."""
    out = {}
    for k, v in after.items():
        d = v - before.get(k, 0.0)
        if d:
            out[k] = round(d, 6)
    return out

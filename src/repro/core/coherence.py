"""Mutable data plane: mutation schedules + cache-coherence policies
(ISSUE 8).

The datastore was read-only through PR 7, so the paper's *cache update*
surface had no teeth: no cached copy could ever be wrong. This module
adds the write path as data — a :class:`MutationPlan` of seeded
:class:`MutationEvent` s on sim time (frame row updates, new imagery
arrivals), scheduled into the engine's event heap exactly like the PR-6
fault events — and the *coherence policies* that decide what a cache may
serve once writes exist:

* ``write-invalidate`` — a mutation purges every copy (owner, replicas,
  superseded in-flight fills). Nothing stale is ever consumed; readers
  pay the re-fetch.
* ``write-through`` — a mutation pushes the new version into every live
  copy in place (writer-side cost, counted per copy). Caches never lag.
* ``ttl`` — the llm-cache idiom: a copy serves until its *age* exceeds
  ``ttl_s``, then refreshes on next read. A version-lagged copy inside
  its TTL serves stale, but staleness can never exceed the TTL (the
  mutation happened after the install), so ``ttl_s`` is the declared
  staleness bound.
* ``serve-stale`` — bounded staleness: a version-lagged copy serves as
  long as its staleness (now minus the first unapplied mutation) is at
  most ``bound_s``; beyond the bound the read refreshes. This is the
  programmatic base the GPT-driven ``cache_update`` path is graded
  against.

The policies follow the established dual-policy shape (admission /
replication / recovery): a programmatic rule plus an
:class:`LLMCoherence` wrapper that renders the rule as natural language,
asks the LLM per stale read (refresh-now vs serve-stale-within-bound),
grades every verdict against the programmatic expectation, and falls
back to it on malformed output. Whatever the LLM answers, the engine
CLAMPS consumption to the declared bound — serve-stale past the bound is
forced to refresh — so the staleness contract is a hard property, not a
model behavior.

Degeneracy contract (property-locked like PR-5/PR-7): ``mutations=None``
or an EMPTY plan replays the PR-7 engine bit-identically — versions
never move, every read is fresh, no counter increments.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Iterator, List, Optional, Sequence

from repro.core.prompts import coherence_decision_prompt, parse_json_tail

UPDATE = "update"      # in-place frame rows changed (version bump)
ARRIVAL = "arrival"    # new imagery landed for the key (version bump)
_KINDS = (UPDATE, ARRIVAL)
_KIND_ORDER = {UPDATE: 0, ARRIVAL: 1}

REFRESH = "refresh"
SERVE_STALE = "serve_stale"

MAX_MUTATIONS_DEFAULT = 100_000


def _require(cond: bool, msg: str) -> None:
    """Fail-fast parameter validation (ISSUE 8, like core.traffic): a bad
    rate or bound here silently corrupts every downstream staleness
    property — reject loudly at construction."""
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class MutationEvent:
    """One datastore write at sim time ``at``: ``key``'s version bumps by
    one. ``kind`` distinguishes in-place row updates from new-imagery
    arrivals (both version the key; workloads and tables use the split
    for reporting and for the flash-crowd-on-fresh-data pairing)."""

    at: float
    key: str
    kind: str = UPDATE

    def __post_init__(self):
        _require(isinstance(self.at, (int, float)) and self.at >= 0.0,
                 f"mutation time must be >= 0, got {self.at!r}")
        _require(isinstance(self.key, str) and bool(self.key),
                 f"mutation key must be a non-empty string, got {self.key!r}")
        _require(self.kind in _KINDS,
                 f"mutation kind must be one of {_KINDS}, got {self.kind!r}")


class MutationPlan:
    """A deterministic schedule of datastore writes (like
    :class:`~repro.core.faults.FaultPlan` for membership changes).

    Events are sorted by (time, kind, key) so same-instant writes apply
    in a fixed order whatever order the generator produced them. An
    EMPTY plan is falsy and is the degeneracy reference: the coherence
    layer runs every hook yet replays the mutation-free engine
    bit-identically (locked by tests/test_coherence.py)."""

    def __init__(self, events: Sequence[MutationEvent] = ()):
        evs = list(events)
        for e in evs:
            _require(isinstance(e, MutationEvent),
                     f"MutationPlan takes MutationEvents, got {e!r}")
        self.events: List[MutationEvent] = sorted(
            evs, key=lambda e: (e.at, _KIND_ORDER[e.kind], e.key))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[MutationEvent]:
        return iter(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __repr__(self) -> str:
        return f"MutationPlan({self.events!r})"

    # -- parametric generators (deterministic in their seed) -----------------
    @staticmethod
    def single(key: str, at: float, kind: str = UPDATE) -> "MutationPlan":
        """One write to ``key`` at ``at``."""
        return MutationPlan([MutationEvent(at, key, kind)])

    @staticmethod
    def periodic(keys: Sequence[str], period_s: float, *,
                 start_s: float = 0.0, horizon_s: float,
                 kind: str = UPDATE) -> "MutationPlan":
        """Round-robin writes over ``keys`` every ``period_s`` from
        ``start_s`` to ``horizon_s`` (exclusive) — the steady drumbeat of
        a re-imaged region, or (with ``kind=ARRIVAL``) a feed of new
        scenes walking the key list."""
        _require(len(keys) > 0, "periodic plan needs at least one key")
        _require(period_s > 0.0, f"period_s must be > 0, got {period_s}")
        _require(start_s >= 0.0, f"start_s must be >= 0, got {start_s}")
        _require(horizon_s > start_s,
                 f"horizon_s ({horizon_s}) must be > start_s ({start_s})")
        evs, t, i = [], start_s, 0
        while t < horizon_s:
            evs.append(MutationEvent(t, keys[i % len(keys)], kind))
            i += 1
            t = start_s + i * period_s
        return MutationPlan(evs)

    @staticmethod
    def random_plan(keys: Sequence[str], rate_per_s: float,
                    horizon_s: float, *, seed: int = 0,
                    arrival_p: float = 0.0,
                    max_events: int = MAX_MUTATIONS_DEFAULT,
                    ) -> "MutationPlan":
        """Poisson write stream at ``rate_per_s`` over ``horizon_s``:
        each event hits a uniformly drawn key from ``keys`` and is an
        ARRIVAL with probability ``arrival_p`` (else an UPDATE).
        Deterministic in ``seed``."""
        _require(len(keys) > 0, "random plan needs at least one key")
        _require(rate_per_s > 0.0,
                 f"rate_per_s must be > 0, got {rate_per_s}")
        _require(horizon_s > 0.0,
                 f"horizon_s must be > 0, got {horizon_s}")
        _require(0.0 <= arrival_p <= 1.0,
                 f"arrival_p must be in [0, 1], got {arrival_p}")
        _require(max_events >= 1,
                 f"max_events must be >= 1, got {max_events}")
        rng = random.Random(seed)
        evs: List[MutationEvent] = []
        t = rng.expovariate(rate_per_s)
        while t < horizon_s:
            _require(len(evs) < max_events,
                     f"mutation plan exceeded max_events={max_events} "
                     f"(rate {rate_per_s}/s over {horizon_s}s)")
            kind = ARRIVAL if rng.random() < arrival_p else UPDATE
            evs.append(MutationEvent(t, keys[rng.randrange(len(keys))],
                                     kind))
            t += rng.expovariate(rate_per_s)
        return MutationPlan(evs)


# ---------------------------------------------------------------------------
# Coherence policies (dual shape: programmatic rule + LLM wrapper)
# ---------------------------------------------------------------------------

class CoherencePolicy:
    """What a cache may do with a copy once the datastore has moved on.

    Two hooks: the *mutation-time* behavior is declared by the class
    flags (``invalidate_on_write`` purges every copy;
    ``refresh_on_write`` pushes the new version into every copy), and
    the *read-time* behavior is :meth:`on_stale_read` — called when a
    consumer is about to serve a version-lagged copy, returning
    ``"refresh"`` or ``"serve_stale"``. ``bound_s`` is the declared
    staleness bound the engine enforces as a hard clamp (``0.0`` means
    nothing stale is ever consumable)."""

    name = "?"
    invalidate_on_write = False
    refresh_on_write = False
    bound_s: float = 0.0

    def on_stale_read(self, key: str, staleness_s: float, age_s: float,
                      freq: int) -> str:
        return REFRESH

    def expired(self, age_s: float) -> bool:
        """TTL-style age expiry, independent of versions (False for
        every policy but TTL)."""
        return False

    def describe(self) -> str:
        raise NotImplementedError


class WriteInvalidate(CoherencePolicy):
    """Purge every copy at write time; nothing stale is ever served."""

    name = "write-invalidate"
    invalidate_on_write = True

    def describe(self) -> str:
        return ("every write invalidates all cached copies; a read after "
                "a write always re-fetches (zero staleness)")


class WriteThrough(CoherencePolicy):
    """Push the new version into every live copy at write time."""

    name = "write-through"
    refresh_on_write = True

    def describe(self) -> str:
        return ("every write refreshes all cached copies in place; "
                "caches never lag the store (zero staleness)")


class TTLCoherence(CoherencePolicy):
    """Age-based expiry (the llm-cache idiom): a copy serves — fresh or
    version-lagged — until its age exceeds ``ttl_s``, then the next read
    refreshes it. Staleness never exceeds the TTL because the mutation
    postdates the install."""

    name = "ttl"

    def __init__(self, ttl_s: float = 30.0):
        _require(ttl_s > 0.0, f"ttl_s must be > 0, got {ttl_s}")
        self.ttl_s = ttl_s
        self.bound_s = ttl_s

    def expired(self, age_s: float) -> bool:
        return age_s > self.ttl_s

    def on_stale_read(self, key: str, staleness_s: float, age_s: float,
                      freq: int) -> str:
        return SERVE_STALE if age_s <= self.ttl_s else REFRESH

    def describe(self) -> str:
        return (f"serve any cached copy younger than {self.ttl_s:g}s "
                f"(even if the store has newer data); refresh a copy "
                f"older than {self.ttl_s:g}s on its next read")


class ServeStaleCoherence(CoherencePolicy):
    """Bounded staleness: serve a version-lagged copy while its
    staleness (seconds since the first unapplied write) is at most
    ``bound_s``; refresh beyond the bound."""

    name = "serve-stale"

    def __init__(self, bound_s: float = 20.0):
        _require(bound_s > 0.0, f"bound_s must be > 0, got {bound_s}")
        self.bound_s = bound_s

    def on_stale_read(self, key: str, staleness_s: float, age_s: float,
                      freq: int) -> str:
        return SERVE_STALE if staleness_s <= self.bound_s else REFRESH

    def describe(self) -> str:
        return (f"serve a stale cached copy while its staleness is at "
                f"most {self.bound_s:g} seconds; refresh now once the "
                f"staleness exceeds {self.bound_s:g} seconds")


class LLMCoherence(CoherencePolicy):
    """GPT-driven ``cache_update``: each stale read is described to the
    LLM (key, staleness, bound, observed frequency) and its
    refresh-now vs serve-stale-within-bound verdict is used — graded
    against the wrapped programmatic rule exactly like the admission /
    replication / recovery paths. Malformed output falls back to the
    programmatic expectation. The engine's bound clamp applies to the
    LLM's answers too: a serve-stale verdict past ``bound_s`` is forced
    to refresh, so the staleness contract survives any decision noise."""

    def __init__(self, base: CoherencePolicy, llm, few_shot: bool = True):
        _require(base is not None and not isinstance(base, LLMCoherence),
                 "LLMCoherence wraps a programmatic policy")
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.name = f"llm-{base.name}"
        self.llm_total = 0
        self.llm_correct = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0
        # resilience fallbacks to the programmatic base (ungraded): garbled
        # prompt/completion vs endpoint pool down (ISSUE 9)
        self.parse_fallbacks = 0
        self.degraded = 0

    @property
    def invalidate_on_write(self) -> bool:          # type: ignore[override]
        return self.base.invalidate_on_write

    @property
    def refresh_on_write(self) -> bool:             # type: ignore[override]
        return self.base.refresh_on_write

    @property
    def bound_s(self) -> float:                     # type: ignore[override]
        return self.base.bound_s

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def expired(self, age_s: float) -> bool:
        return self.base.expired(age_s)

    def render_prompt(self, key: str, staleness_s: float, freq: int) -> str:
        return coherence_decision_prompt(
            self.base.describe(), key, staleness_s, self.base.bound_s,
            freq, few_shot=self.few_shot)

    def on_stale_read(self, key: str, staleness_s: float, age_s: float,
                      freq: int) -> str:
        from repro.core.endpoints import LLMUnavailableError
        from repro.core.prompts import LLMParseError
        expected = self.base.on_stale_read(key, staleness_s, age_s, freq)
        prompt = self.render_prompt(key, staleness_s, freq)
        try:
            out = self.llm.complete(prompt)
        except LLMUnavailableError:
            # endpoint pool down: programmatic twin, ungraded (the router
            # already billed the wasted retry tokens)
            self.degraded += 1
            return expected
        except LLMParseError:
            self.parse_fallbacks += 1
            self.prompt_tokens += len(prompt) // 4
            return expected
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(out) // 4
        try:
            parsed = parse_json_tail(out)
            decision = (parsed.get("decision")
                        if isinstance(parsed, dict) else None)
        except ValueError:
            decision = None
        if decision not in (REFRESH, SERVE_STALE):
            # garbled/meaningless completion: programmatic twin, ungraded
            self.parse_fallbacks += 1
            return expected
        self.llm_total += 1
        if decision == expected:
            self.llm_correct += 1
        return decision

    def describe(self) -> str:
        return self.base.describe()


_POLICIES = ("write-invalidate", "write-through", "ttl", "serve-stale")


def make_coherence(policy: str = "write-invalidate", *,
                   impl: str = "python", llm=None, few_shot: bool = True,
                   ttl_s: float = 30.0,
                   bound_s: float = 20.0) -> CoherencePolicy:
    """Factory for the engine's ``coherence=`` argument.

    ``impl="llm"`` wraps the read-time decision in the GPT-driven
    :class:`LLMCoherence` path — only meaningful for the policies that
    HAVE a read-time decision (``ttl`` / ``serve-stale``);
    write-invalidate and write-through act at write time and never
    consult a reader."""
    _require(policy in _POLICIES,
             f"unknown coherence policy {policy!r} (expected one of "
             f"{_POLICIES})")
    _require(impl in ("python", "llm"),
             f"coherence impl must be 'python' or 'llm', got {impl!r}")
    if policy == "write-invalidate":
        base: CoherencePolicy = WriteInvalidate()
    elif policy == "write-through":
        base = WriteThrough()
    elif policy == "ttl":
        base = TTLCoherence(ttl_s=ttl_s)
    else:
        base = ServeStaleCoherence(bound_s=bound_s)
    if impl == "llm":
        _require(policy in ("ttl", "serve-stale"),
                 f"impl='llm' needs a read-time decision; {policy!r} "
                 f"decides at write time")
        _require(llm is not None, "impl='llm' requires an llm backend")
        return LLMCoherence(base, llm, few_shot=few_shot)
    return base


# ---------------------------------------------------------------------------
# Accounting (engine-side counters live here so tests can assert on one
# object; the CoherenceRuntime in repro.agent.concurrency fills it)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CoherenceStats:
    mutations: int = 0
    updates: int = 0
    arrivals: int = 0
    invalidations: int = 0      # copies purged at write time (WI)
    writethroughs: int = 0      # copies refreshed in place at write time
    superseded_fills: int = 0   # in-flight fills outdated by a write
    expired_reads: int = 0      # TTL age expiries (refresh, never stale)
    clamped: int = 0            # serve-stale verdicts forced to refresh
    fresh_reads: int = 0
    stale_reads: int = 0
    refresh_reads: int = 0
    max_staleness_s: float = 0.0

    def consumes(self) -> int:
        return self.fresh_reads + self.stale_reads + self.refresh_reads

    def stale_share(self) -> float:
        n = self.consumes()
        return self.stale_reads / n if n else 0.0

"""Deterministic fault/elasticity layer for the discrete-event engine.

The paper's deployment is an industry-scale platform spanning hundreds of
GPT endpoints — a fleet that size loses pods and gets resized mid-traffic.
This module supplies the *schedule* side of that story as plain sim-time
data, so membership changes can land as first-class
:class:`~repro.agent.geollm.simclock.EventQueue` events with exact ordering
against loads, prefetches and replication epochs (the engine-side semantics
— aborts, retries, warm-up transients — live in
``repro.agent.concurrency``; see docs/architecture.md):

* :class:`FaultPlan`        — a sorted schedule of ``fail``/``restore``/
                              ``scale_out``/``scale_in`` events, plus
                              parametric generators (single, periodic,
                              random-seeded, correlated multi-pod, elastic);
* :class:`RetryPolicy`      — bounded sim-time exponential backoff for
                              sessions whose in-flight load died with its
                              pod;
* :class:`SimFailureInjector` / :class:`SimStragglerDetector` — the seed
  fault-tolerance idioms (``repro.distributed.fault_tolerance``) ported to
  sim time: a deterministic fail-at-sim-times schedule and z-score
  straggler / heartbeat-timeout detection that never touch
  ``time.monotonic()`` (the wall-clock originals stay quarantined to the
  training loop);
* :class:`ThresholdRecovery` / :class:`LLMRecovery` — the GPT-driven
  post-failover decision, mirroring admission/replication's dual-policy
  shape: after a pod dies, each hot key it held is judged *re-warm now*
  (background load onto the new rendezvous owner) vs *lazy refill* (the
  next demand pays); the LLM path prompts with the programmatic rule's
  ``describe()`` text and is graded against it;
* :class:`BacklogAutoscaler` — a simple open-loop policy driving
  ``scale_out``/``scale_in`` from the PR-4 backlog/EWMA queueing signals.

The degeneracy contract: an **empty** :class:`FaultPlan` (no events, no
autoscaler) replays the fault-free engine bit-identically — locked by
property-based replay in tests/test_faults.py.
"""
from __future__ import annotations

import dataclasses
import json
import random
import statistics
from typing import Dict, List, Optional, Sequence, Tuple

FAIL = "fail"
RESTORE = "restore"
SCALE_OUT = "scale_out"
SCALE_IN = "scale_in"
ACTIONS = (FAIL, RESTORE, SCALE_OUT, SCALE_IN)
# same-instant ordering: capacity arrives before capacity leaves, and a
# restore of pod A runs before a fail of pod B (a correlated plan that
# swaps two pods at one instant never passes through a zero-pod fleet)
_ACTION_ORDER = {SCALE_OUT: 0, RESTORE: 1, FAIL: 2, SCALE_IN: 3}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One membership change at an absolute sim time."""
    at: float
    action: str
    pod: str

    def __post_init__(self):
        assert self.action in ACTIONS, self.action
        assert self.at >= 0.0, self.at


class FaultPlan:
    """A deterministic sim-time schedule of membership changes.

    Events are kept sorted by ``(at, action-order, pod)`` so injecting them
    into the scheduler is order-independent of construction. An empty plan
    is falsy and must replay the fault-free engine bit-identically (the
    degeneracy contract)."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.at, _ACTION_ORDER[e.action], e.pod))

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __iter__(self):
        return iter(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan({self.events!r})"

    # -- parametric generators ------------------------------------------------
    @staticmethod
    def single(pod: str, fail_at: float,
               restore_at: Optional[float] = None) -> "FaultPlan":
        """One pod failure, optionally restored later (cold — its cache
        contents died with it)."""
        evs = [FaultEvent(fail_at, FAIL, pod)]
        if restore_at is not None:
            assert restore_at > fail_at
            evs.append(FaultEvent(restore_at, RESTORE, pod))
        return FaultPlan(evs)

    @staticmethod
    def periodic(pods: Sequence[str], period_s: float, downtime_s: float,
                 start_s: float, horizon_s: float) -> "FaultPlan":
        """Round-robin rolling failures: every ``period_s`` starting at
        ``start_s`` the next pod in ``pods`` fails for ``downtime_s``."""
        assert period_s > 0 and 0 < downtime_s < period_s
        evs, i, t = [], 0, start_s
        while t < horizon_s:
            pod = pods[i % len(pods)]
            evs.append(FaultEvent(t, FAIL, pod))
            evs.append(FaultEvent(t + downtime_s, RESTORE, pod))
            i += 1
            t += period_s
        return FaultPlan(evs)

    @staticmethod
    def random_plan(pods: Sequence[str], n_faults: int, horizon_s: float,
                    downtime_s: float, seed: int = 0,
                    min_gap_s: float = 1.0) -> "FaultPlan":
        """Seeded random failures: ``n_faults`` fail/restore pairs at
        uniform times in ``[min_gap_s, horizon_s)``, pods drawn with
        replacement. Deterministic in ``seed``; a pod already down at its
        drawn fail time simply no-ops (fail is idempotent)."""
        rng = random.Random(seed)
        evs = []
        for _ in range(n_faults):
            t = min_gap_s + rng.random() * max(0.0, horizon_s - min_gap_s)
            pod = pods[rng.randrange(len(pods))]
            evs.append(FaultEvent(t, FAIL, pod))
            evs.append(FaultEvent(t + downtime_s, RESTORE, pod))
        return FaultPlan(evs)

    @staticmethod
    def correlated(pods: Sequence[str], at: float,
                   downtime_s: float) -> "FaultPlan":
        """Correlated multi-pod outage (one rack/zone): every pod in
        ``pods`` fails at the same instant and restores together."""
        evs = [FaultEvent(at, FAIL, p) for p in pods]
        evs += [FaultEvent(at + downtime_s, RESTORE, p) for p in pods]
        return FaultPlan(evs)

    @staticmethod
    def elastic(pod: str, out_at: float,
                in_at: Optional[float] = None) -> "FaultPlan":
        """Fleet resize: add ``pod`` at ``out_at``; optionally retire it
        again at ``in_at`` (its contents re-route like a failure, but it is
        accounted as a scale event, not a failover)."""
        evs = [FaultEvent(out_at, SCALE_OUT, pod)]
        if in_at is not None:
            assert in_at > out_at
            evs.append(FaultEvent(in_at, SCALE_IN, pod))
        return FaultPlan(evs)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded sim-time exponential backoff for aborted in-flight loads.

    A session whose load died with its pod waits ``delay(attempt)`` and
    re-issues against the key's new rendezvous owner; after
    ``max_retries`` aborts of the same key it stops retrying the cache
    path and bypasses to a direct DB read (never a stall-forever)."""
    base_s: float = 0.25
    factor: float = 2.0
    cap_s: float = 8.0
    max_retries: int = 4

    def delay(self, attempt: int) -> float:
        assert attempt >= 1
        return min(self.cap_s, self.base_s * self.factor ** (attempt - 1))


# ---------------------------------------------------------------------------
# Seed fault-tolerance idioms, ported to sim time (never time.monotonic())
# ---------------------------------------------------------------------------

class SimFailureInjector:
    """Sim-clock analogue of the training loop's
    :class:`~repro.distributed.fault_tolerance.FailureInjector`: fail the
    given pods once at given *sim times*. ``plan()`` renders the schedule
    as a :class:`FaultPlan` for the engine; ``due(now)`` drains events up
    to ``now`` for direct driving in tests."""

    def __init__(self, fail_at: Dict[float, str],
                 downtime_s: Optional[float] = None):
        self.schedule = sorted(fail_at.items())
        self.downtime_s = downtime_s
        self._fired: set = set()

    def plan(self) -> FaultPlan:
        evs = []
        for t, pod in self.schedule:
            evs.append(FaultEvent(t, FAIL, pod))
            if self.downtime_s is not None:
                evs.append(FaultEvent(t + self.downtime_s, RESTORE, pod))
        return FaultPlan(evs)

    def due(self, now: float) -> List[Tuple[float, str]]:
        out = [(t, pod) for t, pod in self.schedule
               if t <= now and t not in self._fired]
        self._fired.update(t for t, _ in out)
        return out


class SimStragglerDetector:
    """Sim-time straggler + heartbeat-timeout detection (the
    :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor` idiom
    with every wall-clock read replaced by the caller's sim ``now``).

    ``record(now, dt)`` feeds one observed load dwell; a dwell more than
    ``sigma`` standard deviations above the trailing-window mean is a
    straggler. ``healthy(now)`` is the heartbeat: false once ``timeout_s``
    of sim time passes without a recorded load."""

    def __init__(self, window: int = 50, sigma: float = 3.0,
                 timeout_s: Optional[float] = None, min_samples: int = 8):
        self.window = window
        self.sigma = sigma
        self.timeout_s = timeout_s
        self.min_samples = min_samples
        self.dwells: List[float] = []
        self.stragglers: List[Tuple[float, float]] = []   # (sim time, dwell)
        self.last_beat = 0.0

    def is_straggling(self, dt: float) -> bool:
        hist = self.dwells[-self.window:]
        if len(hist) < self.min_samples:
            return False
        mu = statistics.fmean(hist)
        sd = statistics.pstdev(hist) or 1e-9
        return dt > mu + self.sigma * sd

    def record(self, now: float, dt: float) -> bool:
        self.last_beat = now
        straggled = self.is_straggling(dt)
        if straggled:
            self.stragglers.append((now, dt))
        self.dwells.append(dt)
        return straggled

    def healthy(self, now: float) -> bool:
        if self.timeout_s is None:
            return True
        return (now - self.last_beat) < self.timeout_s


# ---------------------------------------------------------------------------
# GPT-driven cache recovery (re-warm now vs lazy refill), dual-policy shape
# ---------------------------------------------------------------------------

class RecoveryPolicy:
    """Decides, per hot key lost in a failover, ``"rewarm"`` (issue a
    background load onto the new rendezvous owner now) or ``"lazy"``
    (let the next demand access pay the DB load). Mirrors the
    admission/replication policy shape: a programmatic rule plus a
    natural-language ``describe()`` the GPT-driven path prompts with."""

    name = "base"
    rewarm_min: int = 4

    def decide(self, key: str, freq: int) -> str:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


class ThresholdRecovery(RecoveryPolicy):
    """Re-warm a lost key iff its sketch frequency reaches ``rewarm_min``
    — hot keys pay the failover DB load once, in the background, instead
    of per consumer; cold keys refill lazily (a background load for a key
    nobody re-reads is pure wasted pod bandwidth)."""

    name = "threshold"

    def __init__(self, rewarm_min: int = 4):
        assert rewarm_min >= 1
        self.rewarm_min = rewarm_min

    def decide(self, key, freq):
        return "rewarm" if freq >= self.rewarm_min else "lazy"

    def describe(self):
        return (f"threshold (re-warm NOW when the key's estimated frequency "
                f"is >= {self.rewarm_min}; otherwise refill lazily on the "
                "next demand access). A hot key left cold makes every "
                "consumer pay the failover DB load; a cold key re-warmed "
                "wastes the new owner's bandwidth.")


class LLMRecovery(RecoveryPolicy):
    """GPT-driven recovery: after a failover, the base rule's
    ``describe()`` text plus the sketch evidence are rendered into a
    prompt (``prompts.recovery_decision_prompt``) and the LLM answers
    rewarm/lazy per lost hot key. Graded against the programmatic
    decision; unparseable completions fall back to it. Token cost
    accumulates off the critical path (failover handling is background
    work), surfaced as ``recovery_tokens`` in the episode metrics."""

    def __init__(self, base: RecoveryPolicy, llm, few_shot: bool = True):
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.name = f"llm-{base.name}"
        self.rewarm_min = base.rewarm_min
        self.llm_total = 0
        self.llm_correct = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0
        # resilience fallbacks to the programmatic base (ungraded): garbled
        # prompt/completion vs endpoint pool down (ISSUE 9)
        self.parse_fallbacks = 0
        self.degraded = 0
        self._top_json = "[]"            # evidence block, set per failover

    def describe(self):
        return self.base.describe()

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def set_evidence(self, top: List[Tuple[str, int]]) -> None:
        self._top_json = json.dumps([{"key": k, "freq": f} for k, f in top])

    def decide(self, key, freq):
        from repro.core.endpoints import LLMUnavailableError
        from repro.core.prompts import LLMParseError, parse_json_tail, \
            recovery_decision_prompt
        prompt = recovery_decision_prompt(
            self.base.describe(), key, freq, self.base.rewarm_min,
            self._top_json, self.few_shot)
        expected = self.base.decide(key, freq)
        try:
            completion = self.llm.complete(prompt)
        except LLMUnavailableError:
            # endpoint pool down: programmatic twin, ungraded (the router
            # already billed the wasted retry tokens)
            self.degraded += 1
            return expected
        except LLMParseError:
            self.parse_fallbacks += 1
            self.prompt_tokens += len(prompt) // 4
            return expected
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(completion) // 4
        try:
            raw = parse_json_tail(completion)
            decision = raw.get("decision") if isinstance(raw, dict) else None
        except ValueError:
            decision = None
        if decision not in ("rewarm", "lazy"):
            # garbled/meaningless completion: programmatic twin, ungraded
            self.parse_fallbacks += 1
            return expected
        self.llm_total += 1
        self.llm_correct += int(decision == expected)
        return decision


def make_recovery(*, impl: str = "python", llm=None, few_shot: bool = True,
                  rewarm_min: int = 4) -> RecoveryPolicy:
    """Build a recovery policy; ``impl="llm"`` wraps the threshold rule in
    the GPT-driven path (requires an ``llm`` with ``complete()``)."""
    base = ThresholdRecovery(rewarm_min=rewarm_min)
    if impl == "llm":
        assert llm is not None, "LLM-driven recovery needs an llm backend"
        return LLMRecovery(base, llm, few_shot=few_shot)
    return base


# ---------------------------------------------------------------------------
# Autoscaler: scale_out/in from the PR-4 backlog/EWMA queueing signals
# ---------------------------------------------------------------------------

class BacklogAutoscaler:
    """Open-loop fleet sizing on the contention layer's queueing signals.

    Polled by the scheduler at ``check_every_s`` sim-time boundaries (like
    replication epochs: background bookkeeping, no session clock charged).
    ``decide(now, backlogs)`` looks at the mean demand backlog (seconds of
    queued service) across live pods:

    * mean backlog > ``high_backlog_s``  -> ``"scale_out"`` (add a pod);
    * mean backlog < ``low_backlog_s`` AND this scaler previously added
      pods -> ``"scale_in"`` (retire the most recent addition — the
      initial fleet is never scaled away, so session home pods and the
      rendezvous baseline stay intact);
    * otherwise hold.

    ``cooldown_s`` of sim time must pass between actions (a membership
    change invalidates the very signal that triggered it: the reshuffled
    keys demand-load against their new owners, inflating backlog for a
    while — reacting to that echo would flap).

    **Warm-up-aware gate** (``warmup_aware=True``, closing the PR-6
    open-loop follow-up): a scale_out is only worth paying when the
    surge is predicted to outlive the warm-up of the pod it adds — the
    rendezvous reshuffle forces ~1/(n+1) of resident keys to re-warm via
    demand loads, and a short burst ends before the new pod serves a
    single warm hit, so the fleet pays the reshuffle twice (out AND in).
    The gate uses observed surge persistence as the surge-length
    predictor: the backlog must have stayed above ``high_backlog_s`` for
    at least ``warmup_margin x rewarm_cost_s`` contiguous seconds
    (``rewarm_cost_s`` is the engine's prediction, passed per decision)
    before a scale_out fires; gated checks are counted in ``deferred``.
    Default OFF — the PR-6 naive policy, digest-locked, is unchanged."""

    def __init__(self, check_every_s: float = 20.0,
                 high_backlog_s: float = 1.5, low_backlog_s: float = 0.2,
                 max_extra: int = 2, cooldown_s: float = 60.0,
                 warmup_aware: bool = False, warmup_margin: float = 1.0):
        assert check_every_s > 0 and high_backlog_s > low_backlog_s >= 0.0
        assert warmup_margin >= 0.0
        self.check_every_s = check_every_s
        self.high_backlog_s = high_backlog_s
        self.low_backlog_s = low_backlog_s
        self.max_extra = max_extra
        self.cooldown_s = cooldown_s
        self.warmup_aware = warmup_aware
        self.warmup_margin = warmup_margin
        self.next_check = check_every_s
        self.added: List[str] = []       # pods this scaler added (LIFO)
        self.last_action_at = -1e18
        self.decisions: List[Tuple[float, str]] = []
        self.surge_since: Optional[float] = None  # backlog-high onset
        self.deferred = 0                # scale_outs the warm-up gate held

    def decide(self, now: float, backlogs: Dict[str, float],
               rewarm_cost_s: float = 0.0) -> Optional[str]:
        # surge-age tracking runs on every check (even inside cooldown):
        # persistence is a property of the signal, not of our actions
        mean = (sum(backlogs.values()) / len(backlogs)) if backlogs else 0.0
        if backlogs and mean > self.high_backlog_s:
            if self.surge_since is None:
                self.surge_since = now
        else:
            self.surge_since = None
        if now - self.last_action_at < self.cooldown_s or not backlogs:
            return None
        if mean > self.high_backlog_s and len(self.added) < self.max_extra:
            if self.warmup_aware:
                age = now - self.surge_since
                if age < self.warmup_margin * rewarm_cost_s:
                    self.deferred += 1
                    return None
            return SCALE_OUT
        if mean < self.low_backlog_s and self.added:
            return SCALE_IN
        return None

    def note_action(self, now: float, action: str, pod: str) -> None:
        self.last_action_at = now
        self.decisions.append((now, action))
        if action == SCALE_OUT:
            self.added.append(pod)
        elif action == SCALE_IN and pod in self.added:
            self.added.remove(pod)

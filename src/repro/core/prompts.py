"""Prompt templates for GPT-driven cache operations (paper Fig. 2).

Two prompt-based decisions:
  * READ  — given the user query and current cache contents, choose
            ``read_cache(key)`` vs ``load_db(key)`` per required key.
  * UPDATE — the eviction policy is *described in natural language*; the LLM
            is given this round's loads + cache contents (JSON) and returns
            the updated cache state.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence


class LLMParseError(ValueError):
    """A prompt or completion could not be parsed into a decision.

    Subclasses ``ValueError`` so every existing generic decision handler
    (and ``ToolRegistry.call``'s error surface) keeps catching it; typed so
    the ``LLM*`` policy wrappers can uniformly fall back to their
    programmatic twin and count the fallback."""


SYSTEM_HEADER = (
    "As a Copilot handling geospatial data, you have access to the following "
    "tools [...]\n"
    " - load_db(key): load imagery metadata for `dataset-year` from the "
    "remote database (slow)\n"
    " - read_cache(key): read imagery metadata for `dataset-year` from the "
    "local cache (fast; fails if the key is not cached)\n"
)

READ_FEWSHOT = """Example 1:
Query: Plot the xview1 images from 2022
Cache: {}
Thought: The user asks for the xview1-2022 imagery. The cache is empty, so I must go to the database.
Action: To complete the task I will call load_db(xview1-2022), then plot the results.

Example 2:
Query: Show fair1m and xview1 imgs from 2022
Cache: {"xview1-2022": {...}}
Thought: The user wants both fair1m-2022 and xview1-2022. The cache already contains the latter, so only fair1m must come from the database.
Action: To complete the task I will first call load_db(fair1m-2022), then read_cache(xview1-2022).
"""


def read_decision_prompt(query: str, required_keys: Sequence[str],
                         cache_json: str, few_shot: bool) -> str:
    parts = [SYSTEM_HEADER]
    if few_shot:
        parts.append(READ_FEWSHOT)
    parts.append(
        "Given the user query, the cache content, and the examples above, "
        "decide for EACH required data key whether to call read_cache(key) "
        "or load_db(key). Respond with a JSON object mapping each key to "
        "\"read_cache\" or \"load_db\".\n")
    parts.append(f"User Query: {query}\n")
    parts.append(f"Required keys: {json.dumps(sorted(required_keys))}\n")
    parts.append(f"Cache: {cache_json}\n")
    parts.append("Answer (JSON): ")
    return "".join(parts)


def update_decision_prompt(policy_text: str, loads: Sequence[str],
                           cache_json: str, capacity: int,
                           few_shot: bool) -> str:
    parts = [SYSTEM_HEADER,
             "You are now the cache controller. Apply the cache update "
             "policy below and return the NEW cache state as a JSON list of "
             f"keys (at most {capacity} entries).\n",
             f"Update policy: {policy_text}\n"]
    if few_shot:
        parts.append(
            'Example: policy=LRU, capacity=2, cache={"a": {"last_access": 1},'
            ' "b": {"last_access": 5}}, this round loaded ["c"].\n'
            'Thought: the cache is full; "a" is least recent; evict "a".\n'
            'Answer: ["b", "c"]\n')
    parts.append(f"Current cache: {cache_json}\n")
    parts.append(f"Keys loaded from the database this round: "
                 f"{json.dumps(list(loads))}\n")
    parts.append("Answer (JSON list of keys): ")
    return "".join(parts)


ADMISSION_FEWSHOT = """Example 1:
Admission policy: TinyLFU (admit only if the candidate's frequency is STRICTLY HIGHER than the victim's).
Candidate key: fair1m-2021 (estimated frequency: 4)
Eviction victim if admitted: modis-2016 (estimated frequency: 1)
Thought: the candidate is clearly hotter than the victim, so caching it is worth an eviction.
Answer: {"decision": "admit"}

Example 2:
Admission policy: TinyLFU (admit only if the candidate's frequency is STRICTLY HIGHER than the victim's).
Candidate key: naip-2018 (estimated frequency: 1)
Eviction victim if admitted: xview1-2022 (estimated frequency: 6)
Thought: a one-shot key must not churn out a hot resident; stream it through instead.
Answer: {"decision": "bypass"}
"""


def admission_decision_prompt(policy_text: str, key: str, victim: str,
                              key_freq: int, victim_freq: int,
                              cache_json: str, few_shot: bool,
                              home_demand_json: Optional[str] = None) -> str:
    """Prompt for the GPT-driven admission decision: given the admission
    policy in natural language plus the frequency-sketch estimates, decide
    whether to ADMIT the candidate into the cache (evicting the victim) or
    BYPASS it (serve the data through without caching).

    ``home_demand_json`` (only rendered when provided — the locality-free
    prompt stays byte-identical) exposes the candidate's remote consumer
    demand by home pod, so a locality-aware LLM can weigh WHO is paying
    cross-pod hops for the key."""
    parts = [SYSTEM_HEADER,
             "You are now the cache admission controller. A key was just "
             "loaded from the database and the cache is FULL. Apply the "
             "admission policy below and decide whether to ADMIT the "
             "candidate into the cache (evicting the victim) or BYPASS the "
             "cache (the data is served to the caller but nothing is "
             "cached and no resident is evicted).\n",
             f"Admission policy: {policy_text}\n"]
    if few_shot:
        parts.append(ADMISSION_FEWSHOT)
    parts.append(f"Current cache: {cache_json}\n")
    parts.append(f"Candidate key: {key} (estimated frequency: {key_freq})\n")
    parts.append(f"Eviction victim if admitted: {victim} "
                 f"(estimated frequency: {victim_freq})\n")
    if home_demand_json is not None:
        parts.append("Remote consumer demand for the candidate (reads "
                     "paying a cross-pod hop, by consumer home pod): "
                     f"{home_demand_json}\n")
    parts.append('Respond with a JSON object: {"decision": "admit"} or '
                 '{"decision": "bypass"}.\n')
    parts.append("Answer (JSON): ")
    return "".join(parts)


REPLICATION_FEWSHOT = """Example 1:
Replication policy: threshold (replicate when frequency >= 8; drop a replica when frequency < 4).
Key: xview1-2022 (estimated frequency: 11; currently replicated: no)
Thought: the key is clearly above the promote threshold, so pushing copies to every pod converts its remote joins into local hits.
Answer: {"decision": "replicate"}

Example 2:
Replication policy: threshold (replicate when frequency >= 8; drop a replica when frequency < 4).
Key: modis-2016 (estimated frequency: 6; currently replicated: yes)
Thought: the key cooled below the promote threshold but is still above the demote threshold — inside the hysteresis band, keep the replicas (no flapping).
Answer: {"decision": "hold"}

Example 3:
Replication policy: threshold (replicate when frequency >= 8; drop a replica when frequency < 4).
Key: naip-2018 (estimated frequency: 2; currently replicated: yes)
Thought: the key fell below the demote threshold; its replicas now waste capacity other keys could use.
Answer: {"decision": "drop"}
"""


def replication_decision_prompt(policy_text: str, key: str, freq: int,
                                replicated: bool, promote_min: int,
                                demote_min: int, top_json: str,
                                few_shot: bool,
                                home_demand_json: Optional[str] = None,
                                ) -> str:
    """Prompt for the GPT-driven hot-key replication decision: given the
    replication policy in natural language, the key's sketch estimate, and
    whether it is currently replicated, decide REPLICATE (push a copy to
    every pod), DROP (remove its replicas) or HOLD (change nothing).

    ``home_demand_json`` (only rendered when provided — the locality-free
    prompt stays byte-identical) exposes the key's remote consumer demand
    by home pod: under a cross-pod read penalty, that is exactly the
    evidence that says WHERE a copy converts penalized hops into pod-local
    hits."""
    parts = [SYSTEM_HEADER,
             "You are now the cache REPLICATION controller of a pod-sharded "
             "deployment. Each key's data is cached on exactly one owner "
             "pod; SUPER-HOT keys can additionally be replicated to every "
             "pod, converting other pods' remote joins into local hits at "
             "the cost of cache capacity on each pod. Apply the replication "
             "policy below to ONE key.\n",
             f"Replication policy: {policy_text}\n"]
    if few_shot:
        parts.append(REPLICATION_FEWSHOT)
    parts.append(f"Hottest keys right now (frequency sketch): {top_json}\n")
    parts.append(f"Key: {key} (estimated frequency: {freq}; currently "
                 f"replicated: {'yes' if replicated else 'no'})\n")
    if home_demand_json is not None:
        parts.append("Remote consumer demand for the key (reads paying a "
                     "cross-pod hop, by consumer home pod): "
                     f"{home_demand_json}\n")
    parts.append(f"Thresholds: replicate at >= {promote_min}; drop a "
                 f"replica at < {demote_min}; otherwise hold.\n")
    parts.append('Respond with a JSON object: {"decision": "replicate"}, '
                 '{"decision": "drop"} or {"decision": "hold"}.\n')
    parts.append("Answer (JSON): ")
    return "".join(parts)


RECOVERY_FEWSHOT = """Example 1:
Recovery policy: threshold (re-warm NOW when the key's estimated frequency is >= 4; otherwise refill lazily on the next demand access).
Lost key: xview1-2022 (estimated frequency: 9)
Thought: the key is clearly hot — every consumer would pay the failover DB load; one background re-warm onto the new owner pays it once.
Answer: {"decision": "rewarm"}

Example 2:
Recovery policy: threshold (re-warm NOW when the key's estimated frequency is >= 4; otherwise refill lazily on the next demand access).
Lost key: naip-2018 (estimated frequency: 1)
Thought: a near-cold key may never be read again — a background load for it would only waste the new owner's bandwidth.
Answer: {"decision": "lazy"}
"""


def recovery_decision_prompt(policy_text: str, key: str, freq: int,
                             rewarm_min: int, top_json: str,
                             few_shot: bool) -> str:
    """Prompt for the GPT-driven post-failover recovery decision: a pod
    just died and ``key`` was resident in its cache (now lost; its key
    range re-routed to a new owner pod). Decide REWARM (issue a background
    DB load onto the new owner now, so consumers find it warm) or LAZY
    (let the next demand access pay the load)."""
    parts = [SYSTEM_HEADER,
             "You are now the cache RECOVERY controller of a pod-sharded "
             "deployment. A pod just FAILED: its cached keys are lost and "
             "their key ranges re-routed to the surviving pods. For ONE "
             "lost key, decide whether to RE-WARM it now (issue one "
             "background database load onto its new owner pod) or refill "
             "it LAZILY (the next session that needs it pays the database "
             "load on demand). Apply the recovery policy below.\n",
             f"Recovery policy: {policy_text}\n"]
    if few_shot:
        parts.append(RECOVERY_FEWSHOT)
    parts.append(f"Hottest keys right now (frequency sketch): {top_json}\n")
    parts.append(f"Lost key: {key} (estimated frequency: {freq})\n")
    parts.append(f"Threshold: re-warm at >= {rewarm_min}; otherwise lazy.\n")
    parts.append('Respond with a JSON object: {"decision": "rewarm"} or '
                 '{"decision": "lazy"}.\n')
    parts.append("Answer (JSON): ")
    return "".join(parts)


COHERENCE_FEWSHOT = """Example 1:
Coherence policy: serve a stale cached copy while its staleness is at most 20 seconds; refresh now once the staleness exceeds 20 seconds.
Key: xview1-2022 (staleness: 7.5s; staleness bound: 20s; estimated frequency: 9)
Thought: the copy lags the store by well under the bound — serving it keeps the hot read stream off the database, and the contract still holds.
Answer: {"decision": "serve_stale"}

Example 2:
Coherence policy: serve a stale cached copy while its staleness is at most 20 seconds; refresh now once the staleness exceeds 20 seconds.
Key: modis-2016 (staleness: 31.2s; staleness bound: 20s; estimated frequency: 2)
Thought: the copy is past the declared bound; serving it would break the freshness contract — pay the reload now.
Answer: {"decision": "refresh"}
"""


def coherence_decision_prompt(policy_text: str, key: str, staleness_s: float,
                              bound_s: float, freq: int,
                              few_shot: bool) -> str:
    """Prompt for the GPT-driven ``cache_update`` decision (ISSUE 8): the
    datastore has newer data for ``key`` than the cached copy a session is
    about to consume. Decide REFRESH (reload from the database now — the
    reader pays the load) or SERVE_STALE (serve the lagging copy, allowed
    only within the policy's declared staleness bound — the engine clamps
    anything beyond it)."""
    parts = [SYSTEM_HEADER,
             "You are now the cache COHERENCE controller. The database was "
             "UPDATED after the cached copy of ONE key was installed, so "
             "the copy is stale by the staleness shown below. Decide "
             "whether the session about to consume it should REFRESH "
             "(reload from the database now, paying the load) or "
             "SERVE_STALE (use the lagging copy — permitted only while its "
             "staleness is within the declared bound). Apply the coherence "
             "policy below.\n",
             f"Coherence policy: {policy_text}\n"]
    if few_shot:
        parts.append(COHERENCE_FEWSHOT)
    parts.append(f"Key: {key} (staleness: {staleness_s:.1f}s; staleness "
                 f"bound: {bound_s:g}s; estimated frequency: {freq})\n")
    parts.append(f'Evidence (JSON): {{"staleness_s": {staleness_s:.3f}, '
                 f'"bound_s": {bound_s:g}}}\n')
    parts.append('Respond with a JSON object: {"decision": "refresh"} or '
                 '{"decision": "serve_stale"}.\n')
    parts.append("Answer (JSON): ")
    return "".join(parts)


PLAN_CACHE_FEWSHOT = """Example 1:
Plan-cache policy: TTL + frequency (a cached plan expires 180 seconds after install; CACHE a new plan only if its request frequency is at least 1 and, when the cache is full, at least the evicted plan's frequency).
Candidate plan: detect>plot#2 (estimated frequency: 5)
Eviction victim if cached: count>vqa#1 (estimated frequency: 1)
Thought: the candidate template is requested far more often than the coldest resident — caching it converts repeated planning rounds into lookups.
Answer: {"decision": "cache"}

Example 2:
Plan-cache policy: TTL + frequency (a cached plan expires 180 seconds after install; CACHE a new plan only if its request frequency is at least 1 and, when the cache is full, at least the evicted plan's frequency).
Candidate plan: timeseries#1 (estimated frequency: 1)
Eviction victim if cached: detect>lcc>plot#3 (estimated frequency: 7)
Thought: a one-shot plan must not displace a frequently replayed one; let this request stream through.
Answer: {"decision": "bypass"}
"""


def plan_cache_decision_prompt(policy_text: str, template: str,
                               victim_template: str, freq: int,
                               victim_freq: int, ttl_s: float,
                               few_shot: bool) -> str:
    """Prompt for the GPT-driven PLAN-CACHE admission decision (ISSUE 10):
    a planning round just completed for a (task template, context digest)
    request and the plan cache is FULL. Decide CACHE (store the fresh plan,
    evicting the least-recently-used resident) or BYPASS (serve this
    request's plan without storing it)."""
    parts = [SYSTEM_HEADER,
             "You are now the PLAN-CACHE controller. The agent just paid a "
             "full LLM planning round for the task template below and the "
             "plan cache is FULL. A cached plan is served verbatim to every "
             "later request with the same template over the same data-key "
             "versions, skipping that request's planning round entirely. "
             "Apply the plan-cache policy below and decide whether to "
             "CACHE the fresh plan (evicting the victim) or BYPASS the "
             "cache (the plan is used once and not stored).\n",
             f"Plan-cache policy: {policy_text}\n"]
    if few_shot:
        parts.append(PLAN_CACHE_FEWSHOT)
    parts.append(f"Candidate plan: {template} "
                 f"(estimated frequency: {freq})\n")
    parts.append(f"Eviction victim if cached: {victim_template} "
                 f"(estimated frequency: {victim_freq})\n")
    parts.append(f"Entry time-to-live: {ttl_s:g}s\n")
    parts.append('Respond with a JSON object: {"decision": "cache"} or '
                 '{"decision": "bypass"}.\n')
    parts.append("Answer (JSON): ")
    return "".join(parts)


def parse_json_tail(text: str):
    """Parse the trailing JSON object/list from an LLM completion."""
    text = text.strip()
    for start in range(len(text)):
        if text[start] in "[{":
            try:
                return json.loads(text[start:])
            except json.JSONDecodeError:
                continue
    raise LLMParseError(f"no JSON found in completion: {text[:200]!r}")

"""Simulated GPT endpoint pool: fault schedules, routing, and degradation.

The paper's decision plane runs on "hundreds of GPT endpoints"; until now
our simulated GPT was perfectly reliable. This module makes the *decision*
plane failure-prone the same way ``core.faults`` made the data pods fail:

- ``EndpointFaultPlan`` — a deterministic sim-time schedule of endpoint
  fault windows (outages, rate-limit regimes with a retry-after hint,
  straggler slowdown multipliers, malformed-response injection), with
  ``single``/``periodic``/``random_plan``/``correlated`` generators
  mirroring ``FaultPlan`` and fail-fast validation at construction.
- ``EndpointRouter`` — owns every routed ``SimLLM.complete()`` call:
  per-call endpoint selection (blind to liveness, like a real client),
  bounded sim-time exponential backoff with jitter (``RetryPolicy``),
  optional hedged requests (second request to a *different* endpoint after
  an EWMA-p95 hedge delay, first wins, the loser's tokens are still
  charged), and a per-endpoint circuit breaker (closed / open / half-open).
- ``RoutedLLM`` — wraps a ``SimLLM`` so cache-op decision calls pass an
  admission gate first; when retries exhaust or every breaker is open it
  raises ``LLMUnavailableError`` and the ``LLM*`` policy wrappers fall
  back to their programmatic twins (degraded mode). Planning rounds never
  raise: they pay the full retry latency on the session clock and, during
  a total blackout, jump to the analytically-known next-available instant
  — structural never-stall-forever, like PR 6.

Degeneracy contract: an **empty** plan must replay the router-free engine
bit-identically. The router draws from a private RNG, adds exactly 0.0
latency when no window is active, and hedging/breaker are opt-in, so the
only observable difference is the router's own counters.

Timing is sim-time only: nothing here reads a wall clock.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.faults import RetryPolicy

# Endpoint fault actions. *_END closes the matching window; a start without
# an end is an open-ended window (allowed per endpoint, but a plan that
# leaves the whole pool permanently dead is rejected by the router).
OUTAGE = "outage"
RESTORE = "restore"
LIMIT = "limit"
LIMIT_END = "limit_end"
SLOW = "slow"
SLOW_END = "slow_end"
MALFORM = "malform"
MALFORM_END = "malform_end"

ACTIONS = (OUTAGE, RESTORE, LIMIT, LIMIT_END,
           SLOW, SLOW_END, MALFORM, MALFORM_END)
_ACTION_ORDER = {a: i for i, a in enumerate(ACTIONS)}

# (start-action, end-action) pairs per window kind
_WINDOW_KINDS = ((OUTAGE, RESTORE), (LIMIT, LIMIT_END),
                 (SLOW, SLOW_END), (MALFORM, MALFORM_END))


class LLMUnavailableError(RuntimeError):
    """No endpoint could serve a cache-op decision within the retry budget.

    Deliberately *not* a ``ValueError``: the generic decision-parse
    handlers must not swallow it — the ``LLM*`` wrappers catch it
    explicitly and fall back to their programmatic twin (ungraded)."""


@dataclasses.dataclass(frozen=True)
class EndpointFaultEvent:
    at: float
    action: str
    endpoint: str
    value: float = 0.0  # retry-after (limit) / multiplier (slow) / p (malform)

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown endpoint action {self.action!r}")
        if not (self.at >= 0.0 and math.isfinite(self.at)):
            raise ValueError(f"event time must be finite and >= 0: {self.at}")
        if self.action == LIMIT and not self.value > 0.0:
            raise ValueError(f"limit needs retry_after_s > 0, got {self.value}")
        if self.action == SLOW and not self.value >= 1.0:
            raise ValueError(f"slow needs multiplier >= 1, got {self.value}")
        if self.action == MALFORM and not 0.0 < self.value <= 1.0:
            raise ValueError(f"malform needs p in (0, 1], got {self.value}")
        if self.action not in (LIMIT, SLOW, MALFORM) and self.value != 0.0:
            raise ValueError(f"{self.action} takes no value, got {self.value}")


class EndpointFaultPlan:
    """A deterministic sim-time schedule of endpoint fault windows.

    Events are kept sorted by ``(at, action-order, endpoint)`` so injection
    order is independent of construction order. Start/end events are paired
    into per-endpoint windows at construction and validated fail-fast:
    an end without a matching start, or two overlapping starts of the same
    kind on one endpoint, raise ``ValueError``. An empty plan is falsy and
    must replay the router-free engine bit-identically."""

    def __init__(self, events: Sequence[EndpointFaultEvent] = ()):
        self.events: List[EndpointFaultEvent] = sorted(
            events, key=lambda e: (e.at, _ACTION_ORDER[e.action], e.endpoint))
        # windows[kind][endpoint] -> [(start, end_or_inf, value), ...]
        self.windows: Dict[str, Dict[str, List[Tuple[float, float, float]]]] \
            = {start: {} for start, _ in _WINDOW_KINDS}
        for start, end in _WINDOW_KINDS:
            table = self.windows[start]
            open_at: Dict[str, Tuple[float, float]] = {}
            for ev in self.events:
                if ev.action == start:
                    if ev.endpoint in open_at:
                        raise ValueError(
                            f"overlapping {start!r} windows on {ev.endpoint}")
                    open_at[ev.endpoint] = (ev.at, ev.value)
                elif ev.action == end:
                    if ev.endpoint not in open_at:
                        raise ValueError(
                            f"{end!r} at t={ev.at} without an open "
                            f"{start!r} window on {ev.endpoint}")
                    s, v = open_at.pop(ev.endpoint)
                    if not ev.at > s:
                        raise ValueError(
                            f"empty {start!r} window on {ev.endpoint} "
                            f"[{s}, {ev.at})")
                    table.setdefault(ev.endpoint, []).append((s, ev.at, v))
            for ep, (s, v) in open_at.items():  # open-ended windows
                table.setdefault(ep, []).append((s, math.inf, v))
            for wins in table.values():
                wins.sort()

    @property
    def endpoints(self):
        return sorted({e.endpoint for e in self.events})

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __iter__(self):
        return iter(self.events)

    def __add__(self, other: "EndpointFaultPlan") -> "EndpointFaultPlan":
        return EndpointFaultPlan(self.events + list(other))

    def __repr__(self) -> str:
        return f"EndpointFaultPlan({self.events!r})"

    # -- parametric generators ------------------------------------------------
    @staticmethod
    def single(endpoint: str, at: float, until: Optional[float] = None,
               kind: str = OUTAGE, value: float = 0.0) -> "EndpointFaultPlan":
        """One fault window of ``kind`` on one endpoint; ``until=None``
        leaves it open-ended."""
        starts = dict(_WINDOW_KINDS)
        if kind not in starts:
            raise ValueError(f"unknown window kind {kind!r}")
        evs = [EndpointFaultEvent(at, kind, endpoint, value)]
        if until is not None:
            evs.append(EndpointFaultEvent(until, starts[kind], endpoint))
        return EndpointFaultPlan(evs)

    @staticmethod
    def correlated(endpoints: Sequence[str], at: float,
                   downtime_s: float) -> "EndpointFaultPlan":
        """Correlated blackout (one region/provider incident): every listed
        endpoint goes down at the same instant and restores together."""
        evs = [EndpointFaultEvent(at, OUTAGE, e) for e in endpoints]
        evs += [EndpointFaultEvent(at + downtime_s, RESTORE, e)
                for e in endpoints]
        return EndpointFaultPlan(evs)

    @staticmethod
    def periodic(endpoints: Sequence[str], period_s: float, downtime_s: float,
                 start_s: float, horizon_s: float) -> "EndpointFaultPlan":
        """Round-robin rolling outages: every ``period_s`` starting at
        ``start_s`` the next endpoint goes down for ``downtime_s``."""
        assert period_s > 0 and 0 < downtime_s < period_s
        evs, i, t = [], 0, start_s
        while t < horizon_s:
            ep = endpoints[i % len(endpoints)]
            evs.append(EndpointFaultEvent(t, OUTAGE, ep))
            evs.append(EndpointFaultEvent(t + downtime_s, RESTORE, ep))
            i += 1
            t += period_s
        return EndpointFaultPlan(evs)

    @staticmethod
    def random_plan(endpoints: Sequence[str], n_faults: int, horizon_s: float,
                    downtime_s: float, seed: int = 0,
                    min_gap_s: float = 1.0) -> "EndpointFaultPlan":
        """Seeded random outages: ``n_faults`` outage/restore pairs at
        uniform times; a draw overlapping an existing window on the same
        endpoint is skipped (windows of one kind may not overlap)."""
        rng = random.Random(seed)
        taken: Dict[str, List[Tuple[float, float]]] = {}
        evs = []
        for _ in range(n_faults):
            t = min_gap_s + rng.random() * max(0.0, horizon_s - min_gap_s)
            ep = endpoints[rng.randrange(len(endpoints))]
            span = (t, t + downtime_s)
            if any(s < span[1] and span[0] < e
                   for s, e in taken.get(ep, ())):
                continue
            taken.setdefault(ep, []).append(span)
            evs.append(EndpointFaultEvent(t, OUTAGE, ep))
            evs.append(EndpointFaultEvent(t + downtime_s, RESTORE, ep))
        return EndpointFaultPlan(evs)

    @staticmethod
    def outage_straggler(endpoints: Sequence[str], horizon_s: float,
                         start_s: float = 15.0, outage_s: float = 10.0,
                         stagger_s: float = 25.0,
                         slowdown: float = 8.0) -> "EndpointFaultPlan":
        """The headline mixed regime: staggered finite outages roll across
        all endpoints but the last, while the last endpoint straggles at
        ``slowdown``x for the whole horizon (a bad replica that answers,
        slowly — the case retries alone cannot fix)."""
        assert len(endpoints) >= 2, "need a straggler plus at least one more"
        evs = [EndpointFaultEvent(5.0, SLOW, endpoints[-1], slowdown),
               EndpointFaultEvent(horizon_s, SLOW_END, endpoints[-1])]
        t, i = start_s, 0
        while t + outage_s < horizon_s and i < len(endpoints) - 1:
            evs.append(EndpointFaultEvent(t, OUTAGE, endpoints[i]))
            evs.append(EndpointFaultEvent(t + outage_s, RESTORE, endpoints[i]))
            i += 1
            t += stagger_s
        return EndpointFaultPlan(evs)


# Circuit-breaker states (per endpoint, derived from open-timestamp + now)
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class EndpointRouter:
    """Routes every decision-plane request across a pool of N endpoints.

    Two request classes share the pool but differ in failure semantics:

    - ``plan_call`` (planning rounds): must complete. Failed attempts pay
      fail-fast detection plus jittered exponential backoff on the session
      clock; when the retry budget exhausts during a total blackout the
      call waits to the analytically-known next-available instant (finite
      by construction) and restarts the budget. Optional hedging launches
      a second request on a different endpoint once the primary has been
      in flight for an EWMA-p95 delay; first answer wins, the loser's
      tokens are still charged.
    - ``decision_call`` (cache-op decisions: admit / replicate / recover /
      cache-update / read-plan): latency-free, so a failure cannot be
      waited out — after the retry budget (or instantly, when every
      breaker is open) the call raises ``LLMUnavailableError`` and the
      caller degrades to its programmatic twin.

    The per-endpoint circuit breaker (opt-in) trips after
    ``breaker_threshold`` consecutive bad signals (failed attempts, lost
    hedges, malformed replies), rejects the endpoint while open, and
    half-opens one probe after ``breaker_cooldown_s``."""

    def __init__(self, n_endpoints: int = 4,
                 plan: Optional[EndpointFaultPlan] = None, seed: int = 0,
                 retry: Optional[RetryPolicy] = None,
                 hedge: bool = False, breaker: bool = False,
                 hedge_min_s: float = 0.25, hedge_z: float = 1.645,
                 hedge_alpha: float = 0.2, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 20.0,
                 fail_fast_s: float = 0.05):
        if n_endpoints < 1:
            raise ValueError(f"need at least one endpoint, got {n_endpoints}")
        if breaker_threshold < 1:
            raise ValueError(f"breaker_threshold must be >= 1")
        if breaker_cooldown_s <= 0 or fail_fast_s <= 0 or hedge_min_s <= 0:
            raise ValueError("breaker_cooldown_s / fail_fast_s / hedge_min_s "
                             "must be positive")
        self.names = [f"ep{i}" for i in range(n_endpoints)]
        self.plan = plan if plan is not None else EndpointFaultPlan()
        unknown = set(self.plan.endpoints) - set(self.names)
        if unknown:
            raise ValueError(f"plan names endpoints outside the pool: "
                             f"{sorted(unknown)} (pool {self.names})")
        # a pool that is permanently dead can never satisfy the
        # never-stall-forever contract — reject it up front
        outages = self.plan.windows[OUTAGE]
        if all(any(e == math.inf for _, e, _ in outages.get(n, ()))
               for n in self.names):
            raise ValueError("plan leaves every endpoint in an open-ended "
                             "outage: the pool would be permanently dead")
        self.retry = retry if retry is not None else RetryPolicy()
        self.hedge = hedge
        self.breaker = breaker
        self.hedge_min_s = hedge_min_s
        self.hedge_z = hedge_z
        self.hedge_alpha = hedge_alpha
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self.fail_fast_s = fail_fast_s
        self.rng = random.Random(f"{seed}|endpoints")
        self.now = 0.0
        # EWMA service-time moments feeding the hedge delay
        self._mu = 0.0
        self._var = 0.0
        self._obs = 0
        # breaker state: consecutive bad signals + open timestamp
        self._bad = {n: 0 for n in self.names}
        self._open_at: Dict[str, Optional[float]] = \
            {n: None for n in self.names}
        # counters (surfaced on EpisodeMetrics)
        self.plan_calls = 0
        self.decision_calls = 0
        self.read_checks = 0
        self.retries = 0
        self.hedges = 0
        self.hedge_wins = 0
        self.rate_limited = 0
        self.malformed = 0
        self.degraded = 0
        self.retry_tokens = 0
        self.breaker_opens = 0
        self.breaker_closes = 0
        self.fault_events = 0

    # -- analytic schedule queries (windows, not mutable state) --------------
    def _window(self, kind: str, ep: str, t: float) -> Optional[float]:
        for s, e, v in self.plan.windows[kind].get(ep, ()):
            if s <= t < e:
                return v
        return None

    def up(self, ep: str, t: float) -> bool:
        return self._window(OUTAGE, ep, t) is None

    def retry_after(self, ep: str, t: float) -> float:
        return self._window(LIMIT, ep, t) or 0.0

    def slow_mult(self, ep: str, t: float) -> float:
        v = self._window(SLOW, ep, t)
        return 1.0 if v is None else v

    def malform_p(self, ep: str, t: float) -> float:
        return self._window(MALFORM, ep, t) or 0.0

    def next_available(self, t: float) -> float:
        """Earliest instant >= t at which *some* endpoint is up. Finite for
        any valid plan (construction rejects a permanently dead pool)."""
        best = math.inf
        for ep in self.names:
            nxt = t
            for s, e, _ in self.plan.windows[OUTAGE].get(ep, ()):
                if s <= t < e:
                    nxt = e
                    break
            best = min(best, nxt)
        return best

    # -- circuit breaker -----------------------------------------------------
    def breaker_state(self, ep: str, t: float) -> str:
        if not self.breaker or self._open_at[ep] is None:
            return CLOSED
        if t - self._open_at[ep] >= self.breaker_cooldown_s:
            return HALF_OPEN
        return OPEN

    def _note_fail(self, ep: str, t: float) -> None:
        if not self.breaker:
            return
        if self.breaker_state(ep, t) == HALF_OPEN:
            self._open_at[ep] = t  # probe failed: re-open for a fresh cooldown
            self.breaker_opens += 1
            return
        self._bad[ep] += 1
        if self._bad[ep] >= self.breaker_threshold \
                and self._open_at[ep] is None:
            self._open_at[ep] = t
            self.breaker_opens += 1

    def _note_ok(self, ep: str, t: float) -> None:
        if not self.breaker:
            return
        if self._open_at[ep] is not None:  # successful half-open probe
            self.breaker_closes += 1
        self._open_at[ep] = None
        self._bad[ep] = 0

    # -- selection (blind to liveness, like a real client) -------------------
    def _candidates(self, t: float) -> List[str]:
        return [ep for ep in self.names if self.breaker_state(ep, t) != OPEN]

    def _pick(self, cands: Sequence[str],
              exclude: Optional[str] = None) -> str:
        pool = [c for c in cands if c != exclude] or list(cands)
        return pool[self.rng.randrange(len(pool))]

    # -- hedging -------------------------------------------------------------
    def _observe(self, service_s: float) -> None:
        if self._obs == 0:
            self._mu = service_s
        else:
            d = service_s - self._mu
            self._mu += self.hedge_alpha * d
            self._var += self.hedge_alpha * (d * d - self._var)
        self._obs += 1

    def _hedge_delay(self, nominal_s: float) -> float:
        # EWMA-p95: mean + z*sigma of observed round service times; until
        # the first observation the nominal itself (never hedges a healthy
        # first call)
        if self._obs == 0:
            base = nominal_s
        else:
            base = self._mu + self.hedge_z * math.sqrt(max(0.0, self._var))
        return max(self.hedge_min_s, base)

    # -- request classes -----------------------------------------------------
    def plan_call(self, t0: float, nominal_s: float,
                  tokens: int) -> Tuple[float, int, int, int, float]:
        """Route one planning round starting at ``t0`` whose fault-free
        service time ``nominal_s`` the caller has already paid. Returns
        ``(extra_s, retries, hedges, hedge_wins, wait_s)`` where
        ``extra_s`` is the additional session-clock latency (0.0 exactly
        when no fault window is active) and ``wait_s`` the part spent on
        detection/backoff/retry-after rather than inflated service."""
        self.plan_calls += 1
        t, extra, wait = t0, 0.0, 0.0
        retries = hedges = wins = 0
        attempt = 0
        while True:
            cands = self._candidates(t)
            if not cands:
                # every breaker open: planning must still complete, so the
                # client abandons breaker discipline and probes the pool
                cands = self.names
            ep = self._pick(cands)
            if not self.up(ep, t):
                attempt += 1
                retries += 1
                self.retries += 1
                self.retry_tokens += tokens  # the prompt was sent and lost
                self._note_fail(ep, t)
                if attempt > self.retry.max_retries:
                    # budget exhausted: wait out the blackout (finite by
                    # construction), then restart the budget
                    step = max(self.fail_fast_s, self.next_available(t) - t)
                    attempt = 0
                else:
                    d = self.retry.delay(attempt)
                    step = self.fail_fast_s + d * (0.5 + self.rng.random())
                extra += step
                wait += step
                t += step
                continue
            ra = self.retry_after(ep, t)
            if ra > 0.0:
                # 429 with a retry-after hint: honor it, then the same
                # endpoint's bucket has refilled
                retries += 1
                self.retries += 1
                self.rate_limited += 1
                extra += ra
                wait += ra
                t += ra
            service = nominal_s * self.slow_mult(ep, t)
            hedged_ok = False
            if self.hedge and len(self.names) > 1:
                delay = self._hedge_delay(nominal_s)
                if service > delay:
                    alt_cands = self._candidates(t) or self.names
                    alt = self._pick(alt_cands, exclude=ep)
                    if alt != ep and self.up(alt, t + delay):
                        hedges += 1
                        self.hedges += 1
                        self.retry_tokens += tokens  # loser is still billed
                        alt_service = (delay
                                       + nominal_s * self.slow_mult(alt, t + delay))
                        if alt_service < service:
                            wins += 1
                            self.hedge_wins += 1
                            self._note_fail(ep, t)  # lost its own hedge
                            self._note_ok(alt, t)
                            service = alt_service
                            hedged_ok = True
            if not hedged_ok:
                self._note_ok(ep, t)
            self._observe(service)
            extra += service - nominal_s
            return extra, retries, hedges, wins, wait

    def decision_call(self, prompt_chars: int) -> bool:
        """Route one latency-free cache-op decision at ``self.now``.

        Returns True when the chosen endpoint garbles the response (the
        caller must truncate it). Raises ``LLMUnavailableError`` when the
        retry budget exhausts or every breaker is open — the caller falls
        back to its programmatic twin."""
        self.decision_calls += 1
        t = self.now
        tokens = max(1, prompt_chars // 4)
        for _ in range(self.retry.max_retries + 1):
            cands = self._candidates(t)
            if not cands:
                break  # every breaker open: fail fast, nothing is sent
            ep = self._pick(cands)
            if not self.up(ep, t):
                self.retries += 1
                self.retry_tokens += tokens
                self._note_fail(ep, t)
                continue
            if self.retry_after(ep, t) > 0.0:
                # a latency-free decision cannot wait out a 429
                self.retries += 1
                self.rate_limited += 1
                continue
            mp = self.malform_p(ep, t)
            if mp > 0.0 and self.rng.random() < mp:
                self.malformed += 1
                self._note_fail(ep, t)  # garbled replies are breaker evidence
                return True
            self._note_ok(ep, t)
            return False
        self.degraded += 1
        raise LLMUnavailableError(
            f"no endpoint available for a decision at t={t:.3f}s")

    def decision_available(self) -> bool:
        """Cheap availability probe for the eps-simulated read path (the
        read plan rides the planning prompt; no separate request is sent).
        Counts a degraded decision when the pool cannot serve."""
        self.read_checks += 1
        t = self.now
        ok = any(self.up(ep, t) and self.retry_after(ep, t) == 0.0
                 for ep in self._candidates(t))
        if not ok:
            self.degraded += 1
        return ok

    def decision_serviceable(self) -> bool:
        """Pure twin of :meth:`decision_available`: same window logic, NO
        counter side effects. Used by the plan-cache hit path to burn the
        exact eps draws a fresh plan would have consumed — the skipped
        round must not perturb ``read_checks``/``degraded`` (and through
        them ``fallback_share``), or a hit would change the episode's
        decision-plane accounting."""
        t = self.now
        return any(self.up(ep, t) and self.retry_after(ep, t) == 0.0
                   for ep in self._candidates(t))

    # -- scheduler hook ------------------------------------------------------
    def apply(self, t: float, ev: EndpointFaultEvent) -> None:
        """PRI_FAULT bookkeeping: windows are analytic, so events only
        advance the router clock and count regime transitions."""
        self.now = t
        self.fault_events += 1

    @property
    def llm_calls(self) -> int:
        return self.plan_calls + self.decision_calls

    @property
    def fallback_share(self) -> float:
        denom = self.decision_calls + self.read_checks
        return self.degraded / denom if denom else 0.0


class RoutedLLM:
    """Wraps a ``SimLLM`` so every ``complete()`` is admitted by the
    router first. Truncates the completion when the router injects a
    malformed response (downstream JSON parsing then fails and the policy
    wrapper counts a parse fallback). Everything else — profile, rng,
    ``draw_*`` — delegates to the wrapped backend."""

    def __init__(self, llm, router: EndpointRouter):
        self._llm = llm
        self.router = router

    def complete(self, prompt: str) -> str:
        malform = self.router.decision_call(len(prompt))
        text = self._llm.complete(prompt)
        if malform:
            return text[:max(1, len(text) // 2)]
        return text

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_llm"), name)

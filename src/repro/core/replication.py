"""Cross-pod replication of super-hot keys (ROADMAP follow-up, ISSUE 4).

The pod-sharded cache places each key on exactly one owner pod; under heavy
multi-session traffic the hottest few keys serve a disproportionate share of
all accesses, and whenever churn evicts one from its owner every consumer
pays a remote DB load (plus FCFS queueing on the owner's bandwidth). Systems
in this layer (Cortex's semantic caches, ToolCaching) win by *replicating or
placing hot data near the consumer* — the shared
:class:`~repro.core.admission.FrequencySketch` already identifies the global
top-k, so the evidence is free.

:class:`HotKeyReplicator` promotes hot-but-homeless keys through two feeds:
on each simulated **epoch** it consumes the sketch's ``top_k`` intersected
with the router's per-key demand-load counts (a key that keeps paying
physical DB loads is hot AND unplaceable at its owner), and **between
epochs** the admission layer offers every key it *bypasses* for spill
(:meth:`HotKeyReplicator.offer` via ``router.spill``) — the exact moment we
learn a warm key's owner is full of hotter residents. A promotion pushes
copies via :meth:`PodLocalCacheRouter.replicate`, charging capacity on each
receiving pod: the displaced entry is the host's **minimum-frequency**
resident (placement arbitrage — the swap must beat the globally coldest
stream available), and only if the key's estimate exceeds it by
``gain_ratio``. ``fanout`` bounds copies per key (one copy already converts
the whole miss stream; reads resolve through ``router.locate`` owner-first,
replicas second, at equal pod-local cost).

Demotion is epoch-driven with a **hysteresis band** plus a utility veto: a
replicated key is dropped when its estimate falls below ``demote_frac *
promote_min`` — between the thresholds a *used* replica always holds, so
keys hovering at the promote threshold cannot flap replicate/drop across
epochs (locked in by tests) — and a replica that served no reads for a full
epoch (grace: its promote epoch) returns its slot even inside the band.

Measured effect (zipf-global, the many-endpoints-one-event regime): against
the install-everything engine, replication alone lifts 16-session/4-pod
local hits by 2-4 points with p95 reduced at every tested seed; stacked on
TinyLFU admission it is roughly hit-neutral (placement under TinyLFU is
already near-optimal when every read costs the same pod-locally) while
still trimming the tail — the win is queueing relief on hot owners.

Mirroring admission and eviction, the decision layer is dual: the
programmatic :class:`ThresholdReplication` rule, and the GPT-driven
:class:`LLMReplication` path that renders ``describe()`` + the sketch
evidence into a prompt (``prompts.replication_decision_prompt``), parses the
LLM's replicate/drop/hold answer, and grades it against the programmatic
rule. Like the paper's prompted update, decisions run off the critical path
(background epoch work): they cost tokens, never user-perceived latency.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.core.admission import FrequencySketch


@dataclasses.dataclass
class ReplicationStats:
    epochs: int = 0
    promotes: int = 0            # keys promoted (replicas pushed)
    demotes: int = 0             # keys demoted (replicas dropped)
    holds: int = 0               # in-band decisions that changed nothing
    copies_installed: int = 0    # physical per-pod replica installs
    copies_dropped: int = 0
    replica_bytes: int = 0       # background bytes pushed (off critical path)


class ReplicationPolicy:
    """Decides, per key and epoch, ``"replicate"`` | ``"drop"`` | ``"hold"``.

    Mirrors the admission/eviction policy shape: a programmatic rule plus a
    natural-language ``describe()`` the GPT-driven path prompts with.
    """

    name = "base"
    promote_min: int = 8
    demote_min: int = 4

    def decide(self, key: str, freq: int, replicated: bool) -> str:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


class ThresholdReplication(ReplicationPolicy):
    """Threshold rule with a hysteresis band.

    Promote when the sketch estimate reaches ``promote_min``; demote an
    already-replicated key only when it falls below ``demote_min =
    int(promote_min * demote_frac)``. Estimates inside ``[demote_min,
    promote_min)`` hold the current state — the band is what prevents
    replicate/drop flapping as aging halves the counters each window.
    """

    name = "threshold"

    def __init__(self, promote_min: int = 8, demote_frac: float = 0.5):
        assert promote_min >= 1 and 0.0 <= demote_frac <= 1.0
        self.promote_min = promote_min
        self.demote_min = max(1, int(promote_min * demote_frac))

    def decide(self, key, freq, replicated):
        if not replicated:
            return "replicate" if freq >= self.promote_min else "hold"
        return "drop" if freq < self.demote_min else "hold"

    def describe(self):
        return (f"threshold (replicate when frequency >= {self.promote_min}; "
                f"drop a replica when frequency < {self.demote_min}). Keys "
                "whose frequency sits between the two thresholds KEEP their "
                "current state (hysteresis: no flapping).")


class LLMReplication(ReplicationPolicy):
    """GPT-driven replication: the base policy's ``describe()`` text plus
    the sketch evidence are rendered into a prompt and the LLM answers
    replicate/drop/hold (the paper's prompted-eviction twist applied to
    placement). Graded against the programmatic decision; unparseable
    completions fall back to it. Token cost accumulates off the critical
    path, surfaced as ``replication_tokens`` in the episode metrics."""

    def __init__(self, base: ReplicationPolicy, llm, few_shot: bool = True):
        self.base = base
        self.llm = llm
        self.few_shot = few_shot
        self.name = f"llm-{base.name}"
        self.promote_min = base.promote_min
        self.demote_min = base.demote_min
        self.llm_total = 0
        self.llm_correct = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0
        # resilience fallbacks to the programmatic base (ungraded): garbled
        # prompt/completion vs endpoint pool down (ISSUE 9)
        self.parse_fallbacks = 0
        self.degraded = 0
        self._top_json = "[]"          # evidence block, set per epoch
        self._home_demand: Dict[str, Dict[str, int]] = {}   # locality feed

    def describe(self):
        return self.base.describe()

    @property
    def agreement(self) -> float:
        return self.llm_correct / self.llm_total if self.llm_total else 1.0

    def set_evidence(self, top: List[Tuple[str, int]]) -> None:
        self._top_json = json.dumps([{"key": k, "freq": f} for k, f in top])

    def set_home_demand(self, demand: Dict[str, Dict[str, int]]) -> None:
        """Locality evidence: per-key remote-read counts by consumer home
        pod (``LocalityModel.remote_demand``). Rendered into the prompt so
        the GPT-driven path can reason about WHERE a copy would pay off;
        empty (the default) leaves the prompt byte-identical to the
        locality-free one."""
        self._home_demand = demand

    def decide(self, key, freq, replicated):
        from repro.core.endpoints import LLMUnavailableError
        from repro.core.prompts import LLMParseError, parse_json_tail, \
            replication_decision_prompt
        hd = self._home_demand.get(key)
        prompt = replication_decision_prompt(
            self.base.describe(), key, freq, replicated,
            self.base.promote_min, self.base.demote_min,
            self._top_json, self.few_shot,
            home_demand_json=(json.dumps(hd, sort_keys=True) if hd
                              else None))
        expected = self.base.decide(key, freq, replicated)
        try:
            completion = self.llm.complete(prompt)
        except LLMUnavailableError:
            # endpoint pool down: programmatic twin, ungraded (the router
            # already billed the wasted retry tokens)
            self.degraded += 1
            return expected
        except LLMParseError:
            self.parse_fallbacks += 1
            self.prompt_tokens += len(prompt) // 4
            return expected
        self.prompt_tokens += len(prompt) // 4
        self.completion_tokens += len(completion) // 4
        try:
            raw = parse_json_tail(completion)
            decision = raw.get("decision") if isinstance(raw, dict) else None
        except ValueError:
            decision = None
        if decision not in ("replicate", "drop", "hold"):
            # garbled/meaningless completion: programmatic twin, ungraded
            self.parse_fallbacks += 1
            return expected
        if decision == "replicate" and replicated:
            decision = "hold"            # already replicated: idempotent
        if decision == "drop" and not replicated:
            decision = "hold"
        self.llm_total += 1
        self.llm_correct += int(decision == expected)
        return decision


class HotKeyReplicator:
    """Promotion/demotion of hot-but-homeless keys across pods.

    ``run_epoch(now)`` is called by the concurrent engine's scheduler the
    first time simulated time crosses each ``epoch_s`` boundary (background
    bookkeeping: no session clock is charged). One epoch:

    1. **demote pass** — every currently replicated key is re-judged
       against the (aged) sketch (plus the usage veto: an unused replica
       past its grace epoch returns its slot); a ``drop`` removes its
       replicas from all pods (the owner copy, if any, is untouched);
    2. **promote pass** — candidates are the keys with the most physical
       demand loads since the last epoch (``router.demand_counts``, drained
       here), judged by the policy on their sketch estimate; a
       ``replicate`` pushes copies onto the pods whose coldest residents
       lose the ``gain_ratio`` arbitrage, bounded by ``fanout`` copies and
       ``max_replicated`` concurrently replicated keys.

    Between epochs, :meth:`offer` (wired as ``router.spill``) promotes keys
    the admission layer bypasses, with the same gates — no epoch lag for
    the clearest hot-but-homeless signal there is.

    ``value_of(key)`` supplies the pushed payload (the engine passes the
    datastore's latency-free ``peek`` — replication is a background
    transfer, so only ``replica_bytes`` is accounted, never session time).
    """

    def __init__(self, router, sketch: FrequencySketch, value_of, *,
                 policy: Optional[ReplicationPolicy] = None,
                 top_k: int = 8, max_replicated: int = 4,
                 epoch_s: float = 60.0, fanout: Optional[int] = 1,
                 miss_min: int = 2, gain_ratio: float = 2.0,
                 durability: bool = False, stale_demote_min: int = 2):
        assert epoch_s > 0
        assert stale_demote_min >= 1
        self.router = router
        self.sketch = sketch
        self.value_of = value_of
        self.policy = policy or ThresholdReplication()
        self.top_k = top_k
        self.max_replicated = max_replicated
        self.epoch_s = epoch_s
        self.fanout = fanout              # copies per key (None = every pod)
        self.miss_min = miss_min          # demand loads/epoch to qualify
        self.gain_ratio = gain_ratio      # key must beat the victim by this
        self.durability = durability      # also judge hot RESIDENT keys
        self.next_epoch = epoch_s
        self.replicated: Dict[str, int] = {}     # key -> promote epoch index
        # coherence-churn demotion (ISSUE 8, the ROADMAP's "replication
        # earns its demotion path"): the router counts, per key, every
        # replica copy a mutation staled out (``replica_stale_counts``).
        # That churn is folded into a decaying ``stale_pressure`` score
        # each epoch (halved after use, so the ban lifts once the write
        # stream cools); a replicated key at or above ``stale_demote_min``
        # is demoted past its grace epoch, and a key under pressure is
        # vetoed from (re-)promotion — a copy that keeps going stale pays
        # invalidation fan-out every write and serves nothing for it.
        # Always empty without a MutationPlan (digest-locked no-op).
        self.stale_demote_min = stale_demote_min
        self.stale_pressure: Dict[str, int] = {}
        self.stats = ReplicationStats()

    def _stale_pressure(self, key: str) -> int:
        """Current coherence churn on ``key``'s replicas: the decayed
        cross-epoch score plus churn accumulated since the last epoch
        (``offer`` runs between epochs and must see live pressure)."""
        return (self.stale_pressure.get(key, 0)
                + self.router.replica_stale_counts.get(key, 0))

    def _locality(self):
        """The router's locality model when it actually penalizes remote
        reads (None otherwise — at penalty 1x a replica on a consumer pod
        buys nothing a copy anywhere else wouldn't, so the feeds must stay
        bit-identical to the locality-free replicator)."""
        loc = getattr(self.router, "locality", None)
        return loc if loc is not None and loc.penalty > 1.0 else None

    def _demand(self, key: str) -> int:
        """Promotion evidence for one key: physical demand loads since the
        last epoch, plus — under a locality penalty — remote reads paying
        cross-pod hops (a key can be perfectly resident at its owner and
        still be worth a consumer-pod copy)."""
        demand = self.router.demand_counts.get(key, 0)
        loc = self._locality()
        if loc is not None:
            demand += sum(loc.remote_demand.get(key, {}).values())
        return demand

    def _sync_llm_evidence(self) -> None:
        """Refresh the GPT-driven path's prompt evidence: the sketch's
        current top-k plus (under a locality penalty) the per-key remote
        consumer demand by home pod."""
        if not isinstance(self.policy, LLMReplication):
            return
        self.policy.set_evidence(self.sketch.top_k(self.top_k))
        loc = self._locality()
        self.policy.set_home_demand(loc.remote_demand if loc is not None
                                    else {})

    def offer(self, key: str, value, size_bytes: int) -> bool:
        """Spill promotion (between epochs): the owner pod just BYPASSED
        ``key`` — admission found it warmer than nothing but colder than
        every local resident. Another pod may hold someone *globally*
        colder: judge the key now (no epoch lag — by its next access the
        admission layer would simply bypass it again) and, on
        ``replicate``, place one copy where the displaced resident is
        coldest, subject to the same ``gain_ratio`` margin. Returns whether
        a copy was installed. Wired via ``router.spill``."""
        if key in self.replicated:
            return False
        if len(self.replicated) >= self.max_replicated:
            return False
        if self._demand(key) < self.miss_min:
            return False                 # one-shot traffic: not worth a slot
        if self._stale_pressure(key) >= self.stale_demote_min:
            return False                 # keeps going stale: no re-promote
        freq = self.sketch.estimate(key)
        # spill decisions run between epochs: refresh the prompt's
        # "hottest keys right now" (+ consumer demand) evidence so the
        # LLM is graded on the state it actually sees
        self._sync_llm_evidence()
        if self.policy.decide(key, freq, False) != "replicate":
            self.stats.holds += 1
            return False
        copies = self.router.replicate(key, value, size_bytes, self.fanout,
                                       self.gain_ratio)
        if not copies:
            return False
        self.replicated[key] = self.stats.epochs     # grace: current epoch
        self.stats.promotes += 1
        self.stats.copies_installed += copies
        self.stats.replica_bytes += copies * size_bytes
        return True

    def maybe_run(self, now: float) -> None:
        """Run every epoch boundary crossed up to ``now`` (the scheduler
        calls this with each event's timestamp; boundaries are processed
        before the event executes, so placement state at time t never
        depends on events after t)."""
        while now >= self.next_epoch:
            self.run_epoch(self.next_epoch)
            self.next_epoch += self.epoch_s

    def run_epoch(self, now: float) -> None:
        st = self.stats
        st.epochs += 1
        self._sync_llm_evidence()
        # fold the epoch's coherence churn into the decaying pressure score
        # (drained here like demand_counts/replica_reads; see __init__)
        for key, n in self.router.replica_stale_counts.items():
            self.stale_pressure[key] = self.stale_pressure.get(key, 0) + n
        self.router.replica_stale_counts.clear()
        # demote pass: re-judge every replicated key against the aged
        # sketch, then apply the *utility veto* — a replica that served no
        # reads for a full epoch (grace: the epoch it was promoted in) is
        # not earning its slot and is dropped even inside the frequency
        # hysteresis band. Within the band, a USED replica always holds
        # (the no-flap invariant the tests lock in); the veto only reclaims
        # dead capacity as the working set drifts.
        used = self.router.replica_reads
        for key in sorted(self.replicated):
            freq = self.sketch.estimate(key)
            decision = self.policy.decide(key, freq, True)
            grace = self.replicated[key] == st.epochs - 1
            if decision != "drop" and not grace and not used.get(key, 0):
                decision = "drop"
            if (decision != "drop" and not grace
                    and self.stale_pressure.get(key, 0)
                    >= self.stale_demote_min):
                decision = "drop"        # coherence churn: copies keep
                                         # going stale under the write load
            if decision == "drop":
                st.copies_dropped += self.router.drop_replica(key)
                del self.replicated[key]
                st.demotes += 1
            else:
                st.holds += 1
                # repair: install traffic may have evicted every copy since
                # promotion; re-push only when the key is resident NOWHERE
                # (a live copy — owner or replica — already serves reads at
                # the same pod-local cost, so extra copies are pure
                # capacity loss)
                if self.router.locate(key) is None:
                    value = self.value_of(key)
                    size = getattr(value, "size_bytes", 0)
                    copies = self.router.replicate(key, value, size,
                                                   self.fanout,
                                                   self.gain_ratio)
                    st.copies_installed += copies
                    st.replica_bytes += copies * size
        used.clear()
        # promote pass: candidates are the keys that paid the most physical
        # demand loads since the last epoch (the router's ``demand_counts``
        # feed, drained here) — a key that keeps demand-loading is hot AND
        # homeless: its crowded owner pod cannot retain it (it keeps losing
        # the admission contest there, or the owner's slots are monopolised
        # by even hotter siblings), so its whole access stream is paying
        # remote DB service + FCFS queueing. Spilling it onto another pod's
        # capacity converts that stream into pod-local hits; a key the
        # owner retains never accumulates misses, so it is never promoted
        # (extra copies of it would buy nothing — reads resolve owner-first
        # at equal cost). The sketch still gates on global frequency
        # (``promote_min``) so one epoch's burst cannot promote a cold key.
        # Under a locality penalty the feed gains the consumer term: remote
        # reads paying cross-pod hops count alongside physical demand loads
        # (a key resident at its owner never misses, but its off-home
        # consumers still pay a hop per read — a copy on THEIR pod is the
        # paper-faithful localized win). At penalty 1x the merged feed is
        # exactly ``demand_counts`` — bit-identical to the locality-free
        # replicator.
        missed = self.router.demand_counts
        loc = self._locality()
        if loc is not None and loc.remote_demand:
            missed = dict(missed)
            for key, per_pod in loc.remote_demand.items():
                missed[key] = missed.get(key, 0) + sum(per_pod.values())
        feed = sorted(missed.items(), key=lambda kv: (-kv[1], kv[0]))

        def missed_clear():              # drained whether promoted or not
            self.router.demand_counts.clear()
            if loc is not None:
                loc.remote_demand.clear()
        for key, miss_n in feed[:self.top_k]:
            if miss_n < self.miss_min or key in self.replicated:
                continue
            if self.stale_pressure.get(key, 0) >= self.stale_demote_min:
                continue                 # keeps going stale: no re-promote
            if len(self.replicated) >= self.max_replicated:
                break
            freq = self.sketch.estimate(key)
            decision = self.policy.decide(key, freq, False)
            if decision != "replicate":
                st.holds += 1
                continue
            value = self.value_of(key)
            size = getattr(value, "size_bytes", 0)
            copies = self.router.replicate(key, value, size, self.fanout,
                                           self.gain_ratio)
            if not copies:
                continue              # every host vetoed (hotter residents)
            self.replicated[key] = st.epochs      # promote epoch (grace)
            st.promotes += 1
            st.copies_installed += copies
            st.replica_bytes += copies * size
        missed_clear()
        # durability pass (opt-in; off by default and bit-identical to the
        # miss-fed replicator when off): the miss feed structurally never
        # promotes a key the owner retains — it never misses — yet exactly
        # those hot residents are what a pod failure destroys. Judging the
        # sketch's global top-k too places copies that buy no latency
        # (reads resolve owner-first at equal cost) but let the hottest
        # keys SURVIVE owner loss: replication doubling as resilience
        # (table_resilience measures the recovery-time delta). Runs after
        # the miss feed — homeless keys have latency value on top of the
        # durability value, so they get the replica slots first.
        if self.durability:
            for key, _est in self.sketch.top_k(self.top_k):
                if key in self.replicated:
                    continue
                if self.stale_pressure.get(key, 0) >= self.stale_demote_min:
                    continue             # churned-out copies aren't durable
                if len(self.replicated) >= self.max_replicated:
                    break
                freq = self.sketch.estimate(key)
                if self.policy.decide(key, freq, False) != "replicate":
                    st.holds += 1
                    continue
                value = self.value_of(key)
                size = getattr(value, "size_bytes", 0)
                copies = self.router.replicate(key, value, size, self.fanout,
                                               self.gain_ratio)
                if not copies:
                    continue          # every host vetoed (hotter residents)
                self.replicated[key] = st.epochs
                st.promotes += 1
                st.copies_installed += copies
                st.replica_bytes += copies * size
        # decay the coherence-pressure score (halve per epoch): once the
        # write stream off a key cools, the promotion ban lifts within a
        # couple of epochs instead of banning it forever
        self.stale_pressure = {k: v // 2 for k, v in
                               self.stale_pressure.items() if v // 2 > 0}

    # -- reporting ------------------------------------------------------------
    @property
    def agreement(self) -> float:
        return getattr(self.policy, "agreement", 1.0)

    @property
    def tokens(self) -> int:
        return (getattr(self.policy, "prompt_tokens", 0)
                + getattr(self.policy, "completion_tokens", 0))


def make_replication(*, impl: str = "python", llm=None, few_shot: bool = True,
                     promote_min: int = 8, demote_frac: float = 0.5,
                     ) -> ReplicationPolicy:
    """Build a replication policy; ``impl="llm"`` wraps the threshold rule
    in the GPT-driven path (requires an ``llm`` with ``complete()``)."""
    base = ThresholdReplication(promote_min=promote_min,
                                demote_frac=demote_frac)
    if impl == "llm":
        assert llm is not None, "LLM-driven replication needs an llm backend"
        return LLMReplication(base, llm, few_shot=few_shot)
    return base

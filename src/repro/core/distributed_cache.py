"""Pod-local data caching for multi-pod deployments (DESIGN §3).

The paper runs on "hundreds of GPT endpoints"; at multi-pod scale the
localized cache becomes a *sharded* cache: each pod owns a partition of the
``dataset-year`` key space (rendezvous hashing) and requests are routed with
pod affinity, so a key's data is cached on exactly one pod and reuse
concentrates there. Pod failure triggers deterministic re-partitioning
(elastic), and the remaining pods absorb the failed pod's keys.

Loads can be **asynchronous**: :meth:`PodLocalCacheRouter.start_load`
registers an in-flight load with its simulated completion time (the
concurrent engine's prefetcher and demand loads both use it), and
:meth:`PodLocalCacheRouter.finish_load` installs the value into the owning
pod's cache when the simulation reaches that time. While a load is in
flight, sessions needing the same key *join* it (wait for the existing
completion) instead of issuing a duplicate DB load. See
docs/architecture.md for the full data flow.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional

from repro.core.admission import AdmissionPolicy, FrequencySketch
from repro.core.cache import DataCache
from repro.core.policies import Policy, make_policy


def _score(key: str, pod: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(f"{key}|{pod}".encode(), digest_size=8).digest(), "big")


@dataclasses.dataclass
class RoutingStats:
    """Logical-access accounting (one increment of ``routed`` per session
    data access) plus physical prefetch issuance.

    Invariant: ``routed == local_hits + remote_loads + joined_in_flight``.
    ``prefetch_issued`` counts physical loads started by a prefetcher; they
    are *not* logical accesses (the later consume is, and lands in one of
    the three buckets above — usually ``joined_in_flight`` or
    ``local_hits``).
    """
    routed: int = 0
    local_hits: int = 0
    remote_loads: int = 0
    failovers: int = 0
    joined_in_flight: int = 0
    prefetch_issued: int = 0
    # admission accounting (all zero when no admission policy is wired):
    # ``admitted``/``bypassed`` count full-cache admission decisions;
    # ``bypass_reads`` counts logical accesses served straight from a
    # completed-but-bypassed load (the invariant gains a fourth bucket:
    # routed == local_hits + remote_loads + joined_in_flight + bypass_reads)
    admitted: int = 0
    bypassed: int = 0
    bypass_reads: int = 0
    # hot-key replication accounting (all zero without a HotKeyReplicator):
    # ``replica_installs``/``replica_drops`` count per-pod copy churn;
    # ``replica_hits`` counts local hits served by a NON-owner pod's copy —
    # they are a subset of ``local_hits`` (a replica hit is a local hit
    # that would otherwise have been a remote load or join)
    replica_installs: int = 0
    replica_drops: int = 0
    replica_hits: int = 0
    # fault/elasticity accounting (all zero without a FaultPlan):
    # ``aborted_loads`` counts in-flight loads killed with their pod;
    # ``retried_loads`` counts the physical re-issues the engine makes on
    # behalf of aborted waiters (the physical-load invariant becomes
    # remote_loads + prefetch_issued + retried_loads == total pod loads);
    # ``timeout_loads`` counts waiters that exhausted their retry budget
    # and bypassed to a direct DB read (never a stall-forever);
    # ``scale_outs``/``scale_ins`` count elastic membership changes (a
    # scale_in re-routes like a failure but is not a failover)
    aborted_loads: int = 0
    retried_loads: int = 0
    timeout_loads: int = 0
    scale_outs: int = 0
    scale_ins: int = 0
    # coherence accounting (all zero without a MutationPlan — ISSUE 8):
    # ``stale_reads`` counts logical accesses that consumed a version-lagged
    # copy under a bounded-staleness policy (a sub-bucket of
    # local_hits/joined_in_flight/bypass_reads — the access still lands in
    # its normal invariant bucket); ``refresh_loads`` counts physical
    # reloads forced by a coherence verdict (a sub-bucket of remote_loads:
    # the logical access routes as a remote load AND is marked a refresh);
    # ``superseded_fills`` counts in-flight fills whose version was
    # outdated by a write before completion and that a zero-staleness
    # policy therefore refused to install
    stale_reads: int = 0
    refresh_loads: int = 0
    superseded_fills: int = 0


@dataclasses.dataclass
class InFlightLoad:
    """A DB load that has been issued but whose (simulated) service has not
    completed yet. ``completes_at`` is the absolute sim time at which the
    value lands in the owning pod's cache."""
    key: str
    pod: str
    issued_at: float
    completes_at: float
    value: object
    size_bytes: int
    prefetched: bool = False
    joiners: int = 0
    credited: bool = False    # overlap credited (once per physical load)
    installed: bool = False   # completion installed it into the pod cache
    bypassed: bool = False    # completion was rejected by admission
    aborted: bool = False     # the serving pod died before completes_at
    # datastore version the read serialized at (its issue instant). A write
    # landing mid-flight leaves this behind the key's current version; the
    # coherence layer decides at consume/install time what that means.
    version: int = 0
    superseded: bool = False  # outdated mid-flight; fill not installed


@dataclasses.dataclass
class FailoverReport:
    """What one membership change destroyed — computed *before* the pod
    leaves service so the engine can abort/retry the affected sessions
    with exact state. ``lost_keys`` are the residents of the dying pod's
    cache (its working set, now cold); ``aborted`` are the in-flight loads
    that died with it (each marked ``aborted=True`` and removed from the
    router's in-flight table); ``lost_replicas`` are keys that lost a
    replica copy hosted on the pod (they may still be resident at their
    owner or on other replica pods)."""
    pod: str
    lost_keys: List[str]
    aborted: List[InFlightLoad]
    lost_replicas: List[str]


class PodLocalCacheRouter:
    """Rendezvous-hash router over per-pod DataCaches."""

    def __init__(self, pod_ids: List[str], capacity_per_pod: int = 5,
                 policy_name: str = "lru",
                 clock: Optional[Callable[[], float]] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 sketch: Optional[FrequencySketch] = None):
        self._clock = clock
        self._policy_name = policy_name
        self._capacity = capacity_per_pod   # default for scale_out pods
        # shared cross-session admission: one policy + one frequency sketch
        # for ALL pods (popularity is a property of the key, not the pod)
        self.admission = admission
        self.sketch = sketch
        self.pods: Dict[str, DataCache] = {
            p: DataCache(capacity_per_pod, clock) for p in pod_ids}
        self.policies: Dict[str, Policy] = {
            p: make_policy(policy_name) for p in pod_ids}
        self.alive: Dict[str, bool] = {p: True for p in pod_ids}
        self.stats = RoutingStats()
        self.in_flight: Dict[str, InFlightLoad] = {}
        # owner() memo: rendezvous hashing is deterministic in (key, live
        # pod set), so the winner is cached per key and the whole memo is
        # invalidated whenever membership changes (fail/restore). At 256
        # sessions the blake2-per-(key,pod) max() walk dominated routing.
        self._owner_memo: Dict[str, str] = {}
        # hot-key replicas: key -> pods (never the owner) a HotKeyReplicator
        # has pushed a copy to. The list is *advisory* — a replica can be
        # evicted later by that pod's own install traffic, so lookups verify
        # membership (see ``locate``). Empty without a replicator, in which
        # case every replica-aware path reduces exactly to the owner-only
        # behavior (digest-locked).
        self.replicas: Dict[str, List[str]] = {}
        # per-key demand-load counter since the last replication epoch: the
        # replicator's promotion feed (a key that keeps paying physical DB
        # loads is hot AND homeless — exactly what a replica fixes). Only
        # maintained while a replicator is wired (``spill`` is set); the
        # replicator drains it each epoch.
        self.demand_counts: Dict[str, int] = {}
        # per-key reads served by a replica since the last epoch: the
        # replicator's *demotion* feed (a replica that serves no reads for
        # a full epoch is not earning its slot). Drained each epoch.
        self.replica_reads: Dict[str, int] = {}
        # spill hook: a HotKeyReplicator registers itself here; a full
        # owner pod that BYPASSES a candidate offers it for spill
        # replication at that instant (admission knows the key is warm but
        # cannot place it locally — another pod may hold someone globally
        # colder). None without replication.
        self.spill = None
        # locality cost model (repro.core.locality.LocalityModel): set by
        # the concurrent engine when session->pod affinity is enabled.
        # None keeps every routing decision exactly the owner-first PR-4
        # behavior; with a model whose penalty > 1, ``locate`` becomes
        # cheapest-first and ``replicate`` targets consumer pods.
        self.locality = None
        # mutable-data-plane hooks (ISSUE 8), all inert without mutations:
        # ``version_of`` maps key -> current datastore version (None means
        # the store is immutable and every copy is version 0 forever);
        # ``fresh_fills_only`` is set by the zero-staleness policies
        # (write-invalidate / write-through) so a fill outdated mid-flight
        # is never installed; ``replica_stale_counts`` accumulates, per
        # key, how many REPLICA copies a mutation staled out — the
        # HotKeyReplicator's coherence-churn demotion feed (drained each
        # epoch, like demand_counts/replica_reads).
        self.version_of: Optional[Callable[[str], int]] = None
        self.fresh_fills_only = False
        self.replica_stale_counts: Dict[str, int] = {}

    # -- membership ----------------------------------------------------------
    def _purge_pod(self, pod_id: str) -> FailoverReport:
        """Everything a pod's departure invalidates, computed before it
        leaves service: abort its in-flight loads (they can never
        ``finish_load`` — a dangling record would block the key's next
        demand load forever), un-count their demand-feed contribution (the
        load never completed; the replicator must not promote on it — the
        engine's retry re-counts when it re-issues), drop its replica
        copies, and purge the ``replica_reads`` demotion feed for keys
        left with no replicas at all."""
        aborted = [rec for rec in self.in_flight.values()
                   if rec.pod == pod_id]
        for rec in aborted:
            del self.in_flight[rec.key]
            rec.aborted = True
            self.stats.aborted_loads += 1
            if not rec.prefetched and rec.key in self.demand_counts:
                self.demand_counts[rec.key] -= 1
                if self.demand_counts[rec.key] <= 0:
                    del self.demand_counts[rec.key]
        lost_replicas = []
        for key in list(self.replicas):
            pods = self.replicas[key]
            if pod_id in pods:
                pods.remove(pod_id)
                lost_replicas.append(key)
            if not pods:
                del self.replicas[key]
                self.replica_reads.pop(key, None)
        self._owner_memo.clear()
        return FailoverReport(pod=pod_id,
                              lost_keys=sorted(self.pods[pod_id].keys()),
                              aborted=aborted,
                              lost_replicas=sorted(lost_replicas))

    def fail_pod(self, pod_id: str) -> Optional[FailoverReport]:
        """Simulated pod failure: its cache contents are lost; its key range
        re-routes deterministically to survivors (rendezvous property). The
        rebuilt cache keeps the router's clock so the restored pod stays on
        simulated time (recency metadata stays comparable across pods).

        Idempotent: failing an already-dead pod is a no-op returning
        ``None`` (no failover counted, nothing purged twice). Otherwise
        returns the :class:`FailoverReport` of what died with the pod."""
        if not self.alive.get(pod_id, False):
            return None
        report = self._purge_pod(pod_id)
        self.alive[pod_id] = False
        self.pods[pod_id] = DataCache(self.pods[pod_id].capacity, self._clock)
        self.policies[pod_id] = make_policy(self._policy_name)
        self.stats.failovers += 1
        return report

    def restore_pod(self, pod_id: str) -> bool:
        """Return a failed pod to service (cold — its contents died with
        it). Idempotent: restoring a live pod is a no-op returning False."""
        if self.alive.get(pod_id, False):
            return False
        assert pod_id in self.pods, f"unknown pod {pod_id}"
        self.alive[pod_id] = True
        self._owner_memo.clear()
        return True

    def scale_out(self, pod_id: str,
                  capacity: Optional[int] = None) -> None:
        """Elastic fleet growth: add a brand-new (cold, empty) pod. The
        rendezvous property means only the keys it now wins re-route onto
        it; everything else keeps its owner and its warm cache."""
        assert pod_id not in self.pods, f"pod {pod_id} already exists"
        self.pods[pod_id] = DataCache(capacity or self._capacity, self._clock)
        self.policies[pod_id] = make_policy(self._policy_name)
        self.alive[pod_id] = True
        self._owner_memo.clear()
        self.stats.scale_outs += 1

    def scale_in(self, pod_id: str) -> Optional[FailoverReport]:
        """Elastic fleet shrink: retire a pod entirely. Its keys re-route
        like a failure (same purge/abort semantics, same
        :class:`FailoverReport`) but it is accounted as a scale event, not
        a failover. No-op returning ``None`` for an unknown pod; refuses
        to retire the last live pod."""
        if pod_id not in self.pods:
            return None
        live = self.live_pods()
        assert not (live == [pod_id]), "cannot scale in the last live pod"
        report = (self._purge_pod(pod_id) if self.alive.get(pod_id, False)
                  else FailoverReport(pod=pod_id, lost_keys=[], aborted=[],
                                      lost_replicas=[]))
        del self.pods[pod_id]
        del self.policies[pod_id]
        del self.alive[pod_id]
        self._owner_memo.clear()
        self.stats.scale_ins += 1
        return report

    def live_pods(self) -> List[str]:
        return [p for p, ok in self.alive.items() if ok]

    # -- routing -------------------------------------------------------------
    def owner(self, key: str) -> str:
        pod = self._owner_memo.get(key)
        if pod is None:
            live = self.live_pods()
            if not live:
                raise RuntimeError("no live pods")
            pod = max(live, key=lambda p: _score(key, p))
            self._owner_memo[key] = pod
        return pod

    def locate(self, key: str, home: Optional[str] = None) -> Optional[str]:
        """The pod whose cache currently holds ``key``, cheapest placement
        first for the consumer homed on ``home``.

        Without a locality penalty every pod-local read costs the same, so
        the order is the PR-4 one: the owner when it holds the key (the
        common case and the only case without replication), else the first
        live replica pod that still holds a copy (deterministic:
        replica-list insertion order), else ``None``. With a locality model
        whose ``penalty > 1`` and a consumer ``home``, a copy on the home
        pod is strictly cheaper than any other placement (it skips the
        cross-pod hop), so it wins; all non-home placements still cost the
        same single hop and keep the owner-first tie-break. Replica lists
        are advisory — membership is verified against the actual pod
        cache."""
        pod = self.owner(key)
        held = key in self.pods[pod]
        if held and (home is None or pod == home):
            return pod
        if (home is not None and home != pod and self.locality is not None
                and self.locality.penalty > 1.0):
            pods = self.replicas.get(key)
            if (pods and home in pods and self.alive.get(home, False)
                    and key in self.pods[home]):
                return home
        if held:
            return pod
        pods = self.replicas.get(key)
        if pods:
            for p in pods:
                if self.alive.get(p, False) and key in self.pods[p]:
                    return p
        return None

    def note_access(self, key: str, now: Optional[float] = None) -> None:
        """Record one logical access in the shared frequency sketch (no-op
        without a sketch). Callers on a sim clock pass ``now`` so the
        sketch ages on simulated time."""
        if self.sketch is not None:
            self.sketch.touch(key, now)

    def install(self, pod: str, key: str, value: object,
                size_bytes: int, version: int = 0) -> bool:
        """Install a loaded value into ``pod``'s cache, evicting per the
        pod's policy when full (shared by ``fetch`` and the concurrent
        engine's load path, so eviction semantics cannot diverge).

        With an admission policy wired, a full cache consults it first:
        a rejected candidate **bypasses** — nothing is installed, no
        resident is evicted, and the caller keeps streaming the value to
        the session. Returns whether ``key`` resides in the pod cache
        after the call."""
        cache = self.pods[pod]
        if key in cache:
            return True
        victim = None
        if len(cache) >= cache.capacity:
            victim = self.policies[pod].victim(cache.entries())
            if self.admission is not None:
                if not self.admission.admit(key, victim, self.sketch,
                                            cache.entries(),
                                            size_bytes=size_bytes):
                    self.stats.bypassed += 1
                    if self.spill is not None:
                        # hot-but-homeless: offer the rejected key for
                        # spill replication onto another pod's capacity
                        self.spill(key, value, size_bytes)
                    return False
                self.stats.admitted += 1
        cache.put(key, value, size_bytes, victim=victim, version=version)
        return True

    # -- hot-key replication --------------------------------------------------
    def replicate(self, key: str, value: object, size_bytes: int,
                  fanout: Optional[int] = None,
                  gain_ratio: float = 1.0) -> int:
        """Push copies of ``key`` to live non-owner pods (the
        HotKeyReplicator's promote action). Capacity is charged on each
        receiving pod: a full pod evicts its update policy's victim to make
        room — unless the shared sketch says the victim is at least as hot
        as ``key`` (replication must not churn out someone hotter).

        ``fanout=None`` pushes to *every* eligible pod; a bounded fanout
        takes the cheapest hosts first — pods with free capacity, then pods
        whose would-be victim is coldest (deterministic: ties break by pod
        id). One copy already converts the key's whole miss stream into
        pod-local hits (reads resolve owner-first, replicas second at equal
        cost), so bounded fanout buys the same hits for fewer evictions.

        The replica's victim is the host pod's MINIMUM-FREQUENCY resident
        (per the shared sketch), not the pod's update-policy victim: the
        update policy optimises the pod's own demand stream (recency), but
        a replica install is a *placement arbitrage* — it only pays off
        when the displaced stream is the globally coldest one available.
        Skips pods already holding a copy; skips pods whose coldest
        resident is at least as hot as ``key``. Returns the number of new
        copies.

        With a locality model whose ``penalty > 1``, placement targets
        **consumer pods**: hosts are ordered by the key's remote-read
        demand from sessions homed there (``LocalityModel.remote_demand``,
        highest first — a copy on such a pod converts every one of those
        reads from a penalized hop into a pod-local hit), and the
        gain-ratio arbitrage scales the key's frequency by ``penalty`` on
        demanding hosts (each converted read is worth a whole hop, so the
        swap clears the bar earlier exactly where the locality benefit is
        real). At penalty 1x the demand map is ignored and the ordering is
        bit-identical to the coldest-resident-first PR-4 rule."""
        owner = self.owner(key)
        kf = self.sketch.estimate(key) if self.sketch is not None else None
        loc = self.locality
        demand = (loc.remote_demand.get(key) or {}
                  if loc is not None and loc.penalty > 1.0 else {})
        candidates = []
        for p in self.live_pods():
            if p == owner:
                continue
            cache = self.pods[p]
            if key in cache:
                continue
            gain = loc.penalty if demand.get(p) else 1.0
            victim = None
            vf = -1                      # free slot: cheapest possible host
            if len(cache) >= cache.capacity:
                entries = cache.entries()
                if self.sketch is not None:
                    ests = self.sketch.estimate_many(sorted(entries))
                    vf, victim = min(zip(ests, sorted(entries)))
                    # the swap only pays when the key's stream decisively
                    # beats the displaced one: require a gain_ratio margin
                    # over the coldest resident (>= 1.0; higher = pickier)
                    if kf is not None and kf * gain < gain_ratio * max(vf, 1):
                        continue
                else:
                    victim = self.policies[p].victim(entries)
                    vf = 0
            candidates.append((-demand.get(p, 0), vf, p, victim))
        candidates.sort()
        if fanout is not None:
            candidates = candidates[:fanout]
        installed = 0
        ver = self.version_of(key) if self.version_of is not None else 0
        for _, _, p, victim in candidates:
            self.pods[p].put(key, value, size_bytes, victim=victim,
                             version=ver)
            pods = self.replicas.setdefault(key, [])
            if p not in pods:
                pods.append(p)
            installed += 1
            self.stats.replica_installs += 1
        return installed

    def drop_replica(self, key: str) -> int:
        """Remove every tracked replica of ``key`` (the demote action). The
        owner pod's copy — if any — is untouched: ownership placement stays
        the admission/eviction layer's business. Returns copies removed."""
        dropped = 0
        for p in self.replicas.pop(key, []):
            if self.alive.get(p, False) and self.pods[p].drop(key):
                dropped += 1
                self.stats.replica_drops += 1
        return dropped

    # -- coherence fan-out (ISSUE 8; every method a no-op on a key with no
    # live copies, so the mutation-free engine never reaches this code) ------
    def _note_replica_stale(self, key: str) -> None:
        self.replica_stale_counts[key] = (
            self.replica_stale_counts.get(key, 0) + 1)

    def invalidate_copies(self, key: str) -> int:
        """Write-invalidate fan-out: purge EVERY live copy of ``key`` —
        owner resident and every replica the HotKeyReplicator placed —
        and untrack its replica list (dead pods' copies were already
        destroyed with the pod, so they cannot serve stale either).
        Replica purges feed ``replica_stale_counts`` (demotion pressure:
        a copy that keeps getting invalidated is not earning its slot).
        Returns the number of copies purged."""
        owner = self.owner(key)
        purged = 0
        for p, cache in self.pods.items():
            if self.alive.get(p, False) and cache.drop(key):
                purged += 1
                if p != owner:
                    self._note_replica_stale(key)
        self.replicas.pop(key, None)
        return purged

    def refresh_copies(self, key: str, version: int) -> int:
        """Write-through fan-out: push ``version`` into every live copy in
        place (the writer pays per copy; values are content-identical in
        the sim, so the version stamp IS the refresh). Replica refreshes
        still count as coherence churn for the demotion feed. Returns the
        number of copies refreshed."""
        owner = self.owner(key)
        refreshed = 0
        for p, cache in self.pods.items():
            if not self.alive.get(p, False):
                continue
            e = cache.entry(key)
            if e is not None:
                e.version = version
                refreshed += 1
                if p != owner:
                    self._note_replica_stale(key)
        return refreshed

    def stale_copies(self, key: str) -> int:
        """Bounded-staleness bookkeeping at write time: copies stay in
        place (readers decide at consume time) but replica copies that
        just went version-lagged still count demotion pressure. Returns
        the number of live copies now lagging."""
        owner = self.owner(key)
        lagging = 0
        for p, cache in self.pods.items():
            if not self.alive.get(p, False) or key not in cache:
                continue
            lagging += 1
            if p != owner:
                self._note_replica_stale(key)
        return lagging

    # -- async completion -----------------------------------------------------
    def start_load(self, key: str, value: object, size_bytes: int, *,
                   issued_at: float, completes_at: float,
                   prefetched: bool = False) -> InFlightLoad:
        """Register an in-flight load of ``key`` on its owning pod.

        The caller has already arbitrated pod bandwidth (``completes_at``
        reflects any queueing); until :meth:`finish_load` runs, the key is
        neither cached nor loadable again — sessions that need it join this
        record instead of re-issuing the DB load.
        """
        assert key not in self.in_flight, f"{key} already in flight"
        rec = InFlightLoad(key=key, pod=self.owner(key), issued_at=issued_at,
                           completes_at=completes_at, value=value,
                           size_bytes=size_bytes, prefetched=prefetched,
                           version=(self.version_of(key)
                                    if self.version_of is not None else 0))
        self.in_flight[key] = rec
        if prefetched:
            self.stats.prefetch_issued += 1
        elif self.spill is not None:     # replication wired: feed promotion
            self.demand_counts[key] = self.demand_counts.get(key, 0) + 1
        return rec

    def finish_load(self, key: str) -> InFlightLoad:
        """Complete an in-flight load: install the value into the owning
        pod's cache (evicting per policy). Called by the discrete-event
        scheduler when sim time reaches ``completes_at``."""
        rec = self.in_flight.pop(key)
        if self.alive.get(rec.pod, False):
            if (self.fresh_fills_only and self.version_of is not None
                    and rec.version < self.version_of(key)):
                # a write outdated this fill mid-flight and the policy
                # forbids stale installs: the value still streams to its
                # waiters (their reads serialized before the write) but
                # nothing lands in the cache — the next read re-fetches
                rec.superseded = True
                self.stats.superseded_fills += 1
                return rec
            rec.installed = self.install(rec.pod, rec.key, rec.value,
                                         rec.size_bytes,
                                         version=rec.version)
            rec.bypassed = not rec.installed
        return rec

    def fetch(self, key: str, loader: Callable[[str], object],
              size_of: Callable[[object], int]):
        """Route to the owning pod; hit its local cache or load+install."""
        pod = self.owner(key)
        cache = self.pods[pod]
        self.stats.routed += 1
        self.note_access(key, self._clock() if self._clock else None)
        if key in cache:
            self.stats.local_hits += 1
            return cache.get(key), pod, True
        self.stats.remote_loads += 1
        value = loader(key)
        if not self.install(pod, key, value, size_of(value)):
            # admission bypass: the value streams through uncached
            return value, pod, False
        # install counts as first access
        return cache.get(key), pod, False

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        return {
            "pods": {p: {"keys": sorted(c.keys()),
                         "hit_rate": round(c.stats.hit_rate, 4)}
                     for p, c in self.pods.items()},
            "routed": self.stats.routed,
            "local_hit_rate": (self.stats.local_hits / self.stats.routed
                               if self.stats.routed else 0.0),
            "failovers": self.stats.failovers,
            "admission": (self.admission.name if self.admission else None),
            "admitted": self.stats.admitted,
            "bypassed": self.stats.bypassed,
        }

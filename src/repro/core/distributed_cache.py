"""Pod-local data caching for multi-pod deployments (DESIGN §3).

The paper runs on "hundreds of GPT endpoints"; at multi-pod scale the
localized cache becomes a *sharded* cache: each pod owns a partition of the
``dataset-year`` key space (rendezvous hashing) and requests are routed with
pod affinity, so a key's data is cached on exactly one pod and reuse
concentrates there. Pod failure triggers deterministic re-partitioning
(elastic), and the remaining pods absorb the failed pod's keys.

Loads can be **asynchronous**: :meth:`PodLocalCacheRouter.start_load`
registers an in-flight load with its simulated completion time (the
concurrent engine's prefetcher and demand loads both use it), and
:meth:`PodLocalCacheRouter.finish_load` installs the value into the owning
pod's cache when the simulation reaches that time. While a load is in
flight, sessions needing the same key *join* it (wait for the existing
completion) instead of issuing a duplicate DB load. See
docs/architecture.md for the full data flow.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional

from repro.core.admission import AdmissionPolicy, FrequencySketch
from repro.core.cache import DataCache
from repro.core.policies import Policy, make_policy


def _score(key: str, pod: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(f"{key}|{pod}".encode(), digest_size=8).digest(), "big")


@dataclasses.dataclass
class RoutingStats:
    """Logical-access accounting (one increment of ``routed`` per session
    data access) plus physical prefetch issuance.

    Invariant: ``routed == local_hits + remote_loads + joined_in_flight``.
    ``prefetch_issued`` counts physical loads started by a prefetcher; they
    are *not* logical accesses (the later consume is, and lands in one of
    the three buckets above — usually ``joined_in_flight`` or
    ``local_hits``).
    """
    routed: int = 0
    local_hits: int = 0
    remote_loads: int = 0
    failovers: int = 0
    joined_in_flight: int = 0
    prefetch_issued: int = 0
    # admission accounting (all zero when no admission policy is wired):
    # ``admitted``/``bypassed`` count full-cache admission decisions;
    # ``bypass_reads`` counts logical accesses served straight from a
    # completed-but-bypassed load (the invariant gains a fourth bucket:
    # routed == local_hits + remote_loads + joined_in_flight + bypass_reads)
    admitted: int = 0
    bypassed: int = 0
    bypass_reads: int = 0


@dataclasses.dataclass
class InFlightLoad:
    """A DB load that has been issued but whose (simulated) service has not
    completed yet. ``completes_at`` is the absolute sim time at which the
    value lands in the owning pod's cache."""
    key: str
    pod: str
    issued_at: float
    completes_at: float
    value: object
    size_bytes: int
    prefetched: bool = False
    joiners: int = 0
    credited: bool = False    # overlap credited (once per physical load)
    installed: bool = False   # completion installed it into the pod cache
    bypassed: bool = False    # completion was rejected by admission


class PodLocalCacheRouter:
    """Rendezvous-hash router over per-pod DataCaches."""

    def __init__(self, pod_ids: List[str], capacity_per_pod: int = 5,
                 policy_name: str = "lru",
                 clock: Optional[Callable[[], float]] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 sketch: Optional[FrequencySketch] = None):
        self._clock = clock
        self._policy_name = policy_name
        # shared cross-session admission: one policy + one frequency sketch
        # for ALL pods (popularity is a property of the key, not the pod)
        self.admission = admission
        self.sketch = sketch
        self.pods: Dict[str, DataCache] = {
            p: DataCache(capacity_per_pod, clock) for p in pod_ids}
        self.policies: Dict[str, Policy] = {
            p: make_policy(policy_name) for p in pod_ids}
        self.alive: Dict[str, bool] = {p: True for p in pod_ids}
        self.stats = RoutingStats()
        self.in_flight: Dict[str, InFlightLoad] = {}

    # -- membership ----------------------------------------------------------
    def fail_pod(self, pod_id: str):
        """Simulated pod failure: its cache contents are lost; its key range
        re-routes deterministically to survivors (rendezvous property). The
        rebuilt cache keeps the router's clock so the restored pod stays on
        simulated time (recency metadata stays comparable across pods)."""
        self.alive[pod_id] = False
        self.pods[pod_id] = DataCache(self.pods[pod_id].capacity, self._clock)
        self.policies[pod_id] = make_policy(self._policy_name)
        self.stats.failovers += 1

    def restore_pod(self, pod_id: str):
        self.alive[pod_id] = True

    def live_pods(self) -> List[str]:
        return [p for p, ok in self.alive.items() if ok]

    # -- routing -------------------------------------------------------------
    def owner(self, key: str) -> str:
        live = self.live_pods()
        if not live:
            raise RuntimeError("no live pods")
        return max(live, key=lambda p: _score(key, p))

    def note_access(self, key: str, now: Optional[float] = None) -> None:
        """Record one logical access in the shared frequency sketch (no-op
        without a sketch). Callers on a sim clock pass ``now`` so the
        sketch ages on simulated time."""
        if self.sketch is not None:
            self.sketch.touch(key, now)

    def install(self, pod: str, key: str, value: object,
                size_bytes: int) -> bool:
        """Install a loaded value into ``pod``'s cache, evicting per the
        pod's policy when full (shared by ``fetch`` and the concurrent
        engine's load path, so eviction semantics cannot diverge).

        With an admission policy wired, a full cache consults it first:
        a rejected candidate **bypasses** — nothing is installed, no
        resident is evicted, and the caller keeps streaming the value to
        the session. Returns whether ``key`` resides in the pod cache
        after the call."""
        cache = self.pods[pod]
        if key in cache:
            return True
        victim = None
        if len(cache) >= cache.capacity:
            victim = self.policies[pod].victim(cache.entries())
            if self.admission is not None:
                if not self.admission.admit(key, victim, self.sketch,
                                            cache.entries()):
                    self.stats.bypassed += 1
                    return False
                self.stats.admitted += 1
        cache.put(key, value, size_bytes, victim=victim)
        return True

    # -- async completion -----------------------------------------------------
    def start_load(self, key: str, value: object, size_bytes: int, *,
                   issued_at: float, completes_at: float,
                   prefetched: bool = False) -> InFlightLoad:
        """Register an in-flight load of ``key`` on its owning pod.

        The caller has already arbitrated pod bandwidth (``completes_at``
        reflects any queueing); until :meth:`finish_load` runs, the key is
        neither cached nor loadable again — sessions that need it join this
        record instead of re-issuing the DB load.
        """
        assert key not in self.in_flight, f"{key} already in flight"
        rec = InFlightLoad(key=key, pod=self.owner(key), issued_at=issued_at,
                           completes_at=completes_at, value=value,
                           size_bytes=size_bytes, prefetched=prefetched)
        self.in_flight[key] = rec
        if prefetched:
            self.stats.prefetch_issued += 1
        return rec

    def finish_load(self, key: str) -> InFlightLoad:
        """Complete an in-flight load: install the value into the owning
        pod's cache (evicting per policy). Called by the discrete-event
        scheduler when sim time reaches ``completes_at``."""
        rec = self.in_flight.pop(key)
        if self.alive.get(rec.pod, False):
            rec.installed = self.install(rec.pod, rec.key, rec.value,
                                         rec.size_bytes)
            rec.bypassed = not rec.installed
        return rec

    def fetch(self, key: str, loader: Callable[[str], object],
              size_of: Callable[[object], int]):
        """Route to the owning pod; hit its local cache or load+install."""
        pod = self.owner(key)
        cache = self.pods[pod]
        self.stats.routed += 1
        self.note_access(key, self._clock() if self._clock else None)
        if key in cache:
            self.stats.local_hits += 1
            return cache.get(key), pod, True
        self.stats.remote_loads += 1
        value = loader(key)
        if not self.install(pod, key, value, size_of(value)):
            # admission bypass: the value streams through uncached
            return value, pod, False
        # install counts as first access
        return cache.get(key), pod, False

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        return {
            "pods": {p: {"keys": sorted(c.keys()),
                         "hit_rate": round(c.stats.hit_rate, 4)}
                     for p, c in self.pods.items()},
            "routed": self.stats.routed,
            "local_hit_rate": (self.stats.local_hits / self.stats.routed
                               if self.stats.routed else 0.0),
            "failovers": self.stats.failovers,
            "admission": (self.admission.name if self.admission else None),
            "admitted": self.stats.admitted,
            "bypassed": self.stats.bypassed,
        }

"""Pod-local data caching for multi-pod deployments (DESIGN §3).

The paper runs on "hundreds of GPT endpoints"; at multi-pod scale the
localized cache becomes a *sharded* cache: each pod owns a partition of the
``dataset-year`` key space (rendezvous hashing) and requests are routed with
pod affinity, so a key's data is cached on exactly one pod and reuse
concentrates there. Pod failure triggers deterministic re-partitioning
(elastic), and the remaining pods absorb the failed pod's keys.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional

from repro.core.cache import DataCache
from repro.core.policies import Policy, make_policy


def _score(key: str, pod: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(f"{key}|{pod}".encode(), digest_size=8).digest(), "big")


@dataclasses.dataclass
class RoutingStats:
    routed: int = 0
    local_hits: int = 0
    remote_loads: int = 0
    failovers: int = 0


class PodLocalCacheRouter:
    """Rendezvous-hash router over per-pod DataCaches."""

    def __init__(self, pod_ids: List[str], capacity_per_pod: int = 5,
                 policy_name: str = "lru",
                 clock: Optional[Callable[[], float]] = None):
        self._clock = clock
        self._policy_name = policy_name
        self.pods: Dict[str, DataCache] = {
            p: DataCache(capacity_per_pod, clock) for p in pod_ids}
        self.policies: Dict[str, Policy] = {
            p: make_policy(policy_name) for p in pod_ids}
        self.alive: Dict[str, bool] = {p: True for p in pod_ids}
        self.stats = RoutingStats()

    # -- membership ----------------------------------------------------------
    def fail_pod(self, pod_id: str):
        """Simulated pod failure: its cache contents are lost; its key range
        re-routes deterministically to survivors (rendezvous property). The
        rebuilt cache keeps the router's clock so the restored pod stays on
        simulated time (recency metadata stays comparable across pods)."""
        self.alive[pod_id] = False
        self.pods[pod_id] = DataCache(self.pods[pod_id].capacity, self._clock)
        self.policies[pod_id] = make_policy(self._policy_name)
        self.stats.failovers += 1

    def restore_pod(self, pod_id: str):
        self.alive[pod_id] = True

    def live_pods(self) -> List[str]:
        return [p for p, ok in self.alive.items() if ok]

    # -- routing -------------------------------------------------------------
    def owner(self, key: str) -> str:
        live = self.live_pods()
        if not live:
            raise RuntimeError("no live pods")
        return max(live, key=lambda p: _score(key, p))

    def install(self, pod: str, key: str, value: object, size_bytes: int):
        """Install a loaded value into ``pod``'s cache, evicting per the
        pod's policy when full (shared by ``fetch`` and the concurrent
        engine's load path, so eviction semantics cannot diverge)."""
        cache = self.pods[pod]
        if key in cache:
            return
        victim = None
        if len(cache) >= cache.capacity:
            victim = self.policies[pod].victim(cache.entries())
        cache.put(key, value, size_bytes, victim=victim)

    def fetch(self, key: str, loader: Callable[[str], object],
              size_of: Callable[[object], int]):
        """Route to the owning pod; hit its local cache or load+install."""
        pod = self.owner(key)
        cache = self.pods[pod]
        self.stats.routed += 1
        if key in cache:
            self.stats.local_hits += 1
            return cache.get(key), pod, True
        self.stats.remote_loads += 1
        value = loader(key)
        self.install(pod, key, value, size_of(value))
        # install counts as first access
        return cache.get(key), pod, False

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        return {
            "pods": {p: {"keys": sorted(c.keys()),
                         "hit_rate": round(c.stats.hit_rate, 4)}
                     for p, c in self.pods.items()},
            "routed": self.stats.routed,
            "local_hit_rate": (self.stats.local_hits / self.stats.routed
                               if self.stats.routed else 0.0),
            "failovers": self.stats.failovers,
        }

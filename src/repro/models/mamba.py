"""Mamba-style selective-SSM heads for the hybrid (hymba) family.

Hymba runs attention heads and SSM heads *in parallel* inside each layer
(arXiv:2411.13676); this module provides the SSM half. Per head of dim
``hd`` with state width ``N``:

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (z_t  (x)  B_t)
    y_t = S_t @ C_t + D_h * z_t

with data-dependent dt (softplus), B, C, a short causal conv on the input,
and a chunked scan (checkpointed inner loop) like the RWKV path.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Init

SSM_CHUNK = 64


def init_mamba(ini: Init, cfg: ModelConfig, n_layers: int) -> Dict:
    d = cfg.d_model
    H, hd, N = cfg.n_ssm_heads, cfg.ssm.head_dim, cfg.ssm.state_size
    cw = max(cfg.ssm.conv_width, 1)
    L = (n_layers,)
    return {
        "w_in": ini.param(L + (d, H * hd), ("layers", "embed", "ssm_dim")),
        "w_dt": ini.param(L + (d, H), ("layers", "embed", "")),
        "b_dt": ini.zeros(L + (H,), ("layers", "")),
        "w_B": ini.param(L + (d, H * N), ("layers", "embed", "")),
        "w_C": ini.param(L + (d, H * N), ("layers", "embed", "")),
        "a_log": ini.zeros(L + (H,), ("layers", "")),       # A = -exp(a_log)
        "d_skip": ini.ones(L + (H,), ("layers", "")),
        "conv": ini.param(L + (cw, H * hd), ("layers", "conv", "ssm_dim"),
                          scale=0.5),
        "w_out": ini.param(L + (H * hd, d), ("layers", "ssm_dim", "embed"),
                           scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _conv1d(z: jax.Array, w: jax.Array, carry: jax.Array = None):
    """Causal depthwise conv. z: (B,S,C); w: (cw,C); carry: (B,cw-1,C)."""
    cw = w.shape[0]
    if cw == 1:
        return z * w[0], None
    if carry is None:
        carry = jnp.zeros((z.shape[0], cw - 1, z.shape[2]), z.dtype)
    zp = jnp.concatenate([carry, z], axis=1)
    out = sum(zp[:, i:i + z.shape[1], :] * w[i] for i in range(cw))
    return out, zp[:, -(cw - 1):, :]


def _ssm_inputs(p: Dict, cfg: ModelConfig, x: jax.Array, conv_carry=None):
    H, hd, N = cfg.n_ssm_heads, cfg.ssm.head_dim, cfg.ssm.state_size
    B, S, _ = x.shape
    z = x @ p["w_in"]
    z, conv_carry = _conv1d(z, p["conv"], conv_carry)
    z = jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype).reshape(B, S, H, hd)
    dt = jax.nn.softplus((x @ p["w_dt"] + p["b_dt"]).astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,)
    decay = jnp.exp(dt * a)                                  # (B,S,H)
    Bt = (x @ p["w_B"]).reshape(B, S, H, N)
    Ct = (x @ p["w_C"]).reshape(B, S, H, N)
    return z, dt, decay, Bt, Ct, conv_carry


def ssm_scan(z, dt, decay, Bt, Ct, s0, chunk: int = SSM_CHUNK):
    """z: (B,S,H,hd); dt/decay: (B,S,H); Bt/Ct: (B,S,H,N); s0: (B,H,hd,N)."""
    B, S, H, hd = z.shape
    c = chunk if S % chunk == 0 else S
    n = S // c

    def to_chunks(x):
        return x.reshape((B, n, c) + x.shape[2:]).swapaxes(0, 1).swapaxes(1, 2)

    zc, dtc, dc, Bc, Cc = map(to_chunks, (z, dt, decay, Bt, Ct))

    @jax.checkpoint
    def chunk_body(s, xs):
        zz, dd, de, bb, cc = xs

        def step(s_in, ts):
            zt, dtt, det, bt, ct = ts
            upd = jnp.einsum("bhi,bhn->bhin", (zt * dtt[..., None]).astype(jnp.float32),
                             bt.astype(jnp.float32))
            s_out = det.astype(jnp.float32)[..., None, None] * s_in + upd
            yt = jnp.einsum("bhin,bhn->bhi", s_out, ct.astype(jnp.float32))
            return s_out, yt

        s, ys = jax.lax.scan(step, s, (zz, dd, de, bb, cc))
        return s, ys

    s_final, yc = jax.lax.scan(chunk_body, s0.astype(jnp.float32),
                               (zc, dtc, dc, Bc, Cc))
    y = yc.swapaxes(1, 2).swapaxes(0, 1).reshape(B, S, H, hd)
    return y.astype(z.dtype), s_final


def mamba_mix(p: Dict, cfg: ModelConfig, x: jax.Array, state: jax.Array):
    """Full-sequence SSM heads. x: (B,S,D); state: (B,H,hd,N) fp32.
    Returns (out, s_final, conv_carry)."""
    B, S, _ = x.shape
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    z, dt, decay, Bt, Ct, conv_carry = _ssm_inputs(p, cfg, x)
    y, s_final = ssm_scan(z, dt, decay, Bt, Ct, state,
                          chunk=(S if cfg.unroll else SSM_CHUNK))
    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * z
    out = y.reshape(B, S, H * hd) @ p["w_out"]
    return out, s_final, conv_carry


def mamba_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: jax.Array,
               conv_carry: jax.Array):
    """Single-token decode. x: (B,1,D); state: (B,H,hd,N) fp32;
    conv_carry: (B,cw-1,H*hd). Returns (out, state', conv_carry')."""
    B = x.shape[0]
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    z, dt, decay, Bt, Ct, conv_carry = _ssm_inputs(p, cfg, x, conv_carry)
    zt, dtt, det, bt, ct = z[:, 0], dt[:, 0], decay[:, 0], Bt[:, 0], Ct[:, 0]
    upd = jnp.einsum("bhi,bhn->bhin", (zt * dtt[..., None]).astype(jnp.float32),
                     bt.astype(jnp.float32))
    state = det.astype(jnp.float32)[..., None, None] * state + upd
    yt = jnp.einsum("bhin,bhn->bhi", state, ct.astype(jnp.float32)).astype(x.dtype)
    yt = yt + p["d_skip"][None, :, None].astype(yt.dtype) * zt
    out = yt.reshape(B, 1, H * hd) @ p["w_out"]
    return out, state, conv_carry

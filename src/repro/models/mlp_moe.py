"""Dense FFN (SwiGLU / GELU) and Mixture-of-Experts blocks.

MoE uses the GShard/mesh-tf *capacity-based dense dispatch* — the TPU-native
formulation: tokens are folded into groups, a (group, token, expert,
capacity) dispatch tensor routes top-k tokens into per-expert buffers, and
expert FFNs run as one batched einsum over (expert, capacity) — so compiled
FLOPs scale with top-k (active experts), not n_experts. Ragged/sorted
dispatch is a GPU-ism; the MXU wants the dense batched matmul.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import Init, gelu, swiglu

CAPACITY_FACTOR = 1.25
GROUP_TOKENS = 1024


def init_mlp(ini: Init, cfg: ModelConfig, n_layers: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    L = (n_layers,)
    p = {
        "w_up": ini.param(L + (d, f), ("layers", "embed", "mlp")),
        "w_down": ini.param(L + (f, d), ("layers", "mlp", "embed"),
                            scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = ini.param(L + (d, f), ("layers", "embed", "mlp"))
    return p


def mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = constrain(up, ("batch", "seq", "act_mlp"))
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = swiglu(gate, up)
    else:
        h = gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def init_moe(ini: Init, cfg: ModelConfig, n_layers: int) -> Dict:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    L = (n_layers,)
    p = {
        "router": ini.param(L + (d, e), ("layers", "embed", "experts")),
        "we_gate": ini.param(L + (e, d, f), ("layers", "experts", "embed", "mlp")),
        "we_up": ini.param(L + (e, d, f), ("layers", "experts", "embed", "mlp")),
        "we_down": ini.param(L + (e, f, d), ("layers", "experts", "mlp", "embed"),
                             scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.moe.n_shared_experts:
        s = cfg.moe.n_shared_experts
        p["ws_gate"] = ini.param(L + (d, s * f), ("layers", "embed", "mlp"))
        p["ws_up"] = ini.param(L + (d, s * f), ("layers", "embed", "mlp"))
        p["ws_down"] = ini.param(L + (s * f, d), ("layers", "mlp", "embed"))
    return p


def moe_capacity(cfg: ModelConfig, group_tokens: int) -> int:
    mc = cfg.moe
    # dropless for small groups (decode steps): capacity covers the worst
    # case so no token is ever dropped at generation time
    if group_tokens * mc.top_k <= 64:
        return group_tokens * mc.top_k
    c = int(group_tokens * mc.top_k * CAPACITY_FACTOR / mc.n_experts)
    return max(c, mc.top_k)


def _routing(p: Dict, cfg: ModelConfig, xg: jax.Array,
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """dispatch/combine tensors (G,T,E,C) from grouped tokens xg (G,T,D)."""
    mc = cfg.moe
    G, T, _ = xg.shape
    E, K = mc.n_experts, mc.top_k
    C = moe_capacity(cfg, T)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    gate_vals, idx = jax.lax.top_k(logits, K)              # (G,T,K)
    gate_vals = jax.nn.softmax(gate_vals, axis=-1)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)     # (G,T,K,E)
    # position of each (token, k) inside its expert buffer (k=0 has priority)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * T, E)
    pos_flat = (jnp.cumsum(flat, axis=1) - 1.0) * flat
    pos = pos_flat.reshape(G, K, T, E).transpose(0, 2, 1, 3)  # (G,T,K,E)

    dispatch = jnp.zeros((G, T, E, C), jnp.float32)
    combine = jnp.zeros((G, T, E, C), jnp.float32)
    for k in range(K):
        oh_e = onehot[:, :, k, :]                           # (G,T,E)
        pos_t = jnp.sum(pos[:, :, k, :] * oh_e, axis=-1)    # (G,T)
        keep = (jnp.sum(pos[:, :, k, :] * oh_e, axis=-1) < C).astype(jnp.float32)
        oh_c = jax.nn.one_hot(pos_t, C, dtype=jnp.float32)  # (G,T,C)
        d_k = jnp.einsum("gte,gtc->gtec", oh_e * keep[..., None], oh_c)
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_vals[:, :, k, None, None]
    return dispatch, combine, logits


def moe(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Top-k routed experts, capacity-based dispatch. x: (B,S,D)."""
    B, S, D = x.shape
    tokens = B * S
    T = GROUP_TOKENS if tokens % GROUP_TOKENS == 0 else tokens
    G = tokens // T
    xg = x.reshape(G, T, D)
    dispatch, combine, _ = _routing(p, cfg, xg)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)         # (G,E,C,D)
    xe = constrain(xe, ("moe_tokens", "experts", "", "act_embed"))
    h = swiglu(
        jnp.einsum("gecd,edf->gecf", xe, p["we_gate"]),
        jnp.einsum("gecd,edf->gecf", xe, p["we_up"]),
    )
    h = constrain(h, ("moe_tokens", "experts", "", "act_mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
    out = jnp.einsum("gecd,gtec->gtd", ye, combine).reshape(B, S, D)
    if cfg.moe.n_shared_experts:
        out = out + jnp.einsum(
            "bsf,fd->bsd",
            swiglu(jnp.einsum("bsd,df->bsf", x, p["ws_gate"]),
                   jnp.einsum("bsd,df->bsf", x, p["ws_up"])),
            p["ws_down"])
    return out


def moe_aux_loss(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing loss."""
    mc = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, mc.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, mc.n_experts, dtype=jnp.float32),
                    axis=(0, 1, 2))
    imp = jnp.mean(probs, axis=(0, 1))
    return mc.n_experts * jnp.sum(frac * imp)

"""RWKV6 ("Finch") time-mix and channel-mix — attention-free recurrence with
data-dependent decay (arXiv:2404.05892).

The WKV recurrence runs as a *chunked* scan: an outer ``lax.scan`` over
sequence chunks carries the (B,H,K,V) state, the inner per-step scan is
wrapped in ``jax.checkpoint`` so training memory is O(S/chunk) states, not
O(S). The Pallas kernel in ``repro.kernels.rwkv_wkv`` is the TPU hot path
for the same computation.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Init, rms_norm

LORA_RANK = 32
WKV_CHUNK = 64


def init_time_mix(ini: Init, cfg: ModelConfig, n_layers: int) -> Dict:
    d = cfg.d_model
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    r = LORA_RANK
    L = (n_layers,)
    p: Dict = {"w0": ini.zeros(L + (d,), ("layers", "embed"))}
    for name in ("x", "w", "k", "v", "r", "g"):
        p[f"mu_{name}"] = ini.zeros(L + (d,), ("layers", "embed"))
    for name in ("w", "k", "v", "r", "g"):
        p[f"la_{name}"] = ini.param(L + (d, r), ("layers", "embed", "lora"))
        p[f"lb_{name}"] = ini.param(L + (r, d), ("layers", "lora", "embed"),
                                    scale=0.1)
    for name in ("wr", "wk", "wv", "wg"):
        p[name] = ini.param(L + (d, H * hd), ("layers", "embed", "ssm_dim"))
    p["wo"] = ini.param(L + (H * hd, d), ("layers", "ssm_dim", "embed"),
                        scale=1.0 / max(cfg.n_layers, 1) ** 0.5)
    p["u"] = ini.zeros(L + (H, hd), ("layers", "", ""))
    p["ln_x"] = ini.ones(L + (H * hd,), ("layers", "ssm_dim"))
    return p


def init_channel_mix(ini: Init, cfg: ModelConfig, n_layers: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    L = (n_layers,)
    return {
        "mu_k": ini.zeros(L + (d,), ("layers", "embed")),
        "mu_r": ini.zeros(L + (d,), ("layers", "embed")),
        "wk": ini.param(L + (d, f), ("layers", "embed", "mlp")),
        "wv": ini.param(L + (f, d), ("layers", "mlp", "embed"),
                        scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
        "wr": ini.param(L + (d, d), ("layers", "embed", "act_embed")),
    }


def _ddlerp(x, dx, mu, la, lb):
    """Data-dependent token-shift interpolation (rwkv6)."""
    return x + dx * (mu + jnp.tanh((x + dx * mu) @ la) @ lb)


def wkv_scan(r, k, v, w, u, s0, chunk: int = WKV_CHUNK,
             ) -> Tuple[jax.Array, jax.Array]:
    """WKV recurrence.  r,k,v,w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd) fp32.

    y_t = r_t . (S_{t-1} + u * k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns (y (B,S,H,hd), s_final).
    """
    B, S, H, hd = r.shape
    c = chunk if S % chunk == 0 else S
    n = S // c

    def to_chunks(x):
        return x.reshape(B, n, c, H, hd).transpose(1, 2, 0, 3, 4)  # (n,c,B,H,hd)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    @jax.checkpoint
    def chunk_body(s, xs):
        rr, kk, vv, ww = xs  # each (c,B,H,hd)

        def step(s_in, ts):
            rt, kt, vt, wt = ts
            kvt = jnp.einsum("bhi,bhj->bhij", kt.astype(jnp.float32),
                             vt.astype(jnp.float32))
            yt = jnp.einsum("bhi,bhij->bhj", rt.astype(jnp.float32),
                            s_in + u.astype(jnp.float32)[None, :, :, None] * kvt)
            s_out = wt.astype(jnp.float32)[..., None] * s_in + kvt
            return s_out, yt

        s, ys = jax.lax.scan(step, s, (rr, kk, vv, ww))
        return s, ys

    s_final, yc = jax.lax.scan(chunk_body, s0.astype(jnp.float32),
                               (rc, kc, vc, wc))
    y = yc.transpose(2, 0, 1, 3, 4).reshape(B, S, H, hd)
    return y.astype(r.dtype), s_final


def _tm_inputs(p: Dict, x: jax.Array, xx: jax.Array, cfg: ModelConfig):
    """r,k,v,w,g tensors (B,S,H,hd) from x and its token-shift xx."""
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    dx = xx - x
    xw = _ddlerp(x, dx, p["mu_w"], p["la_w"], p["lb_w"])
    xk = _ddlerp(x, dx, p["mu_k"], p["la_k"], p["lb_k"])
    xv = _ddlerp(x, dx, p["mu_v"], p["la_v"], p["lb_v"])
    xr = _ddlerp(x, dx, p["mu_r"], p["la_r"], p["lb_r"])
    xg = _ddlerp(x, dx, p["mu_g"], p["la_g"], p["lb_g"])
    shp = x.shape[:-1] + (H, hd)
    r = (xr @ p["wr"]).reshape(shp)
    k = (xk @ p["wk"]).reshape(shp)
    v = (xv @ p["wv"]).reshape(shp)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32)).astype(x.dtype)
    # decay in (0,1), data-dependent (the "Finch" contribution)
    w = jnp.exp(-jnp.exp((jnp.tanh(xw @ p["la_w"]) @ p["lb_w"] + p["w0"]
                          ).astype(jnp.float32))).reshape(shp)
    return r, k, v, w.astype(jnp.float32), g


def time_mix(p: Dict, cfg: ModelConfig, x: jax.Array, shift: jax.Array,
             state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix. x: (B,S,D); shift: (B,D) last token of the
    previous segment; state: (B,H,hd,hd) fp32. Returns (out, shift', state')."""
    B, S, D = x.shape
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    xx = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, w, g = _tm_inputs(p, x, xx, cfg)
    # unroll mode (cost probes): single chunk; the recurrence flops are
    # added analytically by the dry-run (see launch/dryrun.py)
    y, s_final = wkv_scan(r, k, v, w, p["u"], state,
                          chunk=(S if cfg.unroll else WKV_CHUNK))
    y = y.reshape(B, S, H * hd)
    y = rms_norm(y.reshape(B, S, H, hd), jnp.ones((hd,), x.dtype),
                 cfg.norm_eps).reshape(B, S, H * hd) * p["ln_x"]
    out = (y * g) @ p["wo"]
    return out, x[:, -1, :], s_final


def time_mix_step(p: Dict, cfg: ModelConfig, x: jax.Array, shift: jax.Array,
                  state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode. x: (B,1,D)."""
    B, _, D = x.shape
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    xx = shift[:, None, :]
    r, k, v, w, g = _tm_inputs(p, x, xx, cfg)
    rt, kt, vt, wt = (t[:, 0] for t in (r, k, v, w))
    kv = jnp.einsum("bhi,bhj->bhij", kt.astype(jnp.float32),
                    vt.astype(jnp.float32))
    y = jnp.einsum("bhi,bhij->bhj", rt.astype(jnp.float32),
                   state + p["u"].astype(jnp.float32)[None, :, :, None] * kv)
    state = wt.astype(jnp.float32)[..., None] * state + kv
    y = y[:, None].astype(x.dtype).reshape(B, 1, H, hd)
    y = rms_norm(y, jnp.ones((hd,), x.dtype), cfg.norm_eps
                 ).reshape(B, 1, H * hd) * p["ln_x"]
    out = (y * g.reshape(B, 1, H * hd)) @ p["wo"]
    return out, x[:, 0, :], state


def channel_mix(p: Dict, cfg: ModelConfig, x: jax.Array, shift: jax.Array,
                ) -> Tuple[jax.Array, jax.Array]:
    """Squared-ReLU channel mix. x: (B,S,D); shift: (B,D)."""
    xx = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    dx = xx - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["wr"]).astype(jnp.float32)).astype(x.dtype) \
        * (k @ p["wv"]), x[:, -1, :]

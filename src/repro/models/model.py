"""Unified composable model covering all assigned families.

One parameter pytree + three entry points:

    init_model(ini, cfg)                      -> Boxed param tree
    forward(cfg, params, batch)               -> final hidden states (train)
    loss_fn(cfg, params, batch)               -> (scalar, metrics)
    prefill_step(cfg, params, batch)          -> (cache, last-token logits)
    decode_step(cfg, params, tokens, cache)   -> (logits, cache')

Layers are stacked along a leading ``layers`` dim and executed with
``lax.scan`` (small HLO, fast compile at 56+ layers). MoE interleaving
(llama4: dense/MoE alternation) scans over super-layers of ``interleave``
sublayers so the alternating order is preserved inside one homogeneous scan.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import effective_cache_len
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp_moe, rwkv
from repro.models.common import Boxed, Init, maybe_scan, rms_norm

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_model(ini: Init, cfg: ModelConfig) -> Dict:
    L, D = cfg.n_layers, cfg.d_model
    k = cfg.moe.interleave if cfg.moe else 1
    n_moe = L // k if cfg.moe else 0
    n_dense = L - n_moe

    p: Dict = {
        "embed": ini.param((cfg.padded_vocab, D), ("vocab", "embed")),
        "final_norm": ini.ones((D,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = ini.param((D, cfg.padded_vocab), ("embed", "vocab"))

    dec: Dict = {
        "norm1": ini.ones((L, D), ("layers", "embed")),
        "norm2": ini.ones((L, D), ("layers", "embed")),
    }
    if cfg.family == "ssm":
        dec["tm"] = rwkv.init_time_mix(ini, cfg, L)
        dec["cm"] = rwkv.init_channel_mix(ini, cfg, L)
    else:
        dec["attn"] = attn_mod.init_attention(ini, cfg, L)
        if cfg.family == "hybrid":
            dec["ssm"] = mamba_mod.init_mamba(ini, cfg, L)
        if n_dense:
            dec["mlp"] = mlp_moe.init_mlp(ini, cfg, n_dense)
        if n_moe:
            dec["moe"] = mlp_moe.init_moe(ini, cfg, n_moe)
    if cfg.is_encdec:
        dec["cross"] = attn_mod.init_attention(ini, cfg, L, cross=True)
        dec["norm3"] = ini.ones((L, D), ("layers", "embed"))
    p["dec"] = dec

    if cfg.is_encdec:
        Le = cfg.n_encoder_layers
        p["enc"] = {
            "attn": attn_mod.init_attention(ini, cfg, Le),
            "mlp": mlp_moe.init_mlp(ini, cfg, Le),
            "norm1": ini.ones((Le, D), ("layers", "embed")),
            "norm2": ini.ones((Le, D), ("layers", "embed")),
            "final_norm": ini.ones((D,), ("embed",)),
        }
    if cfg.frontend == "audio_frames":
        p["frame_proj"] = ini.param((D, D), ("embed", "act_embed"))
    if cfg.frontend == "vision_patches":
        p["patch_proj"] = ini.param((D, D), ("embed", "act_embed"))
    return p


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _remat(body, cfg: ModelConfig):
    """Layer rematerialisation. "block" recomputes everything (min memory,
    but re-executes the FSDP weight gathers in backward); "dots" saves
    matmul outputs so neither the matmuls nor their operand gathers are
    recomputed (more live memory, fewer collective bytes — §Perf)."""
    if cfg.remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _regroup(tree, n_super: int, k: int):
    """Reshape stacked leaves (n_super*k, ...) -> (n_super, k, ...)."""
    return jax.tree.map(
        lambda a: a.reshape((n_super, k) + a.shape[1:]), tree)


def _idx(tree, j: int):
    return jax.tree.map(lambda a: a[j], tree)


def _embed_tokens(cfg: ModelConfig, p: Dict, batch: Dict) -> jax.Array:
    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        vis = batch["patches"] @ p["patch_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    return constrain(x, ("batch", "seq", "act_embed"))


def _unembed(cfg: ModelConfig, p: Dict, h: jax.Array) -> jax.Array:
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


# ---------------------------------------------------------------------------
# Full-sequence stacks (train / prefill)
# ---------------------------------------------------------------------------

def _ffn(cfg: ModelConfig, lp: Dict, j: int, k: int, x: jax.Array,
         aux: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sublayer j's FFN: MoE on the last sublayer of a super-layer."""
    if cfg.moe and j == k - 1:
        mp = lp["moe"]
        aux = aux + mlp_moe.moe_aux_loss(mp, cfg, x)
        return mlp_moe.moe(mp, cfg, x), aux
    return mlp_moe.mlp(_idx(lp["mlp"], j), cfg, x), aux


def _lm_stack_full(cfg: ModelConfig, dec: Dict, x: jax.Array, *,
                   memory: Optional[jax.Array], collect_cache: bool,
                   cache_len: int, remat: bool):
    """Decoder stack over the full sequence.

    Returns (hidden, aux_loss, per-layer cache pytree or None).
    """
    L = cfg.n_layers
    k = cfg.moe.interleave if cfg.moe else 1
    n_super = L // k

    xs = {
        "attn": _regroup(dec["attn"], n_super, k),
        "norm1": _regroup(dec["norm1"], n_super, k),
        "norm2": _regroup(dec["norm2"], n_super, k),
    }
    if cfg.moe:
        xs["moe"] = dec["moe"]  # (n_super, ...)
        if "mlp" in dec:
            xs["mlp"] = _regroup(dec["mlp"], n_super, k - 1)
    else:
        xs["mlp"] = _regroup(dec["mlp"], n_super, k)
    if cfg.family == "hybrid":
        xs["ssm"] = _regroup(dec["ssm"], n_super, k)
    if cfg.is_encdec:
        xs["cross"] = _regroup(dec["cross"], n_super, k)
        xs["norm3"] = _regroup(dec["norm3"], n_super, k)

    def body(carry, lp):
        x, aux = carry
        ys = []
        for j in range(k):
            a_in = rms_norm(x, _idx(lp["norm1"], j), cfg.norm_eps)
            ap = _idx(lp["attn"], j)
            if collect_cache:
                a_out, (kk, vv) = attn_mod.attend(ap, cfg, a_in, return_kv=True)
                rk = attn_mod.pack_ring(kk, cache_len)
                rv = attn_mod.pack_ring(vv, cache_len)
                if cfg.kv_quant:
                    qk, sk = attn_mod.quantize_kv(rk, cfg.n_kv_heads)
                    qv, sv = attn_mod.quantize_kv(rv, cfg.n_kv_heads)
                    y = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
                else:
                    y = {"k": rk, "v": rv}
            else:
                a_out = attn_mod.attend(ap, cfg, a_in)
                y = {}
            if cfg.family == "hybrid":
                m_out, s_f, conv_carry = mamba_mod.mamba_mix(
                    _idx(lp["ssm"], j), cfg, a_in,
                    jnp.zeros((x.shape[0], cfg.n_ssm_heads, cfg.ssm.head_dim,
                               cfg.ssm.state_size), jnp.float32))
                a_out = a_out + m_out
                if collect_cache:
                    y["ssm_state"] = s_f
                    if cfg.ssm.conv_width > 1:
                        y["conv_state"] = conv_carry
            x = x + a_out
            if cfg.is_encdec:
                c_in = rms_norm(x, _idx(lp["norm3"], j), cfg.norm_eps)
                cp = _idx(lp["cross"], j)
                x = x + attn_mod.attend(cp, cfg, c_in, causal=False,
                                        kv_x=memory, use_rope=False)
                if collect_cache:
                    ck, cv = attn_mod.cross_kv(cp, cfg, memory)
                    y["cross_k"], y["cross_v"] = ck, cv
            f_in = rms_norm(x, _idx(lp["norm2"], j), cfg.norm_eps)
            f_out, aux = _ffn(cfg, lp, j, k, f_in, aux)
            x = x + f_out
            ys.append(y)
        x = constrain(x, ("batch", "seq", "act_embed"))
        # stack sublayer cache slices -> leading dim k
        ys_st = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys[0] else None
        return (x, aux), ys_st

    if remat:
        body = _remat(body, cfg)
    (x, aux), cache_st = maybe_scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                    unroll=cfg.unroll)
    if collect_cache and cache_st is not None:
        # (n_super, k, ...) -> (L, ...)
        cache_st = jax.tree.map(
            lambda a: a.reshape((L,) + a.shape[2:]), cache_st)
    return x, aux, cache_st


def _rwkv_stack_full(cfg: ModelConfig, dec: Dict, x: jax.Array, *,
                     collect_cache: bool, remat: bool):
    B = x.shape[0]
    H, hd = cfg.n_ssm_heads, cfg.ssm.head_dim
    xs = {"tm": dec["tm"], "cm": dec["cm"],
          "norm1": dec["norm1"], "norm2": dec["norm2"]}

    def body(carry, lp):
        x, aux = carry
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        shift0 = jnp.zeros((B, cfg.d_model), x.dtype)
        a_in = rms_norm(x, lp["norm1"], cfg.norm_eps)
        tm_out, tm_shift, s_f = rwkv.time_mix(lp["tm"], cfg, a_in, shift0, s0)
        x = x + tm_out
        c_in = rms_norm(x, lp["norm2"], cfg.norm_eps)
        cm_out, cm_shift = rwkv.channel_mix(lp["cm"], cfg, c_in, shift0)
        x = x + cm_out
        y = ({"ssm_state": s_f, "shift_tm": tm_shift, "shift_cm": cm_shift}
             if collect_cache else None)
        return (x, aux), y

    if remat:
        body = _remat(body, cfg)
    (x, aux), cache_st = maybe_scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                    unroll=cfg.unroll)
    return x, aux, cache_st


def _encoder(cfg: ModelConfig, p: Dict, frames: jax.Array) -> jax.Array:
    x = frames @ p["frame_proj"]
    enc = p["enc"]
    xs = {"attn": enc["attn"], "mlp": enc["mlp"],
          "norm1": enc["norm1"], "norm2": enc["norm2"]}

    def body(x, lp):
        a_in = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn_mod.attend(lp["attn"], cfg, a_in, causal=False)
        f_in = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_moe.mlp(lp["mlp"], cfg, f_in)
        return x, None

    x, _ = maybe_scan(body, x, xs, unroll=cfg.unroll)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Dict, batch: Dict, *,
            is_train: bool = True, collect_cache: bool = False,
            cache_len: int = 0):
    """Hidden states (B,S,D) after final norm (+ aux loss, + prefill cache)."""
    memory = None
    if cfg.is_encdec:
        memory = _encoder(cfg, params, batch["frames"])
    x = _embed_tokens(cfg, params, batch)
    remat = is_train and cfg.remat != "none"
    if cfg.family == "ssm":
        h, aux, cache = _rwkv_stack_full(cfg, params["dec"], x,
                                         collect_cache=collect_cache,
                                         remat=remat)
    else:
        h, aux, cache = _lm_stack_full(cfg, params["dec"], x, memory=memory,
                                       collect_cache=collect_cache,
                                       cache_len=cache_len, remat=remat)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, cache


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy: never materialises fp32 (B,S,V) logits)
# ---------------------------------------------------------------------------

def chunked_xent(cfg: ModelConfig, params: Dict, h: jax.Array,
                 targets: jax.Array, chunk: int = 512):
    B, S, D = h.shape
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    c = chunk if S % chunk == 0 else S
    n = S // c
    hc = h.reshape(B, n, c, D).swapaxes(0, 1)
    tc = targets.reshape(B, n, c).swapaxes(0, 1)
    pad_mask = (jnp.arange(cfg.padded_vocab) >= cfg.vocab_size) * (-1e30)

    def body(acc, xs):
        hh, tt = xs
        logits = jnp.einsum("bcd,dv->bcv", hh, w,
                            preferred_element_type=jnp.float32) + pad_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        loss = jnp.sum(lse - gold)
        correct = jnp.sum(jnp.argmax(logits, -1) == tt)
        return (acc[0] + loss, acc[1] + correct), None

    (loss, correct), _ = maybe_scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, tc), unroll=cfg.unroll)
    ntok = B * S
    return loss / ntok, correct / ntok


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            aux_weight: float = 0.01):
    h, aux, _ = forward(cfg, params, batch, is_train=True)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        h = h[:, batch["patches"].shape[1]:, :]
    # keep the backward residual stream in model dtype (see grad_cast)
    from repro.models.common import grad_cast
    loss, acc = chunked_xent(cfg, params, grad_cast(h, cfg.jnp_dtype),
                             batch["targets"])
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def prefill_step(cfg: ModelConfig, params: Dict, batch: Dict,
                 max_len: Optional[int] = None,
                 true_lens: Optional[jax.Array] = None):
    """Run the prompt, return (cache, last-token logits).

    ``true_lens`` (B,) supports right-padded prompts (serving engine
    bucketing): logits are taken at each row's true last token and the
    decode position starts there — padded ring slots are provably masked
    at decode because their slot position exceeds ``pos``.
    """
    if cfg.is_encdec:
        S = batch["tokens"].shape[1] + batch["frames"].shape[1]
    else:
        S = batch["tokens"].shape[1]
        if cfg.frontend == "vision_patches" and "patches" in batch:
            S += batch["patches"].shape[1]
    C = effective_cache_len(cfg, max_len or S)
    h, _, cache = forward(cfg, params, batch, is_train=False,
                          collect_cache=True, cache_len=C)
    B = h.shape[0]
    cache = dict(cache or {})
    n_dec_tokens = batch["tokens"].shape[1] if cfg.is_encdec else S
    if true_lens is None:
        pos = jnp.full((B,), n_dec_tokens, jnp.int32)
        logits = _unembed(cfg, params, h[:, -1:, :])
    else:
        pos = true_lens.astype(jnp.int32)
        offset = 0
        if cfg.frontend == "vision_patches" and "patches" in batch:
            offset = batch["patches"].shape[1]
        idx = jnp.clip(true_lens - 1 + offset, 0, h.shape[1] - 1)
        logits = _unembed(cfg, params,
                          h[jnp.arange(B), idx][:, None, :])
    cache["pos"] = pos
    return cache, logits


def decode_step(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                cache: Dict):
    """One decode step for the whole batch. tokens: (B,1)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", "seq", "act_embed"))
    pos = cache["pos"]
    dec = params["dec"]
    L = cfg.n_layers
    k = cfg.moe.interleave if cfg.moe else 1
    n_super = L // k

    if cfg.family == "ssm":
        xs = ({"tm": dec["tm"], "cm": dec["cm"], "norm1": dec["norm1"],
               "norm2": dec["norm2"]},
              {"ssm_state": cache["ssm_state"], "shift_tm": cache["shift_tm"],
               "shift_cm": cache["shift_cm"]})

        def body(x, xs_i):
            lp, lc = xs_i
            a_in = rms_norm(x, lp["norm1"], cfg.norm_eps)
            tm_out, tm_shift, s = rwkv.time_mix_step(
                lp["tm"], cfg, a_in, lc["shift_tm"], lc["ssm_state"])
            x = x + tm_out
            c_in = rms_norm(x, lp["norm2"], cfg.norm_eps)
            cm_out, cm_shift = rwkv.channel_mix(
                lp["cm"], cfg, c_in, lc["shift_cm"])
            x = x + cm_out
            return x, {"ssm_state": s, "shift_tm": tm_shift,
                       "shift_cm": cm_shift}

        x, new_c = maybe_scan(body, x, xs, unroll=cfg.unroll)
        new_cache = dict(cache)
        new_cache.update(new_c)
        new_cache["pos"] = pos + 1
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return _unembed(cfg, params, h), new_cache

    # attention families
    lp_xs = {
        "attn": _regroup(dec["attn"], n_super, k),
        "norm1": _regroup(dec["norm1"], n_super, k),
        "norm2": _regroup(dec["norm2"], n_super, k),
    }
    if cfg.moe:
        lp_xs["moe"] = dec["moe"]
        if "mlp" in dec:
            lp_xs["mlp"] = _regroup(dec["mlp"], n_super, k - 1)
    else:
        lp_xs["mlp"] = _regroup(dec["mlp"], n_super, k)
    if cfg.family == "hybrid":
        lp_xs["ssm"] = _regroup(dec["ssm"], n_super, k)
    if cfg.is_encdec:
        lp_xs["cross"] = _regroup(dec["cross"], n_super, k)
        lp_xs["norm3"] = _regroup(dec["norm3"], n_super, k)

    lc_xs = {kk: vv.reshape((n_super, k) + vv.shape[1:])
             for kk, vv in cache.items() if kk != "pos"}

    def body(carry, xs_i):
        x, aux = carry
        lp, lc = xs_i
        # cross-attention K/V is read-only at decode time: not re-emitted
        new_lc = {kk: [] for kk in lc if not kk.startswith("cross_")}
        for j in range(k):
            a_in = rms_norm(x, _idx(lp["norm1"], j), cfg.norm_eps)
            if cfg.kv_quant:
                a_out, k2, v2, ks2, vs2 = attn_mod.decode_attend(
                    _idx(lp["attn"], j), cfg, a_in, pos,
                    lc["k"][j], lc["v"][j],
                    lc["k_scale"][j], lc["v_scale"][j])
                new_lc["k_scale"].append(ks2)
                new_lc["v_scale"].append(vs2)
            else:
                a_out, k2, v2 = attn_mod.decode_attend(
                    _idx(lp["attn"], j), cfg, a_in, pos,
                    lc["k"][j], lc["v"][j])
            new_lc["k"].append(k2)
            new_lc["v"].append(v2)
            if cfg.family == "hybrid":
                cw = cfg.ssm.conv_width
                if cw > 1:
                    m_out, s2, cc2 = mamba_mod.mamba_step(
                        _idx(lp["ssm"], j), cfg, a_in,
                        lc["ssm_state"][j], lc["conv_state"][j])
                    new_lc["conv_state"].append(cc2)
                else:
                    m_out, s2, _ = mamba_mod.mamba_step(
                        _idx(lp["ssm"], j), cfg, a_in, lc["ssm_state"][j],
                        None)
                new_lc["ssm_state"].append(s2)
                a_out = a_out + m_out
            x = x + a_out
            if cfg.is_encdec:
                c_in = rms_norm(x, _idx(lp["norm3"], j), cfg.norm_eps)
                x = x + attn_mod.cross_decode_attend(
                    _idx(lp["cross"], j), cfg, c_in,
                    lc["cross_k"][j], lc["cross_v"][j])
            f_in = rms_norm(x, _idx(lp["norm2"], j), cfg.norm_eps)
            f_out, aux = _ffn(cfg, lp, j, k, f_in, aux)
            x = x + f_out
        new_lc = {kk: jnp.stack(vv) for kk, vv in new_lc.items()}
        return (x, aux), new_lc

    (x, _), new_c = maybe_scan(body, (x, jnp.zeros((), jnp.float32)),
                               (lp_xs, lc_xs), unroll=cfg.unroll)
    new_cache = {kk: vv.reshape((L,) + vv.shape[2:])
                 for kk, vv in new_c.items()}
    for kk in ("cross_k", "cross_v"):
        if kk in cache:
            new_cache[kk] = cache[kk]
    new_cache["pos"] = pos + 1
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, h), new_cache

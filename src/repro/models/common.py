"""Parameter construction with attached logical sharding axes.

Params are built as pytrees of :class:`Boxed` (value + logical axes), then
split into a value tree and an axes tree. The axes tree feeds
``repro.distributed.sharding`` to derive NamedShardings on any mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Boxed:
    value: object          # jax.Array or ShapeDtypeStruct
    axes: Tuple[str, ...]  # logical axis per dim


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """(values, axes) trees from a Boxed tree."""
    vals = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return vals, axes


class Init:
    """Splittable rng + param factory used by all module ``init`` functions."""

    def __init__(self, rng: jax.Array, dtype=jnp.bfloat16, abstract: bool = False):
        self._rng = rng
        self.dtype = dtype
        self.abstract = abstract  # build ShapeDtypeStructs only (dry-run)

    def _next(self) -> jax.Array:
        self._rng, k = jax.random.split(self._rng)
        return k

    def param(self, shape, axes, scale: float = 1.0, mode: str = "normal") -> Boxed:
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return Boxed(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        if mode == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif mode == "ones":
            v = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale / np.sqrt(max(fan_in, 1))
            v = (jax.random.truncated_normal(self._next(), -2.0, 2.0, shape, jnp.float32)
                 * std).astype(self.dtype)
        return Boxed(v, tuple(axes))

    def zeros(self, shape, axes) -> Boxed:
        return self.param(shape, axes, mode="zeros")

    def ones(self, shape, axes) -> Boxed:
        return self.param(shape, axes, mode="ones")


def maybe_scan(body: Callable, carry, xs, unroll: bool = False):
    """``lax.scan`` or an equivalent python loop (see ModelConfig.unroll)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def abstract_init(init_fn: Callable, cfg) -> Tuple[object, object]:
    """(ShapeDtypeStruct params, axes) without allocating anything."""
    ini = Init(jax.random.PRNGKey(0), dtype=cfg.jnp_dtype, abstract=True)
    return unbox(init_fn(ini, cfg))


# ---------------------------------------------------------------------------
# Numerics shared by all model families
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """Identity forward; casts the COTANGENT to ``dtype`` in backward.

    The fp32 loss head otherwise makes the residual-stream cotangent fp32
    through every layer, doubling the bytes of every TP all-reduce /
    all-gather of activation gradients (EXPERIMENTS §Perf, mixtral it.2)."""
    return x


def _grad_cast_fwd(x, dtype):
    return x, None


def _grad_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    # computed in the input dtype: an f32 cast here makes the BACKWARD
    # gradients (incl. the MoE dL/dxe all-reduce across the model axis)
    # fp32, doubling the dominant collective bytes (EXPERIMENTS §Perf it.2)
    return jax.nn.silu(x_gate) * x_up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)

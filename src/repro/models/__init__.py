from repro.models.common import Boxed, Init, abstract_init, unbox  # noqa: F401
from repro.models.model import (  # noqa: F401
    decode_step,
    forward,
    init_model,
    loss_fn,
    prefill_step,
)

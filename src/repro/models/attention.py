"""Attention for all families: GQA, RoPE, qk-norm, QKV bias, sliding-window,
chunked-local (llama4/iRoPE-style), cross-attention, ring-buffer decode cache.

Training/prefill attention is *chunked-query*: we scan over query chunks and
compute (chunk x S) score tiles, so the S x S score matrix is never
materialised (required for the 32K-token prefill shapes). The Pallas flash
kernel in ``repro.kernels`` is the TPU hot path; this XLA path is the
portable reference and what the dry-run lowers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import Init, maybe_scan, rms_norm, rope

NEG_INF = -1e30


def init_attention(ini: Init, cfg: ModelConfig, n_layers: int,
                   n_q_heads: Optional[int] = None, cross: bool = False) -> Dict:
    hq = n_q_heads if n_q_heads is not None else cfg.n_attn_heads
    d, hd, kv = cfg.d_model, cfg.head_dim_, cfg.n_kv_heads
    L = (n_layers,)
    p = {
        "wq": ini.param(L + (d, hq * hd), ("layers", "embed", "heads")),
        "wk": ini.param(L + (d, kv * hd), ("layers", "embed", "kv")),
        "wv": ini.param(L + (d, kv * hd), ("layers", "embed", "kv")),
        "wo": ini.param(L + (hq * hd, d), ("layers", "heads", "embed"),
                        scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = ini.zeros(L + (hq * hd,), ("layers", "heads"))
        p["bk"] = ini.zeros(L + (kv * hd,), ("layers", "kv"))
        p["bv"] = ini.zeros(L + (kv * hd,), ("layers", "kv"))
    if cfg.qk_norm and not cross:
        p["q_norm"] = ini.ones(L + (hd,), ("layers", ""))
        p["k_norm"] = ini.ones(L + (hd,), ("layers", ""))
    return p


def _project_qkv(p: Dict, cfg: ModelConfig, x: jax.Array,
                 kv_x: Optional[jax.Array] = None):
    """Returns q (B,S,KV,G,hd), k,v (B,Skv,KV,hd)."""
    src = x if kv_x is None else kv_x
    hd, kvh = cfg.head_dim_, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q, ("batch", "seq", "act_heads"))
    hq = q.shape[-1] // hd
    g = hq // kvh
    q = q.reshape(*q.shape[:2], kvh, g, hd)
    k = k.reshape(*k.shape[:2], kvh, hd)
    v = v.reshape(*v.shape[:2], kvh, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _mask(qpos: jax.Array, kpos: jax.Array, cfg: ModelConfig,
          causal: bool) -> jax.Array:
    """(len(qpos), len(kpos)) additive mask in fp32."""
    qp, kp = qpos[:, None], kpos[None, :]
    ok = jnp.ones(qp.shape[:1] + kp.shape[1:], dtype=bool)
    if causal:
        ok &= kp <= qp
    if cfg.sliding_window is not None:
        ok &= (qp - kp) < cfg.sliding_window
    if cfg.attn_chunk is not None:
        ok &= (qp // cfg.attn_chunk) == (kp // cfg.attn_chunk)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _pick_chunk(s: int, target: int = 1024) -> int:
    if s <= target:
        return s
    c = target
    while s % c:
        c //= 2
    return max(c, 1)


def attend(p: Dict, cfg: ModelConfig, x: jax.Array, *,
           causal: bool = True, kv_x: Optional[jax.Array] = None,
           use_rope: bool = True, return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B,S,D) -> (B,S,D).

    With ``return_kv`` also returns the (roped) flat K/V (B,S,KV*hd) for
    prefill cache construction."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x=kv_x)
    Skv = k.shape[1]
    hd = cfg.head_dim_
    qpos = jnp.arange(S, dtype=jnp.int32)
    kpos = jnp.arange(Skv, dtype=jnp.int32)
    if use_rope and kv_x is None:
        q = rope(q.reshape(B, S, -1, hd), qpos, cfg.rope_theta).reshape(q.shape)
        k = rope(k, kpos, cfg.rope_theta)
    scale = hd ** -0.5

    c = _pick_chunk(S)
    n = S // c
    qc = q.reshape(B, n, c, *q.shape[2:]).transpose(1, 0, 2, 3, 4, 5)
    qposc = qpos.reshape(n, c)

    # Local-attention KV slicing: with a sliding window (or chunked-local
    # attention) each query chunk only needs a bounded KV range — slicing
    # it out (static size, dynamic start) removes the O(S^2) wasted score
    # FLOPs that full-row chunked attention pays (EXPERIMENTS §Perf it.1,
    # hymba prefill: 32x fewer attention FLOPs at window=1024, S=32K).
    kv_span = None
    if causal and kv_x is None and Skv == S:
        if cfg.sliding_window is not None:
            kv_span = min(Skv, cfg.sliding_window - 1 + c)
        elif cfg.attn_chunk is not None and cfg.attn_chunk % c == 0:
            kv_span = min(Skv, cfg.attn_chunk)

    def body(_, xs):
        qi, qpi = xs  # (B,c,KV,G,hd), (c,)
        if kv_span is None:
            ks, vs, kp = k, v, kpos
        else:
            if cfg.sliding_window is not None:
                start = qpi[0] - (kv_span - c)
            else:  # chunked-local: the enclosing attention chunk
                start = (qpi[0] // cfg.attn_chunk) * cfg.attn_chunk
            start = jnp.clip(start, 0, Skv - kv_span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kp = start + jnp.arange(kv_span, dtype=jnp.int32)
        s = jnp.einsum("bckgh,btkh->bkgct", qi, ks,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask(qpi, kp, cfg, causal)[None, None, None]
        w = jax.nn.softmax(s, axis=-1).astype(vs.dtype)
        o = jnp.einsum("bkgct,btkh->bckgh", w, vs)
        return None, o

    _, out = maybe_scan(body, None, (qc, qposc), unroll=cfg.unroll)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, -1)
    out = constrain(out, ("batch", "seq", "act_heads"))
    proj = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if return_kv:
        return proj, (k.reshape(B, Skv, -1), v.reshape(B, Skv, -1))
    return proj


def pack_ring(kv: jax.Array, cache_len: int) -> jax.Array:
    """Place a prefilled K/V sequence (B,S,F) into its ring-buffer slots
    (token t -> slot t %% C), keeping only the last ``cache_len`` tokens."""
    B, S, F = kv.shape
    C = cache_len
    if S == C:
        return kv
    if S > C:
        tail = kv[:, S - C:]
        return jnp.roll(tail, S % C, axis=1)
    pad = jnp.zeros((B, C - S, F), kv.dtype)
    return jnp.concatenate([kv, pad], axis=1)


# ---------------------------------------------------------------------------
# int8 KV quantization (per-token-per-head symmetric)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, n_kv_heads: int):
    """x: (..., KVH*hd) -> (int8 codes same shape, scales (..., KVH))."""
    hd = x.shape[-1] // n_kv_heads
    xr = x.reshape(x.shape[:-1] + (n_kv_heads, hd)).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xr), axis=-1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xr / scale[..., None]), -127, 127)
    return (q.astype(jnp.int8).reshape(x.shape),
            scale.astype(x.dtype))


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Inverse of quantize_kv; returns (..., KVH*hd) in ``dtype``."""
    kvh = scale.shape[-1]
    hd = q.shape[-1] // kvh
    xr = q.reshape(q.shape[:-1] + (kvh, hd)).astype(jnp.float32)
    xr = xr * scale[..., None].astype(jnp.float32)
    return xr.reshape(q.shape).astype(dtype)


# ---------------------------------------------------------------------------
# Decode path (ring-buffer KV cache)
# ---------------------------------------------------------------------------

def decode_attend(p: Dict, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                  k_cache: jax.Array, v_cache: jax.Array,
                  k_scale: Optional[jax.Array] = None,
                  v_scale: Optional[jax.Array] = None):
    """One-token attention against the cache.

    x: (B,1,D); pos: (B,) tokens generated so far; k/v_cache: (B,C,KV*hd)
    (ring buffer — token t lives in slot t %% C; int8 when cfg.kv_quant,
    with per-token-per-head scales). Returns (out, k', v'[, ks', vs'])."""
    B, _, _ = x.shape
    C = k_cache.shape[1]
    hd, kvh = cfg.head_dim_, cfg.n_kv_heads
    q, k_new, v_new = _project_qkv(p, cfg, x)
    q = rope(q.reshape(B, 1, -1, hd), pos[:, None], cfg.rope_theta).reshape(q.shape)
    k_new = rope(k_new, pos[:, None], cfg.rope_theta)

    slot = (pos % C).astype(jnp.int32)
    bidx = jnp.arange(B)
    kn = k_new[:, 0].reshape(B, -1)
    vn = v_new[:, 0].reshape(B, -1)
    if cfg.kv_quant:
        kn_q, kn_s = quantize_kv(kn, kvh)
        vn_q, vn_s = quantize_kv(vn, kvh)
        k_cache = k_cache.at[bidx, slot].set(kn_q)
        v_cache = v_cache.at[bidx, slot].set(vn_q)
        k_scale = k_scale.at[bidx, slot].set(kn_s)
        v_scale = v_scale.at[bidx, slot].set(vn_s)
        kc = dequantize_kv(k_cache, k_scale, x.dtype).reshape(B, C, kvh, hd)
        vc = dequantize_kv(v_cache, v_scale, x.dtype).reshape(B, C, kvh, hd)
    else:
        k_cache = k_cache.at[bidx, slot].set(kn)
        v_cache = v_cache.at[bidx, slot].set(vn)
        kc = k_cache.reshape(B, C, kvh, hd)
        vc = v_cache.reshape(B, C, kvh, hd)

    # slot j holds position pslot[j] = pos - ((pos - j) mod C)  (after write,
    # cache holds positions (pos-C, pos]); valid iff 0 <= pslot <= pos and
    # within window/chunk of the current position.
    j = jnp.arange(C, dtype=jnp.int32)[None, :]
    pnow = pos[:, None].astype(jnp.int32)
    pslot = pnow - jnp.mod(pnow - j, C)
    ok = pslot >= 0
    if cfg.sliding_window is not None:
        ok &= (pnow - pslot) < cfg.sliding_window
    if cfg.attn_chunk is not None:
        ok &= (pslot // cfg.attn_chunk) == (pnow // cfg.attn_chunk)
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (B,C)

    # q from _project_qkv is (B,1,KV,G,hd) -> squeeze the seq dim
    s = jnp.einsum("bkgh,btkh->bkgt", q[:, 0], kc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = s + mask[:, None, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    o = jnp.einsum("bkgt,btkh->bkgh", w, vc).reshape(B, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    if cfg.kv_quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def cross_decode_attend(p: Dict, cfg: ModelConfig, x: jax.Array,
                        cross_k: jax.Array, cross_v: jax.Array) -> jax.Array:
    """Decoder cross-attention against precomputed encoder KV.

    x: (B,1,D); cross_k/v: (B,S_enc,KV*hd).
    """
    B = x.shape[0]
    hd, kvh = cfg.head_dim_, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, 1, kvh, -1, hd)
    kc = cross_k.reshape(B, cross_k.shape[1], kvh, hd)
    vc = cross_v.reshape(B, cross_v.shape[1], kvh, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", q[:, 0], kc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    w = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    o = jnp.einsum("bkgt,btkh->bkgh", w, vc).reshape(B, 1, -1)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def cross_kv(p: Dict, cfg: ModelConfig, memory: jax.Array):
    """Precompute cross-attention K/V from encoder memory (B,S_enc,D)."""
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"])
    return k, v

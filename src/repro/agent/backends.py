"""LLM decision-model backends.

``SimLLM`` is the offline stand-in for the paper's GPT endpoints: a
deterministic, seeded simulator whose (a) cache-operation decisions are
produced by actually *parsing the same prompts* the paper would send to GPT,
with a calibrated error rate matching the paper's measured GPT-hit rates
(~96-98%), and (b) agent-quality profile (success / correctness / task
metrics) matches Table I per (model x prompting x shot) cell.

``JaxLLM`` routes ``complete()`` through the real JAX serving engine
(`repro.serving`) — used in the examples with the dcache-agent-150m model.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import random
import re
from typing import Dict, Optional

from repro.core.prompts import LLMParseError, parse_json_tail

# Table I targets: (success, correctness, obj-det F1, LCC recall, VQA rouge)
PROFILES: Dict[tuple, Dict[str, float]] = {
    ("gpt-3.5-turbo", "cot", False): dict(
        success=0.4945, corr=0.3847, f1=0.7068, lcc=0.7019, rouge=0.5662),
    ("gpt-3.5-turbo", "cot", True): dict(
        success=0.5442, corr=0.7050, f1=0.8903, lcc=0.8219, rouge=0.6258),
    ("gpt-3.5-turbo", "react", False): dict(
        success=0.5085, corr=0.7004, f1=0.8794, lcc=0.8912, rouge=0.6141),
    ("gpt-3.5-turbo", "react", True): dict(
        success=0.6345, corr=0.7106, f1=0.8259, lcc=0.9236, rouge=0.6935),
    ("gpt-4-turbo", "cot", False): dict(
        success=0.7048, corr=0.8204, f1=0.8634, lcc=0.8491, rouge=0.6978),
    ("gpt-4-turbo", "cot", True): dict(
        success=0.7289, corr=0.8487, f1=0.8375, lcc=0.9729, rouge=0.7215),
    ("gpt-4-turbo", "react", False): dict(
        success=0.7430, corr=0.8580, f1=0.8849, lcc=0.9452, rouge=0.7218),
    ("gpt-4-turbo", "react", True): dict(
        success=0.7671, corr=0.8567, f1=0.6449, lcc=0.9895, rouge=0.7423),
}

# cache-decision error rates calibrated to Table III GPT-hit rates
CACHE_EPS = {"gpt-3.5-turbo": 0.055, "gpt-4-turbo": 0.034}


@dataclasses.dataclass
class Profile:
    model: str
    prompting: str      # "cot" | "react"
    few_shot: bool

    @property
    def targets(self) -> Dict[str, float]:
        return PROFILES[(self.model, self.prompting, self.few_shot)]

    @property
    def cache_eps(self) -> float:
        return CACHE_EPS[self.model]


class SimLLM:
    """Deterministic GPT stand-in (see module docstring)."""

    def __init__(self, profile: Profile, seed: int = 0):
        self.profile = profile
        ident = f"{seed}|{profile.model}|{profile.prompting}|{profile.few_shot}"
        self.rng = random.Random(
            int.from_bytes(hashlib.blake2b(ident.encode(),
                                           digest_size=8).digest(), "big"))

    # -- generic completion --------------------------------------------------
    def complete(self, prompt: str) -> str:
        handler = None
        if "Respond with a JSON object mapping each key" in prompt:
            handler = self._read_decision
        elif "return the NEW cache state" in prompt:
            handler = self._update_decision
        elif "ADMIT the candidate" in prompt:
            handler = self._admission_decision
        elif "REPLICATION controller" in prompt:
            handler = self._replication_decision
        elif "RECOVERY controller" in prompt:
            handler = self._recovery_decision
        elif "COHERENCE controller" in prompt:
            handler = self._coherence_decision
        elif "PLAN-CACHE controller" in prompt:
            handler = self._plan_cache_decision
        if handler is None:
            # planning / answer prompts: canned completion (token accounting
            # is handled by the agent's latency model)
            return ("Thought: I will decompose the task and call the tools "
                    "in order.\nAction: proceed.")
        try:
            return handler(prompt)
        except LLMParseError:
            raise
        except (AttributeError, IndexError, KeyError, TypeError,
                ValueError) as exc:
            # a prompt the parser cannot read (missing evidence line, garbled
            # JSON, bad numeric field) is a typed parse failure, never a raw
            # AttributeError/JSONDecodeError bubbling into the caller
            raise LLMParseError(
                f"unparseable {handler.__name__} prompt: {exc!r}") from exc

    # -- cache READ ----------------------------------------------------------
    def _read_decision(self, prompt: str) -> str:
        keys = parse_json_tail(
            re.search(r"Required keys: (\[.*?\])", prompt).group(1))
        # the live cache-contents line is the LAST "Cache:" line (few-shot
        # examples above it also contain Cache: lines)
        cache = json.loads(re.findall(r"Cache: (\{.*\})", prompt)[-1])
        eps = self.profile.cache_eps
        out = {}
        for k in keys:
            correct = "read_cache" if k in cache else "load_db"
            if self.rng.random() < eps:
                correct = ("load_db" if correct == "read_cache"
                           else "read_cache")
            out[k] = correct
        return ("Thought: comparing required keys against cache contents.\n"
                f"Answer: {json.dumps(out)}")

    # -- cache UPDATE --------------------------------------------------------
    def _update_decision(self, prompt: str) -> str:
        cache = json.loads(
            re.findall(r"Current cache: (\{.*\})", prompt)[-1])
        loads = parse_json_tail(
            re.search(r"this round: (\[.*?\])", prompt).group(1))
        cap = int(re.search(r"at most (\d+) entries", prompt).group(1))
        policy = prompt.lower()
        state = dict(cache)
        protected = set(loads)  # just-loaded keys are the most recent
        for k in loads:
            if k in state:
                continue
            if len(state) >= cap:
                victim = self._victim(state, policy, protected)
                state.pop(victim)
            state[k] = {}
        keys = list(state)
        eps = self.profile.cache_eps
        if len(cache) >= cap and loads and self.rng.random() < eps:
            # LLM slip: evicts the wrong entry
            keys = self._perturb(cache, loads, cap)
        return ("Thought: applying the update policy as described.\n"
                f"Answer: {json.dumps(keys)}")

    # -- cache ADMISSION -----------------------------------------------------
    def _admission_decision(self, prompt: str) -> str:
        """Admission decided by *reading the policy text* (like eviction):
        the frequency estimates are in the prompt, the rule is in the
        policy description, and the calibrated error rate applies."""
        # the live lines are the LAST matches (few-shot examples above them
        # also contain Candidate/victim frequency lines)
        kf = int(re.findall(r"Candidate key: \S+ \(estimated frequency: "
                            r"(\d+)\)", prompt)[-1])
        vf = int(re.findall(r"Eviction victim if admitted: \S+ \(estimated "
                            r"frequency: (\d+)\)", prompt)[-1])
        # the live policy line precedes the few-shot examples (which mention
        # other policies): take the FIRST match
        policy = re.search(r"Admission policy: (.*)", prompt).group(1).lower()
        if "strictly higher" in policy:
            admit = kf > vf
        elif "at least twice" in policy:
            admit = kf >= 2
        elif "always-admit" in policy or "never bypass" in policy:
            admit = True
        else:
            admit = kf > vf
        if self.rng.random() < self.profile.cache_eps:
            admit = not admit
        decision = "admit" if admit else "bypass"
        return ("Thought: weighing the candidate's frequency against the "
                "victim's under the stated policy.\n"
                f'Answer: {json.dumps({"decision": decision})}')

    # -- hot-key REPLICATION -------------------------------------------------
    def _replication_decision(self, prompt: str) -> str:
        """Replication decided by reading the policy text: the sketch
        estimate, current replica state and thresholds are all in the
        prompt; the calibrated error rate applies (a slip lands on the
        nearest wrong decision — promoting a cold key or holding a hot
        one — never on the opposite extreme)."""
        freq, rep = re.findall(
            r"Key: \S+ \(estimated frequency: (\d+); currently "
            r"replicated: (yes|no)\)", prompt)[-1]
        freq, replicated = int(freq), rep == "yes"
        promote = int(re.findall(r"replicate at >= (\d+)", prompt)[-1])
        demote = int(re.findall(r"drop a replica at < (\d+)", prompt)[-1])
        if not replicated:
            decision = "replicate" if freq >= promote else "hold"
        elif freq < demote:
            decision = "drop"
        else:
            decision = "hold"
        if self.rng.random() < self.profile.cache_eps:
            if decision == "hold":
                decision = "drop" if replicated else "replicate"
            else:
                decision = "hold"
        return ("Thought: comparing the key's frequency against the "
                "promote/demote thresholds.\n"
                f'Answer: {json.dumps({"decision": decision})}')

    # -- post-failover RECOVERY ----------------------------------------------
    def _recovery_decision(self, prompt: str) -> str:
        """Failover recovery decided by reading the policy text: the lost
        key's sketch estimate and the re-warm threshold are in the prompt;
        the calibrated error rate flips the verdict."""
        freq = int(re.findall(r"Lost key: \S+ \(estimated frequency: "
                              r"(\d+)\)", prompt)[-1])
        rewarm_min = int(re.findall(r"re-warm at >= (\d+)", prompt)[-1])
        decision = "rewarm" if freq >= rewarm_min else "lazy"
        if self.rng.random() < self.profile.cache_eps:
            decision = "lazy" if decision == "rewarm" else "rewarm"
        return ("Thought: weighing the lost key's frequency against the "
                "re-warm threshold.\n"
                f'Answer: {json.dumps({"decision": decision})}')

    # -- cache COHERENCE (refresh vs serve-stale) ----------------------------
    def _coherence_decision(self, prompt: str) -> str:
        """Refresh-vs-serve-stale decided by reading the evidence block:
        the copy's staleness and the policy's declared bound are in the
        prompt; the calibrated error rate flips the verdict (the engine
        clamps beyond-bound serve_stale answers, so a slip can cost
        latency but never the staleness contract)."""
        staleness = float(re.findall(r'"staleness_s": ([0-9.]+)',
                                     prompt)[-1])
        bound = float(re.findall(r'"bound_s": ([0-9.eE+-]+)', prompt)[-1])
        decision = "serve_stale" if staleness <= bound else "refresh"
        if self.rng.random() < self.profile.cache_eps:
            decision = ("refresh" if decision == "serve_stale"
                        else "serve_stale")
        return ("Thought: weighing the copy's staleness against the "
                "declared bound.\n"
                f'Answer: {json.dumps({"decision": decision})}')

    # -- PLAN-CACHE admission (cache vs bypass a fresh plan) -----------------
    def _plan_cache_decision(self, prompt: str) -> str:
        """Plan-cache admission decided by reading the policy text: the
        candidate and victim plan frequencies are in the prompt; the
        calibrated error rate flips the verdict (a slip can cost planning
        rounds or churn a hot plan, never correctness — a served plan is
        always version-exact)."""
        kf = int(re.findall(r"Candidate plan: \S+ \(estimated frequency: "
                            r"(\d+)\)", prompt)[-1])
        vf = int(re.findall(r"Eviction victim if cached: \S+ \(estimated "
                            r"frequency: (\d+)\)", prompt)[-1])
        # live policy line precedes the few-shot examples: FIRST match
        policy = re.search(r"Plan-cache policy: (.*)", prompt).group(1).lower()
        floor = re.search(r"frequency is at least (\d+)", policy)
        cache = kf >= (int(floor.group(1)) if floor else 1) and kf >= vf
        if self.rng.random() < self.profile.cache_eps:
            cache = not cache
        decision = "cache" if cache else "bypass"
        return ("Thought: weighing the candidate plan's request frequency "
                "against the victim's under the stated policy.\n"
                f'Answer: {json.dumps({"decision": decision})}')

    def _victim(self, state: Dict[str, dict], policy_text: str,
                protected=()) -> str:
        def meta(k, field, default):
            v = state.get(k) or {}
            return v.get(field, default)
        keys = sorted(k for k in state if k not in protected) or sorted(state)
        if "least frequently" in policy_text:
            return min(keys, key=lambda k: (meta(k, "access_count", 0),
                                            meta(k, "last_access", 0)))
        if "first in first out" in policy_text:
            return min(keys, key=lambda k: meta(k, "insert_order", 0))
        if "random" in policy_text:
            return self.rng.choice(keys)
        if "farthest in the future" in policy_text:
            return keys[0]
        # default LRU
        return min(keys, key=lambda k: meta(k, "last_access", 0))

    def _perturb(self, cache, loads, cap):
        keys = sorted(cache)
        self.rng.shuffle(keys)
        keep = keys[: max(cap - len(loads), 0)]
        return (keep + list(loads))[:cap]

    # -- agent-quality error draws (used by the runner) ----------------------
    def draw_task_failure(self) -> bool:
        return self.rng.random() > self.profile.targets["success"]

    def draw_bad_calls(self) -> int:
        """Erroneous tool attempts preceding a correct call (geometric, so
        the correctness *ratio* converges to the profile target even below
        50%), capped to keep single traces bounded."""
        c = self.profile.targets["corr"]
        n = 0
        while n < 4 and self.rng.random() > c:
            n += 1
        return n

    def draw_step_corruption(self, kind: str) -> bool:
        t = self.profile.targets
        target = {"detect": t["f1"], "lcc": t["lcc"], "vqa": t["rouge"]}.get(
            kind, max(t["success"], 0.9))
        return self.rng.random() > target


class JaxLLM:
    """Real decision model: completions generated by the JAX serving engine.

    Constructed lazily from an ``repro.serving.engine.ServingEngine`` plus a
    byte-level tokenizer; used by examples/serve_agent.py.
    """

    def __init__(self, engine, max_new_tokens: int = 64):
        self.engine = engine
        self.max_new_tokens = max_new_tokens

    def complete(self, prompt: str) -> str:
        return self.engine.generate_text(prompt, self.max_new_tokens)

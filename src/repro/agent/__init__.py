from repro.agent.agent import AgentRunner, TaskTrace  # noqa: F401
from repro.agent.backends import PROFILES, JaxLLM, Profile, SimLLM  # noqa: F401
from repro.agent.concurrency import (  # noqa: F401
    ConcurrentEpisodeEngine,
    EpisodeMetrics,
    EpisodeResult,
    run_episode,
    session_seed,
)
from repro.agent.runtime import Runtime, build_runtime, build_tasks  # noqa: F401

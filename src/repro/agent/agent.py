"""Tool-augmented agent loop (CoT / ReAct, zero- and few-shot).

Execution pattern per task:
  1. read planning — the cache controller plans read_cache vs load_db per
     required key *up front* (the decision rides the planning round, paper:
     "seamlessly integrating with existing function-calling mechanisms");
     the :attr:`AgentRunner.on_plan` hook fires here, which is where the
     concurrent engine's async prefetcher overlaps pod loads with the
     planning round (docs/architecture.md);
  2. planning LLM round(s) — CoT plans once; ReAct interleaves a round per
     tool call (token/latency accounting follows the prompting style);
  3. data acquisition — executes the read plan; a cache MISS is a failed
     tool call that triggers a re-plan round (paper: the LLM "reassesses
     its tool sequence");
  4. step execution over the tool registry with the SimLLM's calibrated
     tool-error injections (erroneous call -> error result -> retry);
  5. cache update — prompt-driven (LLM) or programmatic, per controller;
  6. final answer round.

The loop is written as a generator (:meth:`AgentRunner.iter_task`) that
yields control after every simulated-clock advance (LLM round, tool call,
pod load), so a discrete-event scheduler can interleave many sessions with
*exact* global time ordering. :meth:`AgentRunner.run_task` simply drains
the generator — the single-session path is bit-identical to the plain loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.agent.backends import SimLLM
from repro.agent.geollm import geotools
from repro.agent.geollm.workload import Step, Task, _frame_var
from repro.core.controller import (LLMController, ProgrammaticController,
                                   ReadPlan)
from repro.core.plan_cache import task_template_id
from repro.core.tools import ToolRegistry

# token-accounting constants (calibrated to Table I "Avg Tokens/Task").
# CoT: one planning round over the whole task; ReAct: one thought/action
# round per step.
PLAN_PROMPT_TOKENS = {"cot": 11_000, "react": 5_500}
PLAN_PROMPT_TOKENS_FS = {"cot": 13_500, "react": 7_200}
PLAN_COMPLETION_TOKENS = {"cot": 260, "react": 55}
STEP_SUMMARY_TOKENS = 1_500
FINAL_PROMPT_TOKENS = 4_500
FINAL_COMPLETION_TOKENS = 150
BAD_CALL_TOKENS = 150          # error round-trip folded into the same round


@dataclasses.dataclass
class TaskTrace:
    tid: int
    success: bool
    time_s: float
    tokens: int
    tool_calls: int
    bad_calls: int
    cache_miss_replans: int
    answers: Dict[int, Any]       # step index -> produced answer
    # loads this task streamed through the cache uninstalled (admission
    # bypass); always 0 without an admission policy
    cache_bypasses: int = 0
    # fault accounting (filled by the concurrent engine's fault layer;
    # always zero without a FaultPlan): retry cycles this task's aborted
    # loads went through, the extra wait they charged, loads that fell
    # back to direct DB reads after exhausting the retry budget, and
    # service seconds wasted on pods that died mid-load
    retried_loads: int = 0
    retry_wait_s: float = 0.0
    timeout_loads: int = 0
    lost_work_s: float = 0.0
    # LLM decision-plane accounting (filled by the concurrent engine's
    # endpoint router; always zero without an EndpointFaultPlan): planning
    # rounds retried against another endpoint, hedged rounds and how many
    # the hedge won, and the wait seconds spent on detection/backoff
    llm_retries: int = 0
    llm_hedges: int = 0
    llm_hedge_wins: int = 0
    llm_retry_wait_s: float = 0.0
    # plan-cache tier (always zero without a PlanCache): planning rounds
    # this task skipped because a stored plan was served verbatim
    plancache_hits: int = 0


class AgentRunner:
    """Drives one agent session.

    ``on_plan`` is the plan-time hook: called as ``on_plan(task, plan)`` the
    moment the :class:`~repro.core.controller.ReadPlan` lands — *before* the
    planning LLM round is charged — so a scheduler can issue asynchronous
    pod loads that overlap the round's latency (the concurrent engine's
    prefetcher). ``None`` (the default) keeps the plain lazy-loading path.
    """

    def __init__(self, registry: ToolRegistry, controller, llm: SimLLM,
                 clock, datastore, use_cache: bool = True,
                 on_plan: Optional[Callable[[Task, Any], None]] = None,
                 endpoints=None, plan_cache=None):
        self.registry = registry
        self.controller = controller
        self.llm = llm
        self.clock = clock
        self.store = datastore
        self.use_cache = use_cache
        self.on_plan = on_plan
        # optional shared PlanCache (ISSUE 10): consult before planning; a
        # hit serves the stored read plan verbatim and skips the planning
        # round (zero plan tokens, no endpoint exposure). None = off, the
        # planning path is byte-identical to the pre-plan-cache engine.
        self.plan_cache = plan_cache
        # optional EndpointRouter: planning rounds route across the
        # simulated GPT endpoint pool and pay retry/hedge latency on this
        # session's clock. Cumulative counters; the engine snapshots them
        # around each task to fill the TaskTrace llm_* fields.
        self.endpoints = endpoints
        self.llm_retries = 0
        self.llm_hedges = 0
        self.llm_hedge_wins = 0
        self.llm_retry_wait_s = 0.0

    # -- latency/token helpers ------------------------------------------------
    def _llm_round(self, prompt_tokens: int, completion_tokens: int) -> int:
        nominal = self.clock.latency.llm_round(prompt_tokens,
                                               completion_tokens)
        self.clock.advance(nominal)
        ep = self.endpoints
        if ep is not None:
            # in-round token additions (miss re-plans, the _acquire prefill
            # ride-along) stay direct clock advances: they are part of this
            # round, not separate endpoint requests
            extra, retries, hedges, wins, wait_s = ep.plan_call(
                self.clock.now(), nominal, prompt_tokens + completion_tokens)
            if extra:
                self.clock.advance(extra)
            self.llm_retries += retries
            self.llm_hedges += hedges
            self.llm_hedge_wins += wins
            self.llm_retry_wait_s += wait_s
        return prompt_tokens + completion_tokens

    # -- acquisition ----------------------------------------------------------
    def _acquire(self, task: Task, env: Dict[str, Any], trace: TaskTrace,
                 plan):
        """Generator: executes the read plan, yielding after every clock
        advance. Returns the list of keys acquired via ``load_db``."""
        keys = task.required_keys
        loads: List[str] = []
        if not self.use_cache:
            for k in keys:
                res = self.registry.call("load_db", clock=self.clock, key=k)
                assert res.ok, res.error
                env[_frame_var(k)] = res.value
                trace.tool_calls += 1
                yield
            return loads

        if isinstance(self.controller, LLMController) and plan.prompt_tokens:
            # the read decision rides the existing planning round (paper:
            # "seamlessly integrating with existing function-calling
            # mechanisms with minimal overhead"): the prompt grows by the
            # cache-contents block (prefill time) while the Action line the
            # agent emits anyway simply names read_cache vs load_db (~a few
            # extra decode tokens, already part of the plan completion)
            lat = self.clock.latency
            self.clock.advance(
                plan.prompt_tokens * lat.llm_prefill_s_per_tok
                + 5 * lat.llm_decode_s_per_tok)
            trace.tokens += plan.prompt_tokens + plan.completion_tokens
            yield
        for k in keys:
            choice = plan.choices[k]
            res = self.registry.call(choice, clock=self.clock, key=k)
            trace.tool_calls += 1
            yield
            if not res.ok:
                # cache miss (or bad decision): the failed call's error
                # message returns in-round; the LLM corrects its tool choice
                # in the same round (token time, no extra round-trip)
                trace.bad_calls += 1
                trace.cache_miss_replans += 1
                lat = self.clock.latency
                self.clock.advance(900 * lat.llm_prefill_s_per_tok
                                   + 25 * lat.llm_decode_s_per_tok)
                trace.tokens += 925
                yield
                res = self.registry.call("load_db", clock=self.clock, key=k)
                trace.tool_calls += 1
                assert res.ok, res.error
                yield
            if choice == "load_db" or not res.ok:
                loads.append(k)
            env[_frame_var(k)] = res.value
        # reused keys refresh recency even when read via cache
        return loads

    # -- step execution ---------------------------------------------------------
    def _run_step(self, step: Step, env: Dict[str, Any], trace: TaskTrace,
                  react: bool, prompt_tokens: int):
        """Generator: executes one step's tool plan, yielding after every
        clock advance. Returns the step's answer value."""
        local = dict(env)
        answer = None
        if react:  # one thought/action round per step
            trace.tokens += self._llm_round(
                prompt_tokens, PLAN_COMPLETION_TOKENS["react"])
            yield
        for call in step.plan:
            # erroneous attempts (hallucinated tool / bad args) precede the
            # correct call; the error round-trip is folded into the round
            for _ in range(self.llm.draw_bad_calls()):
                trace.tool_calls += 1
                trace.bad_calls += 1
                self.registry.call(call.name + "_v2", clock=self.clock)
                trace.tokens += BAD_CALL_TOKENS
            args = {k: (local[v[1:]] if isinstance(v, str)
                        and v.startswith("$") else v)
                    for k, v in call.args.items()}
            res = self.registry.call(call.name, clock=self.clock, **args)
            trace.tool_calls += 1
            yield
            if not res.ok:
                trace.bad_calls += 1
                continue
            if call.out:
                local[call.out] = res.value
            if call.out == "answer":
                answer = res.value
        return answer

    # -- full task ----------------------------------------------------------
    def iter_task(self, task: Task):
        """Run one task as a generator yielding after every clock advance.

        The yields are the discrete-event scheduler's interleave points: a
        session is resumed only while its clock is the global minimum, so
        every shared-state operation between two yields (cache read/install,
        pod-load arbitration, read-plan decision) executes in exact global
        time order. The generator's return value (via ``StopIteration``) is
        the finished :class:`TaskTrace`.
        """
        t0 = self.clock.now()
        trace = TaskTrace(tid=task.tid, success=True, time_s=0.0, tokens=0,
                          tool_calls=0, bad_calls=0, cache_miss_replans=0,
                          answers={})
        prof = self.llm.profile
        react = prof.prompting == "react"
        plan_tokens = (PLAN_PROMPT_TOKENS_FS if prof.few_shot
                       else PLAN_PROMPT_TOKENS)[prof.prompting]

        # read planning happens up front (it rides the planning round): the
        # decisions are fixed here, but their latency/token accounting stays
        # where it always was (inside _acquire), so single-session traces
        # are unchanged. The on_plan hook lets a scheduler start the planned
        # loads NOW, overlapping them with the planning round below.
        plan = None
        plan_hit = False
        # the tier caches the up-front CoT planning round; ReAct has no
        # discrete planning round to skip (read decisions ride the per-step
        # thought/action rounds), so the cache would be pure lookup cost —
        # ReAct profiles bypass it entirely
        pc = self.plan_cache if not react else None
        if self.use_cache:
            if pc is not None:
                # plan-cache consult: one pod-local metadata read, charged
                # on hit AND miss (the lookup itself is never free)
                self.clock.advance(self.clock.latency.cache_read(0.0))
                cached = pc.lookup(task_template_id(task),
                                   task.required_keys, self.clock.now())
                yield
                if cached is not None:
                    plan = cached
                    plan_hit = True
                    trace.plancache_hits += 1
                    # replay correctness (mnimi's warning): the skipped
                    # planning round would have consumed eps draws from the
                    # shared decision RNG — burn the same draws so every
                    # later draw in the episode lands exactly where a
                    # forced-miss replay would put it
                    burn = getattr(self.controller, "consume_plan_noise",
                                   None)
                    if burn is not None:
                        burn(task.required_keys)
            if plan is None:
                plan = self.controller.plan_reads(task.query,
                                                  task.required_keys)
                if pc is not None:
                    # install a token-zeroed copy: a future hit serves the
                    # choices verbatim but charges zero plan tokens (and,
                    # for an LLMController, no prompt ride-along either)
                    pc.install(task_template_id(task), task.required_keys,
                               ReadPlan(dict(plan.choices)),
                               self.clock.now())
            if self.on_plan is not None:
                self.on_plan(task, plan)

        if not react and not plan_hit:
            # CoT: single planning round over the full task — skipped
            # entirely on a plan-cache hit (zero plan tokens, no endpoint
            # latency, no retry/hedge exposure)
            trace.tokens += self._llm_round(
                plan_tokens + STEP_SUMMARY_TOKENS * len(task.steps),
                PLAN_COMPLETION_TOKENS["cot"])
            yield

        env: Dict[str, Any] = {}
        loads = yield from self._acquire(task, env, trace, plan)

        task_failed = self.llm.draw_task_failure()
        for i, step in enumerate(task.steps):
            ans = yield from self._run_step(env=env, step=step, trace=trace,
                                            react=react,
                                            prompt_tokens=plan_tokens)
            if self.llm.draw_step_corruption(step.kind):
                ans = _corrupt(ans, self.llm)
            trace.answers[i] = ans
        if task_failed and task.steps:
            # the failing tasks resolve to a wrong/incomplete final answer
            trace.answers[len(task.steps) - 1] = None

        # cache update (prompt-driven when controller is LLM). The update
        # query runs OFF the critical path — after the user response, like
        # the paper's post-round bookkeeping (Table III shows ~0 latency
        # delta between GPT-driven and programmatic updates) — so it costs
        # tokens but not user-perceived latency.
        if self.use_cache and loads:
            def loader(k):
                return self.store.peek(k)
            upd = self.controller.update(loads, loader,
                                         lambda v: v.size_bytes)
            if isinstance(upd, dict) and upd.get("prompt_tokens"):
                trace.tokens += (upd["prompt_tokens"]
                                 + upd["completion_tokens"])
            if isinstance(upd, dict):
                trace.cache_bypasses = upd.get("bypassed", 0)

        # final answer round
        trace.tokens += self._llm_round(FINAL_PROMPT_TOKENS,
                                        FINAL_COMPLETION_TOKENS)
        trace.time_s = self.clock.now() - t0
        trace.success = not task_failed
        yield
        return trace

    def run_task(self, task: Task) -> TaskTrace:
        """Synchronous execution: drain :meth:`iter_task` to completion."""
        gen = self.iter_task(task)
        while True:
            try:
                next(gen)
            except StopIteration as stop:
                return stop.value


def _corrupt(ans: Any, llm: SimLLM):
    """Deterministic answer corruption for failed steps."""
    r = llm.rng
    if isinstance(ans, dict) and "detections" in ans:
        out = dict(ans)
        out["detections"] = int(ans["detections"] * r.uniform(0.2, 0.8))
        out["images"] = int(ans["images"] * r.uniform(0.2, 0.8))
        return out
    if isinstance(ans, list) and ans and isinstance(ans[0], str):
        return list(reversed(ans))
    if isinstance(ans, str):
        words = ans.split()
        r.shuffle(words)
        return " ".join(words[: max(len(words) // 2, 1)])
    if isinstance(ans, int):
        return int(ans * r.uniform(0.2, 0.8))
    if isinstance(ans, list):
        return [int(x * r.uniform(0.2, 0.8)) if isinstance(x, int) else x
                for x in ans]
    return None

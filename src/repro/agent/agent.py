"""Tool-augmented agent loop (CoT / ReAct, zero- and few-shot).

Execution pattern per task:
  1. planning LLM round(s) — CoT plans once; ReAct interleaves a round per
     tool call (token/latency accounting follows the prompting style);
  2. data acquisition — the cache controller plans read_cache vs load_db
     per required key; a cache MISS is a failed tool call that triggers a
     re-plan round (paper: the LLM "reassesses its tool sequence");
  3. step execution over the tool registry with the SimLLM's calibrated
     tool-error injections (erroneous call -> error result -> retry);
  4. cache update — prompt-driven (LLM) or programmatic, per controller;
  5. final answer round.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.agent.backends import SimLLM
from repro.agent.geollm import geotools
from repro.agent.geollm.workload import Step, Task, _frame_var
from repro.core.controller import LLMController, ProgrammaticController
from repro.core.tools import ToolRegistry

# token-accounting constants (calibrated to Table I "Avg Tokens/Task").
# CoT: one planning round over the whole task; ReAct: one thought/action
# round per step.
PLAN_PROMPT_TOKENS = {"cot": 11_000, "react": 5_500}
PLAN_PROMPT_TOKENS_FS = {"cot": 13_500, "react": 7_200}
PLAN_COMPLETION_TOKENS = {"cot": 260, "react": 55}
STEP_SUMMARY_TOKENS = 1_500
FINAL_PROMPT_TOKENS = 4_500
FINAL_COMPLETION_TOKENS = 150
BAD_CALL_TOKENS = 150          # error round-trip folded into the same round


@dataclasses.dataclass
class TaskTrace:
    tid: int
    success: bool
    time_s: float
    tokens: int
    tool_calls: int
    bad_calls: int
    cache_miss_replans: int
    answers: Dict[int, Any]       # step index -> produced answer


class AgentRunner:
    def __init__(self, registry: ToolRegistry, controller, llm: SimLLM,
                 clock, datastore, use_cache: bool = True):
        self.registry = registry
        self.controller = controller
        self.llm = llm
        self.clock = clock
        self.store = datastore
        self.use_cache = use_cache

    # -- latency/token helpers ------------------------------------------------
    def _llm_round(self, prompt_tokens: int, completion_tokens: int) -> int:
        self.clock.advance(self.clock.latency.llm_round(
            prompt_tokens, completion_tokens))
        return prompt_tokens + completion_tokens

    # -- acquisition ----------------------------------------------------------
    def _acquire(self, task: Task, env: Dict[str, Any], trace: TaskTrace):
        keys = task.required_keys
        loads: List[str] = []
        if not self.use_cache:
            for k in keys:
                res = self.registry.call("load_db", clock=self.clock, key=k)
                assert res.ok, res.error
                env[_frame_var(k)] = res.value
                trace.tool_calls += 1
            return loads

        plan = self.controller.plan_reads(task.query, keys)
        if isinstance(self.controller, LLMController) and plan.prompt_tokens:
            # the read decision rides the existing planning round (paper:
            # "seamlessly integrating with existing function-calling
            # mechanisms with minimal overhead"): the prompt grows by the
            # cache-contents block (prefill time) while the Action line the
            # agent emits anyway simply names read_cache vs load_db (~a few
            # extra decode tokens, already part of the plan completion)
            lat = self.clock.latency
            self.clock.advance(
                plan.prompt_tokens * lat.llm_prefill_s_per_tok
                + 5 * lat.llm_decode_s_per_tok)
            trace.tokens += plan.prompt_tokens + plan.completion_tokens
        for k in keys:
            choice = plan.choices[k]
            res = self.registry.call(choice, clock=self.clock, key=k)
            trace.tool_calls += 1
            if not res.ok:
                # cache miss (or bad decision): the failed call's error
                # message returns in-round; the LLM corrects its tool choice
                # in the same round (token time, no extra round-trip)
                trace.bad_calls += 1
                trace.cache_miss_replans += 1
                lat = self.clock.latency
                self.clock.advance(900 * lat.llm_prefill_s_per_tok
                                   + 25 * lat.llm_decode_s_per_tok)
                trace.tokens += 925
                res = self.registry.call("load_db", clock=self.clock, key=k)
                trace.tool_calls += 1
                assert res.ok, res.error
            if choice == "load_db" or not res.ok:
                loads.append(k)
            env[_frame_var(k)] = res.value
        # reused keys refresh recency even when read via cache
        return loads

    # -- step execution ---------------------------------------------------------
    def _run_step(self, step: Step, env: Dict[str, Any], trace: TaskTrace,
                  react: bool, prompt_tokens: int) -> Any:
        local = dict(env)
        answer = None
        if react:  # one thought/action round per step
            trace.tokens += self._llm_round(
                prompt_tokens, PLAN_COMPLETION_TOKENS["react"])
        for call in step.plan:
            # erroneous attempts (hallucinated tool / bad args) precede the
            # correct call; the error round-trip is folded into the round
            for _ in range(self.llm.draw_bad_calls()):
                trace.tool_calls += 1
                trace.bad_calls += 1
                self.registry.call(call.name + "_v2", clock=self.clock)
                trace.tokens += BAD_CALL_TOKENS
            args = {k: (local[v[1:]] if isinstance(v, str)
                        and v.startswith("$") else v)
                    for k, v in call.args.items()}
            res = self.registry.call(call.name, clock=self.clock, **args)
            trace.tool_calls += 1
            if not res.ok:
                trace.bad_calls += 1
                continue
            if call.out:
                local[call.out] = res.value
            if call.out == "answer":
                answer = res.value
        return answer


    # -- full task ----------------------------------------------------------
    def run_task(self, task: Task) -> TaskTrace:
        t0 = self.clock.now()
        trace = TaskTrace(tid=task.tid, success=True, time_s=0.0, tokens=0,
                          tool_calls=0, bad_calls=0, cache_miss_replans=0,
                          answers={})
        prof = self.llm.profile
        react = prof.prompting == "react"
        plan_tokens = (PLAN_PROMPT_TOKENS_FS if prof.few_shot
                       else PLAN_PROMPT_TOKENS)[prof.prompting]

        if not react:  # CoT: single planning round over the full task
            trace.tokens += self._llm_round(
                plan_tokens + STEP_SUMMARY_TOKENS * len(task.steps),
                PLAN_COMPLETION_TOKENS["cot"])

        env: Dict[str, Any] = {}
        loads = self._acquire(task, env, trace)

        task_failed = self.llm.draw_task_failure()
        for i, step in enumerate(task.steps):
            ans = self._run_step(step, env, trace, react, plan_tokens)
            if self.llm.draw_step_corruption(step.kind):
                ans = _corrupt(ans, self.llm)
            trace.answers[i] = ans
        if task_failed and task.steps:
            # the failing tasks resolve to a wrong/incomplete final answer
            trace.answers[len(task.steps) - 1] = None

        # cache update (prompt-driven when controller is LLM). The update
        # query runs OFF the critical path — after the user response, like
        # the paper's post-round bookkeeping (Table III shows ~0 latency
        # delta between GPT-driven and programmatic updates) — so it costs
        # tokens but not user-perceived latency.
        if self.use_cache and loads:
            def loader(k):
                return self.store.peek(k)
            upd = self.controller.update(loads, loader,
                                         lambda v: v.size_bytes)
            if isinstance(upd, dict) and upd.get("prompt_tokens"):
                trace.tokens += (upd["prompt_tokens"]
                                 + upd["completion_tokens"])

        # final answer round
        trace.tokens += self._llm_round(FINAL_PROMPT_TOKENS,
                                        FINAL_COMPLETION_TOKENS)
        trace.time_s = self.clock.now() - t0
        trace.success = not task_failed
        return trace


def _corrupt(ans: Any, llm: SimLLM):
    """Deterministic answer corruption for failed steps."""
    r = llm.rng
    if isinstance(ans, dict) and "detections" in ans:
        out = dict(ans)
        out["detections"] = int(ans["detections"] * r.uniform(0.2, 0.8))
        out["images"] = int(ans["images"] * r.uniform(0.2, 0.8))
        return out
    if isinstance(ans, list) and ans and isinstance(ans[0], str):
        return list(reversed(ans))
    if isinstance(ans, str):
        words = ans.split()
        r.shuffle(words)
        return " ".join(words[: max(len(words) // 2, 1)])
    if isinstance(ans, int):
        return int(ans * r.uniform(0.2, 0.8))
    if isinstance(ans, list):
        return [int(x * r.uniform(0.2, 0.8)) if isinstance(x, int) else x
                for x in ans]
    return None

"""Concurrent multi-session episode engine (event-granular discrete-event).

The paper's deployment is "an industry-scale massively parallel platform
spanning hundreds of GPT endpoints": many agent sessions run at once and
contend on the *shared* localized cache. This module models that regime:

* **N sessions**, each with its own logical :class:`SimClock`, its own
  seeded :class:`SimLLM`, and its own task stream (independent work);
* an **event-granular scheduler**: each session runs as a generator
  (:meth:`AgentRunner.iter_task`) that yields after *every* clock advance —
  LLM round, tool call, pod load — and the scheduler always resumes the
  session with the smallest logical clock (completions first at equal
  times, then sessions by id — fully deterministic, see
  :class:`~repro.agent.geollm.simclock.EventQueue`). Because a session only
  executes while its clock is the global minimum, every shared-state
  operation (cache read/install, pod-load arbitration, read-plan decision)
  happens in exact global time order: per-pod FCFS queueing is **exact**,
  not the task-atomic approximation of the original engine (which replayed
  whole tasks atomically and let a pod's busy-window leak backwards in
  time; see benchmarks/README.md for how the stall accounting changed);
* one shared :class:`PodLocalCacheRouter` + :class:`GeoDataStore`: a key's
  data is cached on exactly one pod, so sessions working on overlapping
  keys hit each other's cache fills — and queue behind each other's loads;
* **per-pod contention**: each pod serves remote DB loads FCFS in arrival
  order. A load that arrives while the pod is busy stalls until the pod
  frees up; the stall is charged to the session's clock and surfaced in
  the episode metrics (p50/p95 task latency, stall totals, per-pod load
  imbalance);
* **async prefetch** (``prefetch=True``): the moment a session's
  :class:`~repro.core.controller.ReadPlan` lands (plan time, before the
  planning LLM round is charged), the engine issues the planned ``load_db``
  keys as *asynchronous* pod loads via
  :meth:`PodLocalCacheRouter.start_load`. DB service then runs concurrently
  with the planning round; at consume time the session waits only for the
  residual (``completes_at - now``, usually 0), and the hidden service time
  is credited as ``overlap_credit_s``. Loads in flight are **joined** by
  any session needing the same key (no duplicate DB service). A
  prefetch-issued load never counts as a stall — stalls are exclusively
  time spent queued behind *demand* loads.

* **cross-session admission** (``admission="tinylfu"`` etc.): one
  :class:`~repro.core.admission.FrequencySketch` + admission policy shared
  by every pod and session gates installs — a full pod only evicts for a
  candidate the policy admits; rejected keys **bypass** (the value streams
  to the session, residents stay). ``admission_impl="llm"`` routes each
  decision through the GPT-driven prompt path
  (:class:`~repro.core.admission.LLMAdmission`), mirroring the paper's
  prompted eviction. Default (``None``) reproduces the install-everything
  engine bit-identically;
* **workload scenarios** (``scenario=``): beyond the paper's working-set
  sampler, zipfian skew, sequential scan, shifting-hotspot phases, and
  per-pod hot sets with cross-pod spillover (``affinity_zipf``) — see
  :class:`~repro.agent.geollm.workload.WorkloadSampler`;
* **session->pod affinity + locality penalty** (``affinity="sticky"`` /
  ``"round_robin"`` / ``"load_balanced"`` / ``"migrating"``, with
  ``remote_read_penalty``): every session has a home pod and each value it
  consumes from a *different* pod pays a cross-pod hop of
  ``(penalty - 1) x cache_read`` (optionally FCFS-serialized on the home
  pod's ingress link, ``link_queue=True``) — the paper's "localized"
  caching made real on the consumer side. ``remote_read_penalty=1.0``
  classifies reads as local/remote without moving a single clock: traces
  are bit-identical to the affinity-free engine (the degeneracy contract
  tests/test_locality.py locks down). See repro.core.locality.

Single-session behavior: ``n_sessions=1`` (lazy) reproduces the same
answer/token/time traces as the plain :class:`repro.agent.runtime.Runtime`
path (contention can never fire with one session); with prefetch enabled
the answer/token traces are unchanged and only the times shrink. Answer
quality aggregates are independent of N and of prefetch because both only
shift *time*.

docs/architecture.md documents the full data flow, the event model, and
the determinism contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.agent.agent import (
    PLAN_COMPLETION_TOKENS,
    PLAN_PROMPT_TOKENS,
    PLAN_PROMPT_TOKENS_FS,
    STEP_SUMMARY_TOKENS,
    AgentRunner,
    TaskTrace,
)
from repro.agent.backends import Profile, SimLLM
from repro.agent.geollm.datastore import GeoDataStore
from repro.agent.geollm.evaluator import Report, evaluate
from repro.agent.geollm.geotools import make_geo_tools
from repro.agent.geollm.simclock import EventQueue, LatencyModel, SimClock
from repro.agent.geollm.workload import Task, WorkloadSampler, compute_gold
from repro.core import profiling
from repro.core.admission import FrequencySketch, LLMAdmission, make_admission
from repro.core.controller import ReadPlan
from repro.core.distributed_cache import (
    FailoverReport,
    InFlightLoad,
    PodLocalCacheRouter,
)
from repro.core.faults import (
    FAIL,
    RESTORE,
    SCALE_IN,
    SCALE_OUT,
    BacklogAutoscaler,
    FaultEvent,
    FaultPlan,
    LLMRecovery,
    RetryPolicy,
    make_recovery,
)
from repro.core.coherence import (
    ARRIVAL,
    REFRESH,
    SERVE_STALE,
    CoherenceStats,
    MutationEvent,
    MutationPlan,
    make_coherence,
)
from repro.core.endpoints import (
    EndpointFaultEvent,
    EndpointFaultPlan,
    EndpointRouter,
    RoutedLLM,
)
from repro.core.locality import LocalityModel, make_affinity
from repro.core.plan_cache import make_plan_cache
from repro.core.replication import HotKeyReplicator, make_replication
from repro.core.traffic import ArrivalProcess, TrafficStats, make_traffic
from repro.core.tools import (
    ToolRegistry,
    ToolSpec,
    make_admission_tool,
    make_coherence_tool,
    make_plan_cache_tool,
    make_recovery_tool,
    make_replication_tool,
)

# event priorities: membership changes (faults) run before pod-load
# completions at the same instant — a load completing exactly at its pod's
# fail time ABORTS — and completions run before session resumes, so a
# session resuming exactly at a completion time observes the key already
# installed.
PRI_FAULT = -1
PRI_FINISH = 0
PRI_SESSION = 1

# Process-wide memo of gold-annotated per-session task streams. Sampling is
# pure in (seed, n, reuse, scenario, kw) and Task objects are immutable once
# compute_gold has run, so benchmark cells that replay the same workload
# under different engine configs (admission on/off, prefetch modes,
# replication …) share one task set instead of re-sampling and re-running
# the gold executor per cell — the admission table spends most of its wall
# budget there otherwise. ``store_key`` distinguishes datastores whose
# frames differ (the widened ``rows_range`` ablation).
_TASK_MEMO: Dict[tuple, List[Task]] = {}


def _memo_tasks(sseed: int, n_tasks: int, reuse_rate: float, scenario: str,
                scenario_kw: Dict, store: GeoDataStore,
                store_key) -> List[Task]:
    key = (sseed, n_tasks, reuse_rate, scenario,
           tuple(sorted(scenario_kw.items())), store_key)
    tasks = _TASK_MEMO.get(key)
    if tasks is None:
        tasks = WorkloadSampler(reuse_rate, seed=sseed, scenario=scenario,
                                **scenario_kw).sample(n_tasks)
        compute_gold(tasks, store)
        _TASK_MEMO[key] = tasks
        profiling.add("workload.task_memo_misses")
    else:
        profiling.add("workload.task_memo_hits")
    return tasks


# ---------------------------------------------------------------------------
# Contention: per-pod FCFS service of remote DB loads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PodLoadStats:
    loads: int = 0                 # physical DB loads served by this pod
    demand_loads: int = 0          # … issued synchronously by a session
    prefetch_loads: int = 0        # … issued asynchronously at plan time
    stalled_loads: int = 0         # acquisitions that waited behind demand
    stall_s: float = 0.0           # total demand-queueing wait charged
    busy_until: float = 0.0        # end of the pod's current busy window
    overlap_credit_s: float = 0.0  # prefetch service hidden behind LLM work
    service_ewma_s: float = 0.0    # observed per-load service time (EWMA)


class PodContention:
    """FCFS queueing model over each pod's load bandwidth.

    Every physical load extends the owning pod's busy window from
    ``max(arrival, busy_until)``. The event-granular scheduler guarantees
    arrivals are globally nondecreasing in time (``arrival_log`` records
    them; tests assert monotonicity), which is what makes the FCFS order
    *exact* — under the old task-atomic engine a session could arrive "in
    the past" relative to a window extended by a later-scheduled session.

    Demand loads (:meth:`acquire`) charge their queueing wait to the caller
    as a stall. Prefetch loads (:meth:`begin`) only extend the window and
    report their completion time: their queueing delay surfaces, if at all,
    as residual wait at consume time — never as a stall.

    Bookkeeping lives in preallocated per-field arrays indexed by pod id
    (ISSUE 4): the hot path resolves the pod index once and mutates plain
    scalar slots, and all aggregates (``total_stall_s``, ``load_imbalance``
    …) are vectorized reductions instead of per-pod object walks. The
    ``pods`` mapping is kept as a *snapshot* view for reporting and tests.
    """

    def __init__(self, pod_ids: Sequence[str]):
        self.pod_ids: List[str] = list(pod_ids)
        self._idx: Dict[str, int] = {p: i for i, p in enumerate(self.pod_ids)}
        n = len(self.pod_ids)
        self._loads = np.zeros(n, np.int64)
        self._demand = np.zeros(n, np.int64)
        self._prefetch = np.zeros(n, np.int64)
        self._stalled = np.zeros(n, np.int64)
        self._stall_s = np.zeros(n, np.float64)
        self._busy_until = np.zeros(n, np.float64)
        self._overlap = np.zeros(n, np.float64)
        self._ewma = np.zeros(n, np.float64)
        self._pf_consumes = 0        # prefetched loads consumed (fleet-wide)
        self._pf_waited = 0          # … that arrived late (residual wait)
        self.arrival_log: List[float] = []

    @property
    def pods(self) -> Dict[str, PodLoadStats]:
        """Per-pod stats snapshot (reporting/tests; not the hot path)."""
        return {p: PodLoadStats(
            loads=int(self._loads[i]), demand_loads=int(self._demand[i]),
            prefetch_loads=int(self._prefetch[i]),
            stalled_loads=int(self._stalled[i]),
            stall_s=float(self._stall_s[i]),
            busy_until=float(self._busy_until[i]),
            overlap_credit_s=float(self._overlap[i]),
            service_ewma_s=float(self._ewma[i]))
            for p, i in self._idx.items()}

    def _observe(self, i: int, service_s: float) -> None:
        # observed-service EWMA feeding the prefetcher's queueing model
        ewma = self._ewma[i]
        self._ewma[i] = (service_s if ewma == 0.0
                         else 0.8 * ewma + 0.2 * service_s)

    def acquire(self, pod: str, now: float, service_s: float) -> float:
        """Serve one demand load; returns the total dwell (stall + service)
        to charge to the calling session's clock."""
        self.arrival_log.append(now)
        i = self._idx[pod]
        start = max(now, float(self._busy_until[i]))
        stall = start - now
        self._busy_until[i] = start + service_s
        self._loads[i] += 1
        self._demand[i] += 1
        self._observe(i, service_s)
        if stall > 0:
            self._stalled[i] += 1
            self._stall_s[i] += stall
        return stall + service_s

    def begin(self, pod: str, now: float,
              service_s: float) -> Tuple[float, float]:
        """Issue one asynchronous (prefetch) load; returns its
        ``(service_start, completion)`` times. Nothing is charged to any
        session clock here — the consumer pays only the residual wait."""
        self.arrival_log.append(now)
        i = self._idx[pod]
        start = max(now, float(self._busy_until[i]))
        self._busy_until[i] = start + service_s
        self._loads[i] += 1
        self._prefetch[i] += 1
        self._observe(i, service_s)
        return start, start + service_s

    # -- queueing signals (the prefetcher's budget inputs) -------------------
    def backlog_s(self, pod: str, now: float) -> float:
        """Seconds of already-queued service ahead of a load arriving now."""
        return max(0.0, float(self._busy_until[self._idx[pod]]) - now)

    def expected_service_s(self, pod: str, default: float) -> float:
        """Observed per-load service time on ``pod`` (EWMA), or ``default``
        before any load has been observed."""
        ewma = float(self._ewma[self._idx[pod]])
        return ewma if ewma > 0.0 else default

    def queue_depth(self, pod: str, now: float, default_service: float) -> float:
        """Backlog expressed in *loads*: backlog seconds over the observed
        service time (reporting/diagnostics; the budget uses seconds)."""
        svc = self.expected_service_s(pod, default_service)
        return self.backlog_s(pod, now) / svc if svc > 0 else 0.0

    def stall_rate(self, pod: str) -> float:
        """Fraction of this pod's demand acquisitions that stalled
        (reporting/diagnostics; the adaptive guard uses the fleet-wide
        :meth:`guard_stats_total` signal instead — per-pod window rates
        proved too noisy to steer on)."""
        i = self._idx[pod]
        return (float(self._stalled[i]) / float(self._demand[i])
                if self._demand[i] else 0.0)

    def demand_stats_total(self) -> Tuple[int, int]:
        """Fleet-wide (demand, stalled) counters — the adaptive guard's
        window signal (vectorized reductions over the per-pod arrays)."""
        return int(self._demand.sum()), int(self._stalled.sum())

    def note_prefetch_consume(self, wait_s: float) -> None:
        """A session consumed a prefetched load (residual wait ``wait_s``,
        usually 0). Feeds the adaptive guard: a fleet whose prefetches keep
        arriving LATE is over-prefetching even if demand loads never stall."""
        self._pf_consumes += 1
        if wait_s > 0:
            self._pf_waited += 1

    def guard_stats_total(self) -> Tuple[int, int]:
        """(evidence events, bad events) for the adaptive depth guard:
        demand acquisitions + prefetch consumes, and stalled acquisitions +
        late prefetch consumes. Demand stalls alone are blind at loose
        thresholds — there, almost every load is a prefetch and the damage
        surfaces as residual waits instead."""
        demand, stalled = self.demand_stats_total()
        return demand + self._pf_consumes, stalled + self._pf_waited

    def reissue(self, pod: str, now: float,
                service_s: float) -> Tuple[float, float]:
        """Re-issue an aborted demand load on a new pod (fault retry):
        like :meth:`begin` it returns ``(service_start, completion)`` and
        charges no clock here — the aborted waiters pay the *extra* wait
        at the retry handler — but it is accounted as demand traffic, not
        prefetch (per-pod diagnostics stay truthful)."""
        self.arrival_log.append(now)
        i = self._idx[pod]
        start = max(now, float(self._busy_until[i]))
        self._busy_until[i] = start + service_s
        self._loads[i] += 1
        self._demand[i] += 1
        self._observe(i, service_s)
        return start, start + service_s

    def add_pod(self, pod_id: str) -> None:
        """Elastic scale-out: extend the per-pod arrays with a fresh (idle)
        slot. Membership changes are rare, so the O(n) array copies are
        nowhere near the hot path."""
        if pod_id in self._idx:
            return
        self._idx[pod_id] = len(self.pod_ids)
        self.pod_ids.append(pod_id)
        self._loads = np.append(self._loads, 0)
        self._demand = np.append(self._demand, 0)
        self._prefetch = np.append(self._prefetch, 0)
        self._stalled = np.append(self._stalled, 0)
        self._stall_s = np.append(self._stall_s, 0.0)
        self._busy_until = np.append(self._busy_until, 0.0)
        self._overlap = np.append(self._overlap, 0.0)
        self._ewma = np.append(self._ewma, 0.0)

    def clamp_busy(self, pod: str, now: float) -> None:
        """Pod failure: whatever service was queued/running on the pod
        died with it — the busy window must not outlive the pod, or a
        restored (cold, idle) pod would inherit phantom backlog."""
        i = self._idx[pod]
        if float(self._busy_until[i]) > now:
            self._busy_until[i] = now

    def join_stall(self, pod: str, wait_s: float) -> None:
        """A session queued behind another session's *demand* load of the
        same key (in-flight join): counts as a stalled acquisition."""
        if wait_s > 0:
            i = self._idx[pod]
            self._stalled[i] += 1
            self._stall_s[i] += wait_s

    def credit_overlap(self, pod: str, hidden_s: float) -> None:
        """Record prefetch service time that ran concurrently with the
        issuing session's LLM/tool work (credited once per prefetch)."""
        self._overlap[self._idx[pod]] += hidden_s

    @property
    def total_stall_s(self) -> float:
        return float(self._stall_s.sum())

    @property
    def stalled_loads(self) -> int:
        return int(self._stalled.sum())

    @property
    def total_loads(self) -> int:
        return int(self._loads.sum())

    @property
    def prefetch_loads(self) -> int:
        return int(self._prefetch.sum())

    @property
    def overlap_credit_s(self) -> float:
        return float(self._overlap.sum())

    def load_imbalance(self) -> float:
        """max/mean loads across pods (1.0 = perfectly balanced)."""
        if not len(self._loads):
            return 1.0
        mean = float(self._loads.mean())
        return float(self._loads.max()) / mean if mean else 1.0


# ---------------------------------------------------------------------------
# Shared-cache controller + tools (the session-side data plane)
# ---------------------------------------------------------------------------

class SharedCacheController:
    """Read planner against the pod-sharded shared cache.

    Updates are programmatic and happen at load time (the router installs
    every loaded key into its owning pod), so ``update`` is a no-op — the
    multi-session analogue of Table III's programmatic update row. With
    ``decision_eps > 0`` read decisions flip with that probability,
    reproducing the GPT-driven read path's calibrated error rate (misses
    then surface as failed ``read_cache`` calls the agent re-plans around).
    """

    kind = "shared"

    def __init__(self, router: PodLocalCacheRouter, rng=None,
                 decision_eps: float = 0.0, endpoints=None):
        self.router = router
        self.rng = rng
        self.decision_eps = decision_eps
        # optional EndpointRouter: when the GPT pool cannot serve at plan
        # time, the read plan degrades to the eps=0 programmatic heuristic
        # (the paper's "upper bound" decisions — structurally safe, just no
        # longer the simulated-GPT path) and the router counts it
        self.endpoints = endpoints

    def _cached(self, key: str) -> bool:
        # replica-aware: owner first, surviving replicas second. Without a
        # replicator the replica map is empty and this reduces exactly to
        # the owner-membership check (digest-locked).
        return self.router.locate(key) is not None

    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: bool = False) -> ReadPlan:
        simulate_llm = self.decision_eps and self.rng is not None
        if simulate_llm and self.endpoints is not None \
                and not self.endpoints.decision_available():
            # degraded read plan: no eps draws are consumed (the GPT never
            # answered, so there is no decision noise to simulate). Only
            # reachable under a non-empty fault plan — the empty-plan
            # bit-identity contract never takes this branch.
            simulate_llm = False
        choices = {}
        for k in required_keys:
            c = "read_cache" if self._cached(k) else "load_db"
            if simulate_llm and self.rng.random() < self.decision_eps:
                c = "load_db" if c == "read_cache" else "read_cache"
            choices[k] = c
        return ReadPlan(choices)

    def consume_plan_noise(self, required_keys: Sequence[str]) -> None:
        """Replay-correctness burn for a plan-cache hit (ISSUE 10): the
        skipped :meth:`plan_reads` would have drawn one eps sample per
        required key from the session's shared decision RNG — the same
        stream that later feeds ``draw_task_failure`` / ``draw_bad_calls``
        / ``draw_step_corruption``. Burn exactly those draws so every
        subsequent draw lands where a forced-miss replay would put it
        (same branch structure as plan_reads, including the degraded-mode
        gate — probed side-effect-free so the skipped round leaves no
        ``read_checks``/``degraded`` footprint)."""
        simulate_llm = self.decision_eps and self.rng is not None
        if simulate_llm and self.endpoints is not None \
                and not self.endpoints.decision_serviceable():
            simulate_llm = False
        if not simulate_llm:
            return
        for _ in required_keys:
            self.rng.random()

    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> None:
        return None


def make_shared_cache_tools(router: PodLocalCacheRouter, store: GeoDataStore,
                            contention: PodContention, clock: SimClock,
                            session: "Session",
                            events: EventQueue,
                            locality: Optional[LocalityModel] = None,
                            faults: Optional["FaultRuntime"] = None,
                            coherence: Optional["CoherenceRuntime"] = None,
                            ) -> List[ToolSpec]:
    """Per-session ``read_cache`` / ``load_db`` bound to the shared router.

    ``read_cache`` hits the owning pod's local cache (fast,
    contention-free). ``load_db`` resolves in order:

    1. the key is **in flight** (a prefetch, or another session's demand
       load): join it — wait only for the residual ``completes_at - now``.
       Joining a *prefetched* load is a prefetch hit (never a stall);
       joining a *demand* load is a stall charged to this session;
    2. the key was **prefetched by this session and already installed**:
       consume as a pod-local cache read (the load was fully hidden);
    3. otherwise issue a **demand load**: queue on the owning pod's
       bandwidth, charge stall + DB service to the session clock, and
       register the in-flight record whose completion event installs the
       frame into the pod cache (first fill wins — later sessions hit it).

    Accounting invariant (locked in by tests):
    ``routed == local_hits + remote_loads + joined_in_flight +
    bypass_reads`` where ``routed`` counts logical accesses
    (``bypass_reads`` — consumes served straight from a
    completed-but-bypassed prefetch — is zero without admission);
    physical DB loads are
    ``remote_loads + prefetch_issued == contention.total_loads``.
    Every logical access also touches the shared frequency sketch
    (``router.note_access``), which is the admission policy's evidence.

    With a :class:`~repro.core.locality.LocalityModel` wired (session->pod
    affinity), every consumed value additionally pays the consumer-side
    **cross-pod hop** when the serving pod is not the session's home pod:
    the session clock advances by the hop (plus any wait on the home
    pod's ingress link — hop completion is synchronous on the consumer,
    so it needs no scheduler event), and the read is classified local vs
    remote (the partition invariant: ``locality.local_reads +
    locality.remote_reads == routed``). At ``remote_read_penalty == 1.0``
    the hop is exactly zero and every trace is bit-identical to the
    affinity-free engine (tests/test_locality.py).

    With a :class:`CoherenceRuntime` wired (a MutationPlan — ISSUE 8),
    every consume passes a **checkpoint** comparing the serving copy's
    version against the key's current datastore version. A demand load
    serializes its read at the *issue* instant (a write landing during the
    dwell serializes after it — the value is fresh by definition at
    consume). A version-lagged copy asks the policy: ``serve_stale`` keeps
    the normal path (the access stays in its invariant bucket, counted as
    a ``stale_reads`` sub-bucket); ``refresh`` issues one more logical
    access as an authoritative DB read (``routed`` + ``remote_loads``,
    marked ``refresh_loads``) through the same FCFS contention the demand
    path uses. Whatever the policy answers, serving past ``bound_s`` is
    clamped to refresh — the staleness contract is a hard property.
    ``coherence=None`` (no MutationPlan) skips every check bit-identically.
    """
    stats = session.stats
    coh = coherence

    def _consume(key: str, pod: str, size_mb: float) -> None:
        # consumer-side locality charge, called exactly once per logical
        # access (one per ``routed`` increment): classify the read, record
        # consumer demand for the replicator, pay the cross-pod hop
        if locality is None:
            return
        extra = locality.charge(key, pod, session.home_pod, size_mb,
                                clock.now())
        if pod != session.home_pod:
            stats.remote_reads += 1
            if extra > 0.0:
                stats.remote_hop_s += extra
                # the hop is synchronous on the consumer: its completion
                # is this clock advance (no separate scheduler event — a
                # per-read event would be pure heap churn on the hot loop
                # the PR-4 work de-Pythonized, with no consumer)
                clock.advance(extra)

    def _credit_once(rec: InFlightLoad, consume_t: float) -> None:
        # hidden service = dwell that ran while sessions did LLM/tool work;
        # the residual (if any) is what the consumer waits out. Credited at
        # most once per physical load (the record carries the flag), no
        # matter how many sessions consume it.
        if not rec.prefetched or rec.credited:
            return
        rec.credited = True
        contention.credit_overlap(
            rec.pod, min(consume_t, rec.completes_at) - rec.issued_at)

    def _refresh(key: str, current: int, served_pod: str):
        """Coherence-forced reload: one more logical access served by an
        authoritative DB read on the owner's bandwidth (same acquire/stall
        accounting as a demand load, flagged ``refresh_loads``). The
        reloaded frame re-freshens what it can reach: a live in-flight
        record is version-stamped (frames are content-immutable, so the
        landing fill now carries current data), an existing cached copy is
        stamped in place, and a missing copy registers a normal in-flight
        fill that joiners share."""
        frame = store.peek(key)
        now = clock.now()
        store.loads += 1
        router.stats.routed += 1
        router.stats.remote_loads += 1
        router.stats.refresh_loads += 1
        router.note_access(key, now)
        pod = router.owner(key)
        service = clock.latency.db_load(frame.size_mb)
        dwell = contention.acquire(pod, now, service)
        stall = dwell - service
        if stall > 0:
            stats.stalled_loads += 1
            stats.stall_s += stall
        if faults is not None:
            faults.note_access(0.0, now)
        rec = router.in_flight.get(key)
        if rec is not None:
            rec.version = max(rec.version, current)
        else:
            entry = router.pods[served_pod].entry(key)
            if entry is not None:
                entry.version = current
            else:
                router.start_load(key, frame, frame.size_bytes,
                                  issued_at=now, completes_at=now + dwell,
                                  prefetched=False)
                events.push(now + dwell, PRI_FINISH, payload=key)
                if faults is not None:
                    faults.note_waiter(key, session)
        clock.advance(dwell)
        _consume(key, pod, frame.size_mb)
        return frame

    def _checkpoint(key: str, version: int, served_pod: str):
        """Consume checkpoint (ISSUE 8): prove what this access serves.
        Returns ``None`` to serve the copy as-is (fresh, or stale within
        its declared bound) or the authoritative frame when the policy
        orders a refresh — in which case the caller returns it INSTEAD of
        charging the copy's read cost."""
        current = coh.current_version(key)
        now = clock.now()
        coh.note_time(now)
        if version >= current:
            coh.stats.fresh_reads += 1
            return None
        staleness = coh.staleness_of(key, version, now)
        pol = coh.policy
        freq = (int(router.sketch.estimate_peek(key))
                if router.sketch is not None else 0)
        # TTL is enforced on staleness, which lower-bounds age (the missed
        # write postdates the install): the declared bound still holds and
        # the check needs no sim-time fill clock in the pod caches
        decision = pol.on_stale_read(key, staleness, staleness, freq)
        if decision == SERVE_STALE and staleness > pol.bound_s:
            coh.stats.clamped += 1
            decision = REFRESH
        if decision == SERVE_STALE:
            coh.stats.stale_reads += 1
            router.stats.stale_reads += 1
            if staleness > coh.stats.max_staleness_s:
                coh.stats.max_staleness_s = staleness
            coh.ledger.append((now, key, version, current, staleness,
                               SERVE_STALE))
            return None
        base = getattr(pol, "base", pol)
        if base.expired(staleness):
            coh.stats.expired_reads += 1
        coh.stats.refresh_reads += 1
        coh.ledger.append((now, key, version, current, staleness, REFRESH))
        return _refresh(key, current, served_pod)

    def read_cache(key: str):
        owner_pod = router.owner(key)
        if locality is not None:
            # cheapest placement first: a copy on the session's home pod
            # skips the cross-pod hop (identical to the owner-first order
            # at penalty 1x — see PodLocalCacheRouter.locate)
            pod = router.locate(key, home=session.home_pod) or owner_pod
        elif key in router.pods[owner_pod]:
            pod = owner_pod
        else:
            # replica failover: a non-owner pod may still hold a pushed
            # copy (None without replication — then the owner .get below
            # raises the same KeyError/replan path as always)
            pod = router.locate(key) or owner_pod
        value = router.pods[pod].get(key)    # raises KeyError on miss
        router.stats.routed += 1
        router.stats.local_hits += 1
        if pod != owner_pod:
            router.stats.replica_hits += 1
            router.replica_reads[key] = router.replica_reads.get(key, 0) + 1
        router.note_access(key, clock.now())
        if faults is not None:
            faults.note_access(1.0, clock.now())
        if coh is not None:
            fresh = _checkpoint(key, router.pods[pod].entry(key).version,
                                pod)
            if fresh is not None:
                return fresh
        clock.advance(clock.latency.cache_read(value.size_mb))
        _consume(key, pod, value.size_mb)
        return value

    def load_db(key: str):
        pod = router.owner(key)
        now = clock.now()
        router.note_access(key, now)
        rec = router.in_flight.get(key)
        if rec is not None:                       # 1. join an in-flight load
            if coh is not None:
                # a fill issued before a write is version-lagged: a joiner
                # arriving AFTER the write serializes after it too, so it
                # checkpoints here (the issuer serialized at issue and is
                # fresh by definition). A refresh re-reads authoritatively
                # instead of joining — and re-stamps the fill, which now
                # carries current (content-identical) data.
                fresh = _checkpoint(key, rec.version, rec.pod)
                if fresh is not None:
                    session.prefetched.pop(key, None)
                    return fresh
            session.prefetched.pop(key, None)
            wait = max(0.0, rec.completes_at - now)
            rec.joiners += 1
            router.stats.routed += 1
            router.stats.joined_in_flight += 1
            if rec.prefetched:
                stats.prefetch_hits += 1
                stats.prefetch_wait_s += wait
                contention.note_prefetch_consume(wait)
                _credit_once(rec, now)
            elif wait > 0:
                stats.stalled_loads += 1
                stats.stall_s += wait
                contention.join_stall(pod, wait)
            if faults is not None:
                faults.note_access(0.0, now)
                if wait > 0:
                    # the join waits out the record's residual service: if
                    # the serving pod dies first, this session retries
                    faults.note_waiter(key, session)
            clock.advance(wait)
            _consume(key, rec.pod, rec.value.size_mb)
            return rec.value
        own = session.prefetched.pop(key, None)
        if own is not None and key in router.pods[pod]:
            # 2. own prefetch completed + installed: fully hidden load
            value = router.pods[pod].get(key)
            router.stats.routed += 1
            router.stats.local_hits += 1
            stats.prefetch_hits += 1
            contention.note_prefetch_consume(0.0)
            _credit_once(own, now)
            if faults is not None:
                faults.note_access(1.0, now)
            if coh is not None:
                fresh = _checkpoint(
                    key, router.pods[pod].entry(key).version, pod)
                if fresh is not None:
                    return fresh
            clock.advance(clock.latency.cache_read(value.size_mb))
            _consume(key, pod, value.size_mb)
            return value
        if own is not None and own.bypassed:
            # 2b. own prefetch completed but admission rejected the install:
            # bypass-on-miss — the frame streams through to the session
            # (same read cost as a local consume), residents untouched
            router.stats.routed += 1
            router.stats.bypass_reads += 1
            stats.prefetch_hits += 1
            contention.note_prefetch_consume(0.0)
            _credit_once(own, now)
            if faults is not None:
                faults.note_access(0.0, now)
            if coh is not None:
                fresh = _checkpoint(key, own.version, own.pod)
                if fresh is not None:
                    return fresh
            clock.advance(clock.latency.cache_read(own.value.size_mb))
            _consume(key, own.pod, own.value.size_mb)
            return own.value
        # 3. demand load (also covers an erroneous load_db decision for an
        # already-cached key, and a prefetched frame evicted before use —
        # both pay the full DB dwell, like the original engine)
        frame = store.peek(key)
        store.loads += 1
        router.stats.routed += 1
        router.stats.remote_loads += 1
        service = clock.latency.db_load(frame.size_mb)
        dwell = contention.acquire(pod, now, service)
        stall = dwell - service
        if stall > 0:
            stats.stalled_loads += 1
            stats.stall_s += stall
        router.start_load(key, frame, frame.size_bytes, issued_at=now,
                          completes_at=now + dwell, prefetched=False)
        events.push(now + dwell, PRI_FINISH, payload=key)
        if faults is not None:
            faults.note_access(0.0, now)
            # the issuer waits out the whole dwell: if the owning pod dies
            # before completes_at, this session retries against the new
            # rendezvous owner (bounded backoff, then DB bypass)
            faults.note_waiter(key, session)
        if coh is not None:
            # serialization-at-issue: the read serializes at its issue
            # instant, so a write landing during the dwell serializes
            # after it — the consumed value is fresh by definition
            coh.note_time(now)
            coh.stats.fresh_reads += 1
        clock.advance(dwell)
        _consume(key, pod, frame.size_mb)
        return frame

    return [
        ToolSpec(
            name="read_cache",
            description=("Read imagery metadata for a `dataset-year` key "
                         "from the SHARED POD CACHE. Fast (pod-local). "
                         "Fails if the key is not currently cached."),
            parameters={"key": {"type": "string"}},
            fn=read_cache),
        ToolSpec(
            name="load_db",
            description=("Load imagery metadata for a `dataset-year` key "
                         "from the REMOTE DATABASE. Slow; queues on the "
                         "owning pod under concurrent load."),
            parameters={"key": {"type": "string"}},
            fn=load_db),
    ]


# ---------------------------------------------------------------------------
# Sessions + engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionStats:
    stalled_loads: int = 0
    stall_s: float = 0.0
    prefetch_issued: int = 0
    prefetch_hits: int = 0
    prefetch_wait_s: float = 0.0
    prefetch_skipped: int = 0      # planned loads left lazy by the budget
    # consumer-side locality split (zero without session->pod affinity):
    # reads served from a pod other than this session's home, and the
    # cross-pod hop seconds (incl. ingress-link waits) charged for them
    remote_reads: int = 0
    remote_hop_s: float = 0.0
    # fault accounting (all zero without a FaultPlan): retry cycles this
    # session's aborted loads went through, the extra wait those retries
    # charged beyond the original completion, loads that exhausted the
    # retry budget and bypassed to direct DB reads, and service seconds
    # this session had already waited out on pods that then died
    retried_loads: int = 0
    retry_wait_s: float = 0.0
    timeout_loads: int = 0
    lost_work_s: float = 0.0


@dataclasses.dataclass
class Session:
    sid: int
    clock: SimClock
    llm: SimLLM
    runner: AgentRunner
    tasks: List[Task]
    stats: SessionStats
    home_pod: Optional[str] = None   # session->pod affinity (None = off)
    cursor: int = 0
    traces: List[TaskTrace] = dataclasses.field(default_factory=list)
    # keys this session prefetched and has not consumed yet (records stay
    # valid after completion — consume needs issued_at/completes_at)
    prefetched: Dict[str, InFlightLoad] = dataclasses.field(
        default_factory=dict)

    def next_task(self) -> Optional[Task]:
        if self.cursor >= len(self.tasks):
            return None
        t = self.tasks[self.cursor]
        self.cursor += 1
        return t


# ---------------------------------------------------------------------------
# Fault runtime: membership changes as first-class scheduler events
# ---------------------------------------------------------------------------

class RetryEvent:
    """Scheduled re-attempt for the waiters of an aborted in-flight load:
    fires at ``abort_time + backoff`` and re-resolves the key against the
    *current* fleet (join a live in-flight record, read a surviving copy,
    re-issue on the new rendezvous owner, or — past the retry budget —
    bypass to a direct DB read)."""

    __slots__ = ("key", "waiters", "attempt")

    def __init__(self, key: str, waiters: List[Session], attempt: int):
        self.key = key
        self.waiters = waiters
        self.attempt = attempt


class TrafficSpawn:
    """Open-loop session arrival (ISSUE 7): pops at the arrival instant
    with ``PRI_SESSION`` and the session id as tiebreak — so the
    degenerate all-at-t=0 schedule pops in exactly the order the
    closed-loop engine pushed its resume events. The handler constructs
    the session lazily (construction touches no shared mutable state),
    advances its clock to the arrival time, and steps it inline."""

    __slots__ = ("sid", "lifetime_tasks")

    def __init__(self, sid: int, lifetime_tasks: Optional[int]):
        self.sid = sid
        self.lifetime_tasks = lifetime_tasks


class TrafficRetire:
    """Open-loop session departure: pushed at the instant a session's
    generator exhausts its (bounded) task stream. Pure ledger — the
    handler records the retire time for flow-balance / Little's-law
    accounting and touches no clock or shared state."""

    __slots__ = ("sid",)

    def __init__(self, sid: int):
        self.sid = sid


class FaultRuntime:
    """Engine-side semantics of a :class:`~repro.core.faults.FaultPlan`.

    The plan's events enter the scheduler heap at ``PRI_FAULT`` (before
    same-instant completions: a load completing exactly at its pod's fail
    time aborts). This runtime gives each membership change its real
    consequences, all inside the deterministic event order:

    * **abort/retry** — sessions whose pending resume sits at an aborted
      load's ``completes_at`` (the issuer and every joiner — registered
      via :meth:`note_waiter` when they charged the wait) get a
      :class:`RetryEvent` after bounded exponential backoff
      (:class:`~repro.core.faults.RetryPolicy`). At fire time the key is
      re-resolved; waiters whose new completion lands *later* than their
      already-charged clock advance by the difference and their stale
      resume events are superseded (``resume_at`` — the hot loop skips
      session events older than it). A waiter already past the new
      completion keeps its original timing. After ``max_retries`` aborts
      of one key the waiters bypass to a direct DB read — structurally
      never a stall-forever;
    * **prefetch aborts** — a dying pod's in-flight prefetches are purged
      from their issuing session's ``prefetched`` map (``pf_owner``), so
      the consume falls through to a plain demand load;
    * **warm-up transient** — a hit EWMA over logical accesses
      (:meth:`note_access`) is snapshotted at each failure; the transient
      closes when the EWMA regains ``recover_frac`` of its pre-failure
      value. ``task_ends`` lets :meth:`attributed_p95` split task latency
      into failover-window vs steady-state tails;
    * **GPT-driven recovery** — per hot key lost with the pod, the
      recovery policy (threshold or LLM-prompted) decides re-warm-now
      (a background load onto the new owner) vs lazy refill; keys a
      surviving replica still serves skip the decision entirely;
    * **autoscaling** — a :class:`~repro.core.faults.BacklogAutoscaler`
      polled at sim-time boundaries (like replication epochs) drives
      ``scale_out``/``scale_in`` from the contention layer's backlog.

    Degeneracy: with an empty plan and no autoscaler every hook is pure
    bookkeeping — no clock moves, no event is added — and the engine
    replays the fault-free traces bit-identically (locked by
    tests/test_faults.py)."""

    def __init__(self, engine: "ConcurrentEpisodeEngine", events: EventQueue,
                 retry: RetryPolicy, recovery=None,
                 scaler: Optional[BacklogAutoscaler] = None,
                 hit_alpha: float = 0.05, recover_frac: float = 0.95,
                 recover_k: int = 8):
        self.engine = engine
        self.router = engine.router
        self.contention = engine.contention
        self.store = engine.store
        self.latency = engine.latency
        self.events = events
        self.retry = retry
        self.recovery = recovery
        self.scaler = scaler
        self.sessions: List[Session] = []      # filled by run()
        # waiting-session bookkeeping
        self.waiters: Dict[str, List[Session]] = {}
        self.attempts: Dict[str, int] = {}
        self.pf_owner: Dict[str, Session] = {}
        self.resume_at: Dict[int, float] = {}
        # hit EWMAs + failover transients: the FAST ewma tracks the dip
        # and the recovery, while the SLOW one (an order of magnitude
        # slower) is the stable pre-failure baseline the transient is
        # snapshotted against — snapshotting the fast ewma would make
        # the recovery bar hostage to whatever noise peak the failure
        # instant happened to land on
        self.hit_alpha = hit_alpha
        self.base_alpha = hit_alpha / 10.0
        self.recover_frac = recover_frac
        self.recover_k = recover_k
        self.hit_ewma = 0.0
        self.hit_base = 0.0
        self._ewma_init = False
        self.transients: List[Dict] = []
        self._open = 0
        self.task_ends: List[Tuple[float, float]] = []
        # counters
        self.restores = 0
        self.prefetch_aborted = 0
        self.lost_work_s = 0.0
        self.lost_keys_n = 0
        self.lost_replicas_n = 0
        self.rewarms = 0
        self.lazy = 0
        self.autoscale_actions = 0

    # -- hooks from the data plane (pure bookkeeping) ------------------------
    def note_waiter(self, key: str, session: Session) -> None:
        self.waiters.setdefault(key, []).append(session)

    def note_finish(self, key: str) -> None:
        self.waiters.pop(key, None)
        self.attempts.pop(key, None)

    def note_access(self, hit: float, now: float) -> None:
        if not self._ewma_init:
            self.hit_ewma = self.hit_base = hit
            self._ewma_init = True
        else:
            self.hit_ewma += self.hit_alpha * (hit - self.hit_ewma)
            self.hit_base += self.base_alpha * (hit - self.hit_base)
        if self._open:
            for tr in self.transients:
                if tr["recovered_at"] is not None:
                    continue
                # a transient must first DIP below the threshold before it
                # can close — otherwise the first post-failure hit would
                # close it instantly and "recovery time" would measure
                # nothing. A transient that never dips at all reports
                # recovery 0 (the failure never dented the hit rate — with
                # replication on, that is exactly the win being measured).
                # Closing takes ``recover_k`` consecutive accesses at/above
                # the bar: a single fast-EWMA noise spike inside the miss
                # burst must not read as "recovered". The recovery INSTANT
                # is the first access of the qualifying streak.
                if self.hit_ewma < self.recover_frac * tr["pre_ewma"]:
                    tr["dipped"] = True
                    tr["_above"] = 0
                elif tr["dipped"]:
                    if tr["_above"] == 0:
                        tr["_since"] = now
                    tr["_above"] += 1
                    if tr["_above"] >= self.recover_k:
                        tr["recovered_at"] = tr["_since"]
                        self._open -= 1

    # -- event handlers ------------------------------------------------------
    def handle(self, t: float, payload) -> None:
        if payload.__class__ is RetryEvent:
            self._handle_retry(t, payload)
            return
        ev: FaultEvent = payload
        router = self.router
        if ev.action == FAIL:
            report = router.fail_pod(ev.pod)
            if report is None:
                return                      # idempotent: already down
            self.contention.clamp_busy(ev.pod, t)
            self.lost_keys_n += len(report.lost_keys)
            self.lost_replicas_n += len(report.lost_replicas)
            self.transients.append({
                "pod": ev.pod, "at": t, "pre_ewma": self.hit_base,
                "recovered_at": None, "dipped": False,
                "lost_keys": len(report.lost_keys),
                "lost_replicas": len(report.lost_replicas)})
            self._open += 1
            self._handle_aborts(report, t)
            self._recover(report, t)
        elif ev.action == RESTORE:
            if router.restore_pod(ev.pod):
                self.restores += 1
        elif ev.action == SCALE_OUT:
            router.scale_out(ev.pod)
            self.contention.add_pod(ev.pod)
        else:                               # SCALE_IN
            report = router.scale_in(ev.pod)
            if report is not None:
                self.contention.clamp_busy(ev.pod, t)
                self._handle_aborts(report, t)

    def _handle_aborts(self, report: FailoverReport, t: float) -> None:
        for rec in report.aborted:
            lost = max(0.0, min(t, rec.completes_at) - rec.issued_at)
            self.lost_work_s += lost
            if rec.prefetched:
                owner = self.pf_owner.get(rec.key)
                if (owner is not None
                        and owner.prefetched.get(rec.key) is rec):
                    del owner.prefetched[rec.key]
                    self.prefetch_aborted += 1
            waiters = self.waiters.pop(rec.key, [])
            attempt = self.attempts.pop(rec.key, 0) + 1
            if not waiters:
                continue
            for s in waiters:
                s.stats.lost_work_s += lost
            self.events.push(t + self.retry.delay(attempt), PRI_FINISH,
                             payload=RetryEvent(rec.key, waiters, attempt))

    def _handle_retry(self, t: float, ev: RetryEvent) -> None:
        router, contention = self.router, self.contention
        key, timeout = ev.key, False
        rec = router.in_flight.get(key)
        registrable = False
        if rec is not None:
            # another load of the key is live (someone re-demanded it, or
            # a recovery re-warm is running): join it
            completes = rec.completes_at
            rec.joiners += len(ev.waiters)
            registrable = True
        else:
            frame = self.store.peek(key)
            if router.locate(key) is not None:
                # a surviving copy (owner re-fill, or a replica that
                # outlived its owner) serves the retry as a pod-local
                # read — replication doubling as resilience
                completes = t + self.latency.cache_read(frame.size_mb)
            elif ev.attempt > self.retry.max_retries:
                # retry budget exhausted: bypass to a direct DB read (no
                # pod, no queueing, nothing left to abort) — the bounded
                # guarantee that no session stalls forever
                completes = t + self.latency.db_load(frame.size_mb)
                router.stats.timeout_loads += 1
                timeout = True
            else:
                owner = router.owner(key)
                service = self.latency.db_load(frame.size_mb)
                _, completes = contention.reissue(owner, t, service)
                router.start_load(key, frame, frame.size_bytes, issued_at=t,
                                  completes_at=completes, prefetched=False)
                self.events.push(completes, PRI_FINISH, payload=key)
                router.stats.retried_loads += 1
                registrable = True
        still_waiting = []
        for s in ev.waiters:
            s.stats.retried_loads += 1
            if timeout:
                s.stats.timeout_loads += 1
            extra = completes - s.clock.now()
            if extra > 0:
                # the waiter's already-charged wait undershot the new
                # completion: extend its clock and supersede its stale
                # resume event (the hot loop skips events older than
                # ``resume_at``). A waiter already past the new completion
                # keeps its original timing.
                s.stats.retry_wait_s += extra
                s.clock.advance(extra)
                self.resume_at[s.sid] = s.clock.now()
                self.events.push(s.clock.now(), PRI_SESSION, s.sid, s.sid)
                still_waiting.append(s)
        if registrable and still_waiting:
            # the new record can abort too: keep the chain alive
            self.waiters.setdefault(key, []).extend(still_waiting)
            self.attempts[key] = ev.attempt

    def _recover(self, report: FailoverReport, t: float) -> None:
        pol = self.recovery
        if pol is None or not report.lost_keys:
            return
        router, sketch = self.router, self.engine.sketch
        if isinstance(pol, LLMRecovery) and sketch is not None:
            pol.set_evidence(sketch.top_k(8))
        for key in report.lost_keys:
            if key in router.in_flight or router.locate(key) is not None:
                continue        # survived (replica / re-fill): no decision
            freq = int(sketch.estimate(key)) if sketch is not None else 0
            if pol.decide(key, freq) != "rewarm":
                self.lazy += 1
                continue
            frame = self.store.peek(key)
            service = self.latency.db_load(frame.size_mb)
            owner = router.owner(key)
            _, completes = self.contention.begin(owner, t, service)
            router.start_load(key, frame, frame.size_bytes, issued_at=t,
                              completes_at=completes, prefetched=True)
            self.events.push(completes, PRI_FINISH, payload=key)
            self.rewarms += 1

    # -- autoscaling ---------------------------------------------------------
    def predicted_rewarm_s(self) -> float:
        """Predicted warm-up cost of the pod a scale_out would add: the
        rendezvous reshuffle re-homes ~1/(n_live+1) of the resident keys,
        and each re-homed key re-warms through one demand DB load at the
        fleet's observed service EWMA. This is the cost the warm-up-aware
        autoscaler weighs against the surge's observed persistence."""
        live = self.router.live_pods()
        if not live:
            return 0.0
        resident = sum(len(self.router.pods[p]) for p in live)
        if resident == 0:
            return 0.0
        moved = resident / (len(live) + 1.0)
        svc = max(self.contention.expected_service_s(p, 0.0) for p in live)
        return moved * svc

    def run_autoscaler(self, t: float) -> None:
        sc = self.scaler
        while t >= sc.next_check:
            now = sc.next_check
            backlogs = {p: self.contention.backlog_s(p, now)
                        for p in self.router.live_pods()}
            rewarm = (self.predicted_rewarm_s()
                      if sc.warmup_aware else 0.0)
            action = sc.decide(now, backlogs, rewarm_cost_s=rewarm)
            if action == SCALE_OUT:
                pod = self._new_pod()
                self.router.scale_out(pod)
                self.contention.add_pod(pod)
                sc.note_action(now, SCALE_OUT, pod)
                self.autoscale_actions += 1
            elif action == SCALE_IN:
                pod = sc.added[-1]
                report = self.router.scale_in(pod)
                if report is not None:
                    self.contention.clamp_busy(pod, now)
                    self._handle_aborts(report, now)
                sc.note_action(now, SCALE_IN, pod)
                self.autoscale_actions += 1
            sc.next_check += sc.check_every_s

    def _new_pod(self) -> str:
        n = len(self.router.pods)
        while f"pod{n}" in self.router.pods:
            n += 1
        return f"pod{n}"

    # -- reporting -----------------------------------------------------------
    def recovery_stats(self) -> Tuple[float, int]:
        """(mean hit-EWMA recovery time across transients, transients
        still open at episode end). A transient that never dipped below
        the threshold counts as recovery 0 — the failure never dented
        the hit rate; only dipped-and-never-recovered transients count
        as open (``resilience_unrecovered``)."""
        closed: List[float] = []
        open_n = 0
        for tr in self.transients:
            if tr["recovered_at"] is not None:
                closed.append(tr["recovered_at"] - tr["at"])
            elif tr["dipped"]:
                open_n += 1
            else:
                closed.append(0.0)
        return (sum(closed) / len(closed) if closed else 0.0), open_n

    def attributed_p95(self) -> Tuple[float, float]:
        """Task-latency p95 split into tasks ending inside a failover
        window (failure -> EWMA recovery; unclosed windows extend to the
        episode end) vs steady state."""
        windows = [(tr["at"],
                    tr["recovered_at"] if tr["recovered_at"] is not None
                    else (float("inf") if tr["dipped"] else tr["at"]))
                   for tr in self.transients]
        if not windows or not self.task_ends:
            return 0.0, 0.0
        inside: List[float] = []
        outside: List[float] = []
        for end, dur in self.task_ends:
            (inside if any(a <= end <= b for a, b in windows)
             else outside).append(dur)

        def p95(xs):
            return float(np.percentile(np.asarray(xs), 95)) if xs else 0.0
        return p95(inside), p95(outside)


class CoherenceRuntime:
    """Write path + coherence bookkeeping for one episode (ISSUE 8).

    Owns the per-key version counters and mutation timestamps the consume
    checkpoints compare against, applies each :class:`MutationEvent` of
    the engine's :class:`MutationPlan` (scheduled at ``PRI_FAULT``, like
    membership changes), and runs the policy's write-time fan-out:
    write-invalidate purges every live copy (owner, replicas, and —
    via the router's ``fresh_fills_only`` guard — superseded in-flight
    fills), write-through stamps the new version into every live copy and
    any in-flight fill (frames are content-immutable, so the stamp IS the
    refresh), and the bounded policies only book the copies that just
    went version-lagged (readers decide at consume time).

    The ``ledger`` records every version-lagged consume as
    ``(t, key, served_version, current_version, staleness_s, verdict)`` —
    the "prove what it served" audit trail the property tests replay.
    ``clock_now`` tracks the max sim time observed across writes and
    consumes (monotone; the ``cache_update`` probe's time source)."""

    def __init__(self, engine: "ConcurrentEpisodeEngine",
                 plan: MutationPlan, policy):
        self.engine = engine
        self.router = engine.router
        self.plan = plan
        self.policy = policy
        self.versions: Dict[str, int] = {}
        self.mutation_times: Dict[str, List[float]] = {}
        self.stats = CoherenceStats()
        self.ledger: List[tuple] = []
        self._now = 0.0

    # -- the surface the consume checkpoints + cache_update probe use --------
    def current_version(self, key: str) -> int:
        return self.versions.get(key, 0)

    def staleness_of(self, key: str, version: int, now: float) -> float:
        """Seconds since the FIRST write the copy at ``version`` missed —
        how long the consumer has been able to observe newer data."""
        times = self.mutation_times.get(key)
        if not times or version >= len(times):
            return 0.0
        return max(0.0, now - times[version])

    def clock_now(self) -> float:
        return self._now

    def note_time(self, now: float) -> None:
        if now > self._now:
            self._now = now

    # -- the write path ------------------------------------------------------
    def apply(self, t: float, mev: MutationEvent) -> None:
        self.note_time(t)
        key = mev.key
        self.mutation_times.setdefault(key, []).append(t)
        version = len(self.mutation_times[key])
        self.versions[key] = version
        pc = self.engine.plan_cache
        if pc is not None:
            # plan-cache coupling (ISSUE 10): the version bump just moved
            # every context digest covering this key, so the covered plans
            # are already unreachable; under an invalidating policy they
            # are additionally dropped now (counted as invalidations)
            pc.note_write(key, invalidate=self.policy.invalidate_on_write)
        st = self.stats
        st.mutations += 1
        if mev.kind == ARRIVAL:
            st.arrivals += 1
        else:
            st.updates += 1
        pol = self.policy
        if pol.invalidate_on_write:
            st.invalidations += self.router.invalidate_copies(key)
        elif pol.refresh_on_write:
            st.writethroughs += self.router.refresh_copies(key, version)
            rec = self.router.in_flight.get(key)
            if rec is not None:
                # write-through reaches the in-flight fill too: the landing
                # value is content-identical to the new version, so the
                # stamp makes the install current (never superseded)
                rec.version = version
        else:
            # bounded staleness: copies stay; replica copies that just went
            # version-lagged still feed the replicator's demotion pressure
            self.router.stale_copies(key)


@dataclasses.dataclass
class EpisodeMetrics:
    n_sessions: int
    n_pods: int
    n_tasks: int
    makespan_s: float
    throughput_tasks_per_s: float
    mean_task_latency_s: float
    p50_task_latency_s: float
    p95_task_latency_s: float
    total_stall_s: float
    stall_per_task_s: float
    stalled_loads: int
    total_loads: int
    local_hit_rate: float
    pod_load_imbalance: float
    cache_miss_replans: int
    # async-prefetch accounting (all zero when prefetch is off)
    prefetch_issued: int = 0
    prefetch_hits: int = 0
    prefetch_wait_s: float = 0.0
    overlap_credit_s: float = 0.0
    joined_loads: int = 0
    prefetch_skipped: int = 0
    # admission accounting (all zero / 1.0 when admission is off).
    # admission_tokens is the GPT-driven path's decision cost — charged as
    # tokens only, off the critical path like the paper's prompted update
    admitted: int = 0
    bypassed: int = 0
    bypass_reads: int = 0
    admission_agreement: float = 1.0
    admission_tokens: int = 0
    # hot-key replication accounting (all zero / 1.0 when replication is
    # off). replica_hits are local hits served by a non-owner pod's copy;
    # replication_tokens is the GPT-driven path's decision cost (off the
    # critical path, like admission)
    replica_hits: int = 0
    replica_installs: int = 0
    replica_drops: int = 0
    replication_epochs: int = 0
    replication_promotes: int = 0
    replication_demotes: int = 0
    replication_agreement: float = 1.0
    replication_tokens: int = 0
    # locality accounting (all zero when session->pod affinity is off):
    # consumer-side read classification and cross-pod hop/link costs.
    # local+remote partition the routed logical accesses (invariant locked
    # in tests/test_locality.py)
    locality_local_reads: int = 0
    locality_remote_reads: int = 0
    locality_remote_read_share: float = 0.0
    locality_remote_hop_s: float = 0.0
    locality_link_stall_s: float = 0.0
    # resilience accounting (all zero / defaults without a FaultPlan or
    # autoscaler). recovery_s is the mean hit-EWMA recovery time across
    # closed failover transients; failover/steady p95 split task latency
    # by whether the task ended inside a failure->recovery window;
    # incomplete_sessions counts sessions that did not finish their task
    # stream (the zero-stall-forever acceptance gate: always 0)
    resilience_failovers: int = 0
    resilience_restores: int = 0
    resilience_scale_outs: int = 0
    resilience_scale_ins: int = 0
    resilience_aborted_loads: int = 0
    resilience_retried_loads: int = 0
    resilience_timeout_loads: int = 0
    resilience_retry_wait_s: float = 0.0
    resilience_lost_work_s: float = 0.0
    resilience_lost_keys: int = 0
    resilience_lost_replicas: int = 0
    resilience_prefetch_aborted: int = 0
    resilience_recovery_s: float = 0.0
    resilience_unrecovered: int = 0
    resilience_failover_p95_s: float = 0.0
    resilience_steady_p95_s: float = 0.0
    resilience_incomplete_sessions: int = 0
    # GPT-driven post-failover recovery (re-warm vs lazy); token cost is
    # off the critical path like admission/replication decisions
    recovery_rewarms: int = 0
    recovery_lazy: int = 0
    recovery_agreement: float = 1.0
    recovery_tokens: int = 0
    autoscale_actions: int = 0
    # scale_outs the warm-up-aware autoscaler gate deferred (0 unless
    # ``autoscale_kw={"warmup_aware": True, ...}``)
    autoscale_deferred: int = 0
    # open-loop traffic accounting (ISSUE 7; all zero without an arrival
    # process). p99 joins p50/p95 because the capacity harness's SLO is a
    # tail target. Flow balance (spawned == completed + in_system) and the
    # Little's-law residual |L - lambda*W| are the queueing locks
    # tests/test_traffic.py asserts on every capacity cell.
    p99_task_latency_s: float = 0.0
    traffic_spawned: int = 0
    traffic_completed: int = 0
    traffic_in_system: int = 0
    traffic_offered_rate: float = 0.0
    traffic_measured_rate: float = 0.0
    traffic_mean_sojourn_s: float = 0.0
    traffic_mean_in_system: float = 0.0
    traffic_little_residual: float = 0.0
    # mutable-data-plane / coherence accounting (ISSUE 8; all zero / 1.0
    # without a MutationPlan). stale_reads are consumes that served a
    # version-lagged copy within its declared bound (a sub-bucket of the
    # routed-invariant buckets); refresh_loads are the authoritative
    # reloads a refresh verdict forced (a sub-bucket of remote_loads);
    # superseded_fills are in-flight fills outdated by a write and refused
    # install under a zero-staleness policy; max_staleness_s is the worst
    # staleness any consume ever served (the bounded-staleness contract
    # caps it at the policy bound); agreement/tokens are the GPT-driven
    # cache_update path's grading and decision cost (off the critical
    # path, like admission/replication/recovery)
    coherence_mutations: int = 0
    coherence_invalidations: int = 0
    coherence_writethroughs: int = 0
    coherence_stale_reads: int = 0
    coherence_refresh_loads: int = 0
    coherence_superseded_fills: int = 0
    coherence_clamped: int = 0
    coherence_stale_share: float = 0.0
    coherence_max_staleness_s: float = 0.0
    coherence_agreement: float = 1.0
    coherence_tokens: int = 0
    # LLM decision-plane resilience (ISSUE 9; all zero without an
    # EndpointFaultPlan — the router itself only exists when one is
    # passed). llm_calls counts every routed request (planning rounds +
    # latency-free cache-op decisions); retries are failed attempts
    # (outage picks, 429s); hedges/hedge_wins are the speculative second
    # requests and how many answered first (the loser's tokens land in
    # llm_retry_tokens); parse_fallbacks are ungraded programmatic
    # fallbacks after a garbled prompt/completion; degraded_decisions are
    # cache-op decisions the pool could not serve at all (fallback_share =
    # degraded / decision opportunities); retry_wait_s is session-clock
    # time planning rounds spent on detection/backoff/retry-after
    llm_calls: int = 0
    llm_retries: int = 0
    llm_hedges: int = 0
    llm_hedge_wins: int = 0
    llm_rate_limited: int = 0
    llm_malformed: int = 0
    llm_parse_fallbacks: int = 0
    llm_degraded_decisions: int = 0
    llm_fallback_share: float = 0.0
    llm_retry_tokens: int = 0
    llm_retry_wait_s: float = 0.0
    llm_breaker_opens: int = 0
    # plan-cache tier (ISSUE 10; all zero / 1.0 without a PlanCache).
    # hits are planning rounds served verbatim from the shared plan cache
    # (zero plan tokens, no endpoint exposure); installs/rejected/
    # evictions/expired are the admission policy's install-path verdicts;
    # invalidations are entries dropped by a covered-key mutation under an
    # invalidating coherence policy; stale_served is the paranoid
    # serve-time version guard (structurally 0 — the safety lock asserts
    # it); agreement/tokens are the GPT-prompted admission path's grading
    # and decision cost (off the critical path, like admission)
    plancache_lookups: int = 0
    plancache_hits: int = 0
    plancache_hit_rate: float = 0.0
    plancache_installs: int = 0
    plancache_rejected: int = 0
    plancache_evictions: int = 0
    plancache_expired: int = 0
    plancache_invalidations: int = 0
    plancache_stale_served: int = 0
    plancache_agreement: float = 1.0
    plancache_tokens: int = 0
    # token-conservation accounting (ISSUE 10 satellite: the invariant
    # tests recompute these from the raw traces/policies and assert the
    # split is exact). tokens_trace_total sums every per-trace bucket;
    # tokens_decision_total sums the off-critical-path policy decision
    # costs (admission + replication + recovery + coherence + plan-cache)
    # plus the endpoint router's retry/hedge-loser tokens;
    # tokens_fleet_total is their sum — the episode's whole token bill
    tokens_trace_total: int = 0
    tokens_decision_total: int = 0
    tokens_fleet_total: int = 0

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class EpisodeResult:
    metrics: EpisodeMetrics
    sessions: List[Session]
    router: PodLocalCacheRouter
    contention: PodContention
    # the episode's coherence runtime (None without a MutationPlan): the
    # property tests replay its ledger/versions against the contract
    coherence: Optional[CoherenceRuntime] = None

    def evaluate_answers(self) -> Report:
        """Answer-quality aggregate over every session's tasks/traces
        (independent of contention — time shifts, answers don't)."""
        tasks = [t for s in self.sessions for t in s.tasks]
        traces = [tr for s in self.sessions for tr in s.traces]
        return evaluate(tasks, traces)


def session_seed(seed: int, sid: int) -> int:
    """Per-session derived seed. Additive so a 1-session engine started at
    ``session_seed(seed, sid)`` replays exactly the workload/LLM stream of
    session ``sid`` of an N-session episode (the determinism tests rely on
    this). Answer traces replay bit-identically; *time and token* traces
    may differ because read plans depend on the shared cache state other
    sessions produce — that interaction is the scenario under test."""
    return seed + sid


class ConcurrentEpisodeEngine:
    """Event-granular discrete-event execution of N agent sessions over one
    shared, pod-sharded cache. See module docstring for the model."""

    def __init__(self, n_sessions: int, *, n_pods: int = 4,
                 capacity_per_pod: int = 5, model: str = "gpt-4-turbo",
                 prompting: str = "cot", few_shot: bool = True,
                 policy: str = "lru", llm_decisions: bool = True,
                 latency: Optional[LatencyModel] = None, seed: int = 0,
                 prefetch: bool = False, admission: Optional[str] = None,
                 admission_impl: str = "python",
                 scenario: str = "working",
                 scenario_kw: Optional[Dict] = None,
                 sketch_kw: Optional[Dict] = None,
                 replication: bool = False,
                 replication_impl: str = "python",
                 replication_kw: Optional[Dict] = None,
                 rows_range: Optional[tuple] = None,
                 prefetch_adaptive: bool = True,
                 affinity: Optional[str] = None,
                 remote_read_penalty: float = 1.0,
                 affinity_kw: Optional[Dict] = None,
                 link_queue: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 retry_kw: Optional[Dict] = None,
                 recovery_impl: Optional[str] = None,
                 recovery_kw: Optional[Dict] = None,
                 autoscale: bool = False,
                 autoscale_kw: Optional[Dict] = None,
                 fault_kw: Optional[Dict] = None,
                 traffic=None,
                 mutations: Optional[MutationPlan] = None,
                 coherence: Optional[str] = None,
                 coherence_impl: str = "python",
                 coherence_kw: Optional[Dict] = None,
                 endpoint_fault_plan: Optional[EndpointFaultPlan] = None,
                 n_endpoints: int = 4,
                 endpoint_kw: Optional[Dict] = None,
                 plan_cache: Optional[str] = None,
                 plan_cache_kw: Optional[Dict] = None):
        assert n_sessions >= 1 and n_pods >= 1
        if capacity_per_pod < 1:
            raise ValueError(
                f"capacity_per_pod must be >= 1, got {capacity_per_pod}")
        # open-loop traffic (ISSUE 7): an ArrivalProcess (or the string
        # "closed" for the degenerate all-at-t=0 schedule) turns sessions
        # into first-class spawn/retire events. A real arrival process
        # OVERRIDES n_sessions with its schedule length; "closed" keeps
        # the given count. ``traffic=None`` (default) is the closed-loop
        # engine, bit-identical to PR 6.
        self.traffic = None
        self.tstats = None
        if traffic is not None:
            self.traffic = make_traffic(traffic, n_sessions)
            n_sessions = len(self.traffic.schedule())
        self.n_sessions = n_sessions
        self.n_pods = n_pods
        self.profile = Profile(model, prompting, few_shot)
        self.policy = policy
        self.llm_decisions = llm_decisions
        self.latency = latency or LatencyModel()
        self.seed = seed
        self.capacity_per_pod = capacity_per_pod
        self.prefetch = prefetch
        self.prefetch_adaptive = prefetch_adaptive
        # adaptive depth guard state: pod -> [threshold, demand0, stalled0]
        # (counter snapshots at the last adjustment window)
        self._depth_state: Dict[str, List[float]] = {}
        self.scenario = scenario
        self.scenario_kw = dict(scenario_kw or {})

        # session->pod affinity + consumer-side locality penalty (ISSUE 5):
        # each session gets a home pod and every value consumed from a
        # non-home pod pays a cross-pod hop of (penalty-1) x cache_read,
        # optionally FCFS-serialized on the home pod's ingress link.
        # ``affinity=None`` (the default) keeps the locality-free engine;
        # penalty 1.0 with affinity on classifies reads without changing a
        # single trace (the degeneracy contract tests/test_locality.py
        # locks down).
        self.affinity = None
        self.locality = None
        if affinity is not None:
            if remote_read_penalty < 1.0:
                # a sub-1x penalty would CREDIT remote reads (negative
                # clock advances) — fail loudly, not as a deep scheduler
                # assert minutes into an episode
                raise ValueError(
                    f"remote_read_penalty must be >= 1.0, got "
                    f"{remote_read_penalty}")
            self.affinity = make_affinity(affinity, n_pods=n_pods,
                                          **(affinity_kw or {}))
            self.locality = LocalityModel(self.latency,
                                          penalty=remote_read_penalty,
                                          link_queue=link_queue)
        else:
            assert remote_read_penalty == 1.0 and not link_queue \
                and not affinity_kw, \
                "remote_read_penalty/link_queue/affinity_kw require " \
                "session->pod affinity (pass " \
                "affinity='sticky'/'round_robin'/...)"

        # fault/elasticity layer (ISSUE 6): a sim-time FaultPlan turns
        # membership changes into first-class scheduler events; the
        # runtime itself is built per run() (it needs the event queue).
        # ``fault_plan=None`` AND ``autoscale=False`` skip the layer
        # entirely; an EMPTY (non-None) FaultPlan runs with every hook
        # live but replays the fault-free engine bit-identically (the
        # degeneracy contract tests/test_faults.py locks down).
        self.fault_plan = fault_plan
        self.retry_policy = RetryPolicy(**(retry_kw or {}))
        self.fault_kw = dict(fault_kw or {})
        self.autoscaler = (BacklogAutoscaler(**(autoscale_kw or {}))
                           if autoscale else None)
        assert autoscale or not autoscale_kw, \
            "autoscale_kw requires autoscale=True"
        # LLM decision-plane resilience (ISSUE 9): a sim-time
        # EndpointFaultPlan stands up a pool of N simulated GPT endpoints
        # and an EndpointRouter that owns every routed ``complete()`` call
        # (the four shared cache-op sub-LLMs below are wrapped in RoutedLLM)
        # plus every planning round's retry/hedge latency. The router's RNG
        # is private and planning extra is exactly 0.0 under an EMPTY
        # (non-None) plan, so the degeneracy contract holds: empty-plan
        # runs replay the router-free engine's traces bit-identically
        # (tests/test_endpoints.py locks this down). ``None`` (default)
        # skips the layer entirely.
        self.endpoint_plan = endpoint_fault_plan
        self.endpoints = None
        if endpoint_fault_plan is not None:
            if not isinstance(endpoint_fault_plan, EndpointFaultPlan):
                raise ValueError(
                    f"endpoint_fault_plan must be an EndpointFaultPlan or "
                    f"None, got {type(endpoint_fault_plan).__name__}")
            self.endpoints = EndpointRouter(
                n_endpoints, endpoint_fault_plan, seed=seed + 514229,
                **(endpoint_kw or {}))
        elif endpoint_kw or n_endpoints != 4:
            raise ValueError(
                "endpoint_kw/n_endpoints require an endpoint fault plan "
                "(pass endpoint_fault_plan=EndpointFaultPlan(...))")

        self.recovery_policy = None
        if recovery_impl is not None:
            rec_llm = (self._route(SimLLM(self.profile, seed=seed + 331999))
                       if recovery_impl == "llm" else None)
            self.recovery_policy = make_recovery(
                impl=recovery_impl, llm=rec_llm, few_shot=few_shot,
                **(recovery_kw or {}))
        self._faults = None

        # mutable data plane (ISSUE 8): a sim-time MutationPlan versions
        # datastore keys; the coherence policy decides what every cached
        # copy's version lag means — at write time (invalidate / push) or
        # at consume time (bounded staleness, optionally GPT-driven).
        # ``mutations=None`` AND ``coherence=None`` skip the layer
        # entirely (bit-identical replay of the immutable-store engine —
        # the degeneracy contract tests/test_coherence.py locks down); an
        # EMPTY (non-None) MutationPlan runs with every hook live but
        # mutates nothing. The runtime itself is built per run().
        self.mutation_plan = None
        self.coherence_policy = None
        self._coherence = None
        if mutations is not None or coherence is not None:
            if mutations is not None and not isinstance(mutations,
                                                        MutationPlan):
                raise ValueError(
                    f"mutations must be a MutationPlan or None, got "
                    f"{type(mutations).__name__}")
            self.mutation_plan = (mutations if mutations is not None
                                  else MutationPlan())
            coh_llm = (self._route(SimLLM(self.profile, seed=seed + 433003))
                       if coherence_impl == "llm" else None)
            self.coherence_policy = make_coherence(
                coherence or "write-invalidate", impl=coherence_impl,
                llm=coh_llm, few_shot=few_shot, **(coherence_kw or {}))
        elif coherence_impl != "python" or coherence_kw:
            raise ValueError(
                "coherence_impl/coherence_kw require a mutable data plane "
                "(pass mutations=MutationPlan(...) and/or a coherence "
                "policy name)")

        # plan-cache tier (ISSUE 10): ONE shared, capacity-bounded cache of
        # planning-round results keyed (task template, context digest) —
        # a hit serves the stored ReadPlan verbatim and skips the planning
        # LLM round entirely (zero plan tokens, no endpoint exposure; a
        # pod-local lookup read is still charged). Digests embed current
        # key versions (wired to the coherence runtime per run()), so a
        # covered-key write makes old plans unreachable; an invalidating
        # coherence policy additionally drops them eagerly.
        # ``plan_cache=None`` (the default) skips the tier entirely — the
        # planning path replays the pre-plan-cache engine bit-identically
        # (the degeneracy contract tests/test_plan_cache.py locks down).
        self.plan_cache = None
        if plan_cache is not None:
            pc_llm = (self._route(SimLLM(self.profile, seed=seed + 646237))
                      if plan_cache == "llm" else None)
            self.plan_cache = make_plan_cache(
                plan_cache, llm=pc_llm, few_shot=few_shot,
                **(plan_cache_kw or {}))
        elif plan_cache_kw:
            raise ValueError(
                "plan_cache_kw requires a plan cache (pass "
                "plan_cache='python'/'programmatic'/'llm')")

        # cross-session admission: ONE policy + ONE frequency sketch shared
        # by every pod and session (key popularity is global). The sketch
        # ages on simulated time — touches carry the session clocks, which
        # only execute at the global-minimum event time. ``admission=None``
        # (the default) reproduces the install-everything engine exactly.
        # Replication consumes the same sketch, so enabling it alone also
        # builds one.
        self.sketch = None
        adm = None
        if admission is not None or replication:
            self.sketch = FrequencySketch(**(sketch_kw or {}))
        elif self.recovery_policy is not None:
            # post-failover recovery judges lost keys on sketch frequency;
            # without admission/replication nothing else reads it, so its
            # presence cannot change a single routing decision
            self.sketch = FrequencySketch(**(sketch_kw or {}))
        if admission is not None:
            adm_llm = (self._route(SimLLM(self.profile, seed=seed + 104729))
                       if admission_impl == "llm" else None)
            adm = make_admission(admission, impl=admission_impl, llm=adm_llm,
                                 few_shot=few_shot)
            if isinstance(adm, LLMAdmission):
                # locality-aware prompt evidence: the GPT-driven admission
                # path sees the candidate's remote consumer demand
                adm.locality = self.locality
        self.admission_policy = adm

        # shared infrastructure: datastore + pod-sharded cache. Pod caches
        # use tick-order recency (no global wall clock exists across
        # session-local clocks; scheduler order IS the global event order).
        self.store = GeoDataStore(SimClock(self.latency),
                                  rows_range=rows_range)
        self.pod_ids = [f"pod{i}" for i in range(n_pods)]
        self.router = PodLocalCacheRouter(self.pod_ids,
                                          capacity_per_pod=capacity_per_pod,
                                          policy_name=policy,
                                          admission=adm, sketch=self.sketch)
        self.router.locality = self.locality
        self.contention = PodContention(self.pod_ids)
        if self.plan_cache is not None:
            # residency is part of a read plan's request context (see
            # repro.core.plan_cache): bind the digest's residency bit to
            # the live router, replica-aware like the planner's own check
            router = self.router
            self.plan_cache.resident_of = (
                lambda k: router.locate(k) is not None)

        # hot-key replication: one epoch-driven replicator over the shared
        # sketch (see repro.core.replication). ``replication=False`` (the
        # default) leaves the router's replica map empty and every
        # replica-aware path identical to the owner-only engine.
        self.replicator = None
        if replication:
            rkw = dict(replication_kw or {})
            pol_kw = {k: rkw.pop(k) for k in ("promote_min", "demote_frac")
                      if k in rkw}
            rep_llm = (self._route(SimLLM(self.profile, seed=seed + 224737))
                       if replication_impl == "llm" else None)
            rpol = make_replication(impl=replication_impl, llm=rep_llm,
                                    few_shot=few_shot, **pol_kw)
            self.replicator = HotKeyReplicator(
                self.router, self.sketch, self.store.peek, policy=rpol,
                **rkw)
            self.router.spill = self.replicator.offer
        if self.locality is not None and self.replicator is None:
            # nothing drains the consumer-demand evidence without a
            # replicator epoch: window it on sim time so prompt surfaces
            # (LLM admission, cache_admit) see recent demand, not
            # episode-lifetime counts
            self.locality.demand_window_s = 60.0

    def _route(self, llm):
        """Wrap a cache-op sub-LLM in the endpoint router (identity when no
        endpoint fault plan is configured)."""
        return RoutedLLM(llm, self.endpoints) if self.endpoints is not None \
            else llm

    def _store_key(self):
        """Task-memo discriminator for datastore variants (frame content is
        keyed by ``rows_range``; the default store shares one memo slot)."""
        return getattr(self.store, "rows_range", None)

    # -- session assembly ---------------------------------------------------
    def _make_session(self, sid: int, n_tasks: int, reuse_rate: float,
                      events: EventQueue) -> Session:
        sseed = session_seed(self.seed, sid)
        clock = SimClock(LatencyModel(**dataclasses.asdict(self.latency)))
        llm = SimLLM(self.profile, seed=sseed)
        stats = SessionStats()
        controller = SharedCacheController(
            self.router, rng=llm.rng,
            decision_eps=self.profile.cache_eps if self.llm_decisions else 0.0,
            endpoints=self.endpoints)
        home_idx = (self.affinity.home(sid, 0)
                    if self.affinity is not None else None)
        scenario_kw = self.scenario_kw
        if self.scenario == "affinity_zipf":
            # per-pod hot sets: a session samples its HOME pod's group's
            # zipf ranking (with cross-pod spillover — see WorkloadSampler);
            # without affinity the group falls back to a round-robin split
            scenario_kw = dict(scenario_kw)
            scenario_kw.setdefault("n_groups", self.n_pods)
            scenario_kw["group"] = (home_idx if home_idx is not None
                                    else sid % scenario_kw["n_groups"])
        tasks = _memo_tasks(sseed, n_tasks, reuse_rate, self.scenario,
                            scenario_kw, self.store, self._store_key())
        session = Session(sid=sid, clock=clock, llm=llm, runner=None,
                          tasks=tasks, stats=stats,
                          home_pod=(self.pod_ids[home_idx]
                                    if home_idx is not None else None))
        registry = ToolRegistry(
            make_shared_cache_tools(self.router, self.store, self.contention,
                                    clock, session, events,
                                    locality=self.locality,
                                    faults=self._faults,
                                    coherence=self._coherence)
            + make_geo_tools(clock))
        if self.recovery_policy is not None:
            # post-failover recovery as a callable cache op (like
            # cache_admit / cache_replicate): the agent can probe the
            # re-warm/lazy verdict for a key without consuming a decision
            registry.register(make_recovery_tool(self.recovery_policy,
                                                 self.sketch))
        if self._coherence is not None:
            # coherence as a callable cache op (the paper's cache-update
            # op surfaced as a tool, like cache_admit / cache_replicate):
            # probe the fresh/refresh/serve_stale verdict for a key
            # without consuming a decision or LLM tokens
            registry.register(make_coherence_tool(self._coherence,
                                                  self.sketch))
        if self.replicator is not None:
            # replication as a callable cache op (like cache_admit): the
            # agent/controller can query the replicate/drop/hold verdict
            registry.register(make_replication_tool(self.replicator))
        if self.plan_cache is not None:
            # the plan-cache tier as a callable cache op (like
            # cache_admit): probe the cache/bypass verdict and which
            # cached plans cover a key, without consuming a decision
            registry.register(make_plan_cache_tool(self.plan_cache))
        if self.admission_policy is not None:
            # admission as a callable cache op against the owning pod's
            # cache; with a locality model the verdict also reports the
            # key's remote consumer demand by home pod
            router = self.router
            registry.register(make_admission_tool(
                self.admission_policy, self.sketch,
                entries_of=lambda key: router.pods[router.owner(key)
                                                  ].entries(),
                victim_of=lambda key, entries: router.policies[
                    router.owner(key)].victim(entries),
                capacity_of=lambda key: router.pods[router.owner(key)
                                                    ].capacity,
                locality=self.locality))
        on_plan = (self._make_prefetcher(session, events)
                   if self.prefetch else None)
        session.runner = AgentRunner(registry, controller, llm, clock,
                                     self.store, use_cache=True,
                                     on_plan=on_plan,
                                     endpoints=self.endpoints,
                                     plan_cache=self.plan_cache)
        return session

    # -- async prefetch -----------------------------------------------------
    # modeled size of an average yearly frame (12-18k rows x 5200 B); only
    # used for the per-key consume-gap floor in the prefetch budget
    _MEAN_FRAME_MB = 78.0
    # a pod with this much queued work (in loads: backlog seconds over the
    # observed service EWMA) stops accepting prefetches — parking more
    # early loads there only displaces other sessions' demand traffic
    # (measured: the depth guard is what keeps the p95 win at 4:1
    # saturation, where per-load hideability alone turns it into a loss)
    _PREFETCH_DEPTH_MAX = 1.0
    # adaptive guard (``prefetch_adaptive=True``): hill-climb the depth
    # threshold on the fleet's OBSERVED stall rate, windowed over demand
    # loads and EWMA-smoothed. The controller is proportional — threshold =
    # clip(_DEPTH_A - _DEPTH_B * smoothed_rate, floor, cap) — so it tracks
    # the operating point instead of ratcheting on one bad window. The
    # mid-range regime (sessions:pods <= 2:1) stalls rarely: the threshold
    # rises well above the fixed guard and the overlap win the fixed guard
    # trims comes back (8/8 measured 1.10 -> ~1.2). Near the 4:1 operating
    # point (~0.65 smoothed stall rate) it lands at the fixed guard's
    # tuned value by construction; past saturation it drops to the floor,
    # shedding prefetch pressure the fixed guard still admits (32/4
    # improves). The signal is fleet-wide: per-pod window rates at these
    # episode lengths are too noisy to separate "hot pod in a calm fleet"
    # (prefetch still wins there) from "every pod saturated" (prefetch
    # displaces demand traffic).
    _DEPTH_MIN, _DEPTH_CAP = 0.5, 4.0
    _DEPTH_WINDOW = 4
    _DEPTH_A, _DEPTH_B = 2.4, 2.2     # thr = A - B * smoothed stall rate
    _DEPTH_EWMA = 0.7
    _DEPTH_SEED_RATE = 0.15

    def _depth_limit(self, pod: str) -> float:
        """Current prefetch depth threshold (fixed, or the adaptive
        controller's — adjusted at fleet-window boundaries)."""
        if not self.prefetch_adaptive:
            return self._PREFETCH_DEPTH_MAX
        st = self._depth_state.get("*")
        if st is None:
            # seeded mildly optimistic (thr ~2.1). The warmup convoy (every
            # session planning its first task at t=0) is where prefetch is
            # most hideable — everyone is inside an LLM round, no demand
            # queue exists yet — so the guard starts lifted; the short
            # window + wide signal clamp it within roughly a task at 4:1
            # saturation. Measured (seed 0): the controller dominates the
            # fixed guard at every grid cell (8/8 1.10->1.22, 16/8
            # 1.03->1.04, 16/4 1.02->1.04, 32/4 0.98->0.99)
            st = self._depth_state["*"] = [self._DEPTH_SEED_RATE, 0, 0]
        events, bad = self.contention.guard_stats_total()
        if events - st[1] >= self._DEPTH_WINDOW:
            rate = (bad - st[2]) / (events - st[1])
            st[0] += self._DEPTH_EWMA * (rate - st[0])
            st[1], st[2] = events, bad
        return min(self._DEPTH_CAP,
                   max(self._DEPTH_MIN,
                       self._DEPTH_A - self._DEPTH_B * st[0]))

    def _make_prefetcher(self, session: Session,
                         events: EventQueue) -> Callable[[Task, ReadPlan],
                                                         None]:
        """Plan-time hook: issue the planned ``load_db`` keys as async pod
        loads the instant the ReadPlan lands, so DB service overlaps the
        planning LLM round that follows.

        Queueing-aware budget, two tests per key (both from per-pod queue
        depth + observed service times):

        1. **consume-horizon**: the owning pod must be able to *start
           serving the load before the session's predicted consume time*.
           The horizon walks the required keys in acquisition order,
           accumulating (a) the planning round ahead, (b) a pod-local read
           per already-cached key, (c) the completion times of the keys
           this very walk prefetched (a later key cannot be consumed
           before an earlier one lands), and (d) the pod's observed
           service EWMA for keys left lazy;
        2. **depth guard**: the pod's queue depth (backlog seconds over
           its service EWMA) must be below ``_PREFETCH_DEPTH_MAX`` —
           at saturation individually-hideable prefetches still displace
           other sessions' demand loads and fatten the tail.

        Failing either leaves the key lazy, so saturated pods degrade
        gracefully to demand loading. The PR-2 planning-latency budget
        shut prefetch off entirely past ~4:1 sessions-to-pods; this budget
        keeps the p95 win there (measured in ``table_prefetch``'s
        16-sessions/4-pods rows — see benchmarks/README.md)."""
        router, store, contention = self.router, self.store, self.contention
        faults = self._faults
        prof = self.profile
        plan_tok = (PLAN_PROMPT_TOKENS_FS if prof.few_shot
                    else PLAN_PROMPT_TOKENS)[prof.prompting]

        def _plan_latency(task: Task) -> float:
            lat = session.clock.latency
            if prof.prompting == "cot":   # the full planning round is ahead
                return lat.llm_round(
                    plan_tok + STEP_SUMMARY_TOKENS * len(task.steps),
                    PLAN_COMPLETION_TOKENS["cot"])
            # react plans per step; only the first thought/action round
            # reliably precedes the first consume
            return lat.llm_round(plan_tok, PLAN_COMPLETION_TOKENS["react"])

        loc = self.locality

        def prefetch(task: Task, plan: ReadPlan) -> None:
            now = session.clock.now()
            lat = session.clock.latency
            home = session.home_pod
            # predicted seconds until the session consumes the NEXT key,
            # starting with the planning round it is about to pay
            eta = _plan_latency(task)
            consume_gap = lat.cache_read(self._MEAN_FRAME_MB)

            def _gap(p: str) -> float:
                # predicted consume cost of a pod-local read: inflated by
                # the cross-pod hop when the serving pod is off-home (the
                # owner approximates the serving pod — a home replica
                # would be cheaper, which only makes the budget
                # conservative). Exactly consume_gap at penalty 1x.
                if loc is not None and p != home:
                    return consume_gap * loc.penalty
                return consume_gap

            for k in task.required_keys:
                if plan.choices.get(k) != "load_db":
                    eta += _gap(router.owner(k))   # pod-local read of a hit
                    continue
                pod = router.owner(k)
                if k in router.in_flight or k in router.pods[pod]:
                    eta += _gap(pod)          # join / hit at consume time
                    continue
                frame = store.peek(k)
                service = lat.db_load(frame.size_mb)
                if (contention.backlog_s(pod, now) > eta
                        or contention.queue_depth(pod, now, service)
                        >= self._depth_limit(pod)):
                    # leave the key lazy when the pod either cannot START
                    # serving it before its predicted consume point, or is
                    # already queueing deeper than the depth guard allows —
                    # the demand load will queue later at its natural FCFS
                    # position instead of ahead of other sessions' traffic
                    session.stats.prefetch_skipped += 1
                    eta += contention.expected_service_s(pod, service)
                    if loc is not None and pod != home:
                        eta += loc.hop_s(frame.size_mb)
                    continue
                store.loads += 1
                _, completes = contention.begin(pod, now, service)
                rec = router.start_load(k, frame, frame.size_bytes,
                                        issued_at=now, completes_at=completes,
                                        prefetched=True)
                session.prefetched[k] = rec
                session.stats.prefetch_issued += 1
                if faults is not None:
                    # if the pod dies before completion, the abort purges
                    # this session's prefetched entry so the consume falls
                    # through to a plain demand load (graceful bypass)
                    faults.pf_owner[k] = session
                events.push(completes, PRI_FINISH, payload=k)
                # a later key cannot be consumed before this one lands
                eta = max(eta, completes - now) + _gap(pod)

        return prefetch

    # -- event-granular scheduler -------------------------------------------
    def _session_body(self, s: Session):
        """Generator running one session's whole task stream; every inner
        yield is a clock advance (an interleave point for the scheduler).
        With affinity enabled the session's home pod is re-evaluated at
        every task boundary (static policies return the same pod; the
        ``migrating`` policy drifts it across the episode)."""
        aff = self.affinity
        faults = self._faults
        endpoints = self.endpoints
        while True:
            task = s.next_task()
            if task is None:
                return
            if aff is not None:
                s.home_pod = self.pod_ids[aff.home(s.sid, s.cursor - 1)]
            if faults is None and endpoints is None:
                trace = yield from s.runner.iter_task(task)
            else:
                # per-task fault counters: retry adjustments land while
                # the session is suspended mid-task, so the stat deltas
                # across the task are exactly this task's share
                st = s.stats
                r0, w0 = st.retried_loads, st.retry_wait_s
                to0, l0 = st.timeout_loads, st.lost_work_s
                rn = s.runner
                er0, eh0 = rn.llm_retries, rn.llm_hedges
                ew0, ews0 = rn.llm_hedge_wins, rn.llm_retry_wait_s
                trace = yield from rn.iter_task(task)
                trace.retried_loads = st.retried_loads - r0
                trace.retry_wait_s = st.retry_wait_s - w0
                trace.timeout_loads = st.timeout_loads - to0
                trace.lost_work_s = st.lost_work_s - l0
                trace.llm_retries = rn.llm_retries - er0
                trace.llm_hedges = rn.llm_hedges - eh0
                trace.llm_hedge_wins = rn.llm_hedge_wins - ew0
                trace.llm_retry_wait_s = rn.llm_retry_wait_s - ews0
                if faults is not None:
                    faults.task_ends.append((s.clock.now(), trace.time_s))
            s.traces.append(trace)

    def run(self, tasks_per_session: int = 25,
            reuse_rate: float = 0.8) -> EpisodeResult:
        if tasks_per_session < 1:
            raise ValueError(
                f"tasks_per_session must be >= 1, got {tasks_per_session}")
        if not 0.0 <= reuse_rate <= 1.0:
            raise ValueError(
                f"reuse_rate must be in [0, 1], got {reuse_rate}")
        events = EventQueue()
        # fault runtime: built per run (it owns event-queue handles); the
        # plan's membership changes enter the heap at PRI_FAULT so they
        # order exactly against same-instant completions and resumes
        if self.fault_plan is not None or self.autoscaler is not None:
            self._faults = FaultRuntime(self, events, self.retry_policy,
                                        recovery=self.recovery_policy,
                                        scaler=self.autoscaler,
                                        **self.fault_kw)
            for fev in (self.fault_plan or ()):
                events.push(fev.at, PRI_FAULT, payload=fev)
        # coherence runtime (ISSUE 8): writes enter the heap at PRI_FAULT —
        # a mutation at a completion's instant wins, so the fill observes
        # the write (superseded / re-stamped) exactly like a pod failing
        # at that instant would abort it. Seeded after fault events, so a
        # same-instant (fault, mutation) pair applies fault-first
        # (deterministic push-order tie-break).
        if self.mutation_plan is not None:
            self._coherence = CoherenceRuntime(self, self.mutation_plan,
                                               self.coherence_policy)
            self.router.version_of = self._coherence.current_version
            # zero-staleness policies must never install a fill a write
            # outdated mid-flight; bounded policies install it (readers
            # decide at consume time)
            self.router.fresh_fills_only = (
                self.coherence_policy.invalidate_on_write
                or self.coherence_policy.refresh_on_write)
            for mev in self.mutation_plan:
                events.push(mev.at, PRI_FAULT, payload=mev)
        if self.plan_cache is not None and self._coherence is not None:
            # versioned context digests (ISSUE 10): the plan cache keys on
            # key@version, so a covered-key write moves every digest over
            # it — a lagged plan becomes unreachable under ANY policy
            self.plan_cache.version_of = self._coherence.current_version
        # endpoint fault schedule (ISSUE 9): decision-plane faults enter
        # the heap at PRI_FAULT like pod faults and writes; the router's
        # analytic windows answer up/slow/limit queries directly, so these
        # events only advance the router clock and count transitions
        if self.endpoints is not None:
            self.endpoints.now = 0.0
            for eev in self.endpoint_plan:
                events.push(eev.at, PRI_FAULT, payload=eev)
        tstats = None
        if self.traffic is None:
            sessions = [self._make_session(sid, tasks_per_session,
                                           reuse_rate, events)
                        for sid in range(self.n_sessions)]
            bodies = [self._session_body(s) for s in sessions]
            for s in sessions:
                events.push(0.0, PRI_SESSION, s.sid, s.sid)
        else:
            # open-loop (ISSUE 7): sessions are first-class spawn events.
            # A spawn pops at (arrival, PRI_SESSION, sid) — for the
            # degenerate all-at-t=0 schedule that is exactly the order
            # the closed-loop push loop above produces, and the handler
            # constructs + steps the session inline, so the replay is
            # bit-identical (the degeneracy contract).
            arrivals = self.traffic.schedule()
            tstats = self.tstats = TrafficStats(self.traffic.offered_rate)
            sessions = [None] * len(arrivals)
            bodies = [None] * len(arrivals)
            for sid, arr in enumerate(arrivals):
                events.push(arr.at, PRI_SESSION, sid,
                            TrafficSpawn(sid, arr.lifetime_tasks))
        if self._faults is not None:
            self._faults.sessions = sessions
        # Hot loop (ISSUE 4): payloads are an int session id or a str
        # in-flight key (no wrapper tuples), popped without Event
        # allocation. Zero-length clock advances are COALESCED: while the
        # running session's clock has not moved, every other session event
        # sits at a strictly later (time, priority, tiebreak) — the session
        # would be re-popped immediately — and a session can only schedule
        # *future* completions, so stepping its generator inline is
        # bit-identical to round-tripping through the heap (the determinism
        # tests and table digests lock this in).
        pop = events.pop_timed
        in_flight = self.router.in_flight
        finish_load = self.router.finish_load
        replicator = self.replicator
        faults = self._faults
        scaler = self.autoscaler
        coherence = self._coherence
        endpoints = self.endpoints
        n_events = n_steps = 0
        while events:
            t, payload = pop()
            n_events += 1
            if endpoints is not None:
                # decision calls read the router clock (plan calls pass
                # their own timestamp), so keep it on the pop frontier
                endpoints.now = t
            if replicator is not None and t >= replicator.next_epoch:
                # replication epochs run on simulated-time boundaries,
                # before the first event at/after each boundary (background
                # bookkeeping: no session clock is charged)
                replicator.maybe_run(t)
            if scaler is not None and t >= scaler.next_check:
                # autoscaler polls on sim-time boundaries like replication
                # epochs: fleet sizing is background control, no session
                # clock is charged
                faults.run_autoscaler(t)
            cls = payload.__class__
            if cls is not int:
                if cls is str:
                    # pod-load completion: install into the owning pod's
                    # cache at exactly this instant (before any same-time
                    # session op). An aborted load was already purged from
                    # in_flight, so its completion event is inert.
                    if payload in in_flight:
                        finish_load(payload)
                        if faults is not None:
                            faults.note_finish(payload)
                    continue
                if cls is TrafficSpawn:
                    # session arrival: construct lazily (construction
                    # touches no shared mutable state — task memo and LLM
                    # streams are pure functions of the sid), advance its
                    # clock to the arrival instant, then FALL THROUGH to
                    # step it exactly like a resume event
                    sid = payload.sid
                    n_tasks = (payload.lifetime_tasks
                               if payload.lifetime_tasks is not None
                               else tasks_per_session)
                    s = self._make_session(sid, n_tasks, reuse_rate, events)
                    s.clock.advance_to(t)
                    sessions[sid] = s
                    bodies[sid] = self._session_body(s)
                    tstats.note_spawn(t, sid)
                    payload = sid
                elif cls is TrafficRetire:
                    # session departure: pure ledger, no clock moves
                    tstats.note_retire(t, payload.sid)
                    continue
                elif cls is MutationEvent:
                    # datastore write (ISSUE 8): version the key and run
                    # the policy's fan-out before any same-instant
                    # completion installs or session consumes
                    coherence.apply(t, payload)
                    continue
                elif cls is EndpointFaultEvent:
                    # endpoint transition (ISSUE 9): the router reads
                    # availability from analytic windows, so this only
                    # moves its clock and counts the transition
                    self.endpoints.apply(t, payload)
                    continue
                else:
                    # membership change (FaultEvent) or retry (RetryEvent)
                    faults.handle(t, payload)
                    continue
            if faults is not None and t < faults.resume_at.get(payload, 0.0):
                # stale resume: a retry pushed this session's wake-up to a
                # later instant (only possible while faults are active)
                continue
            body = bodies[payload]
            clock = sessions[payload].clock
            t0 = clock.now()
            try:
                next(body)
                n_steps += 1
                while clock.now() == t0:      # coalesce zero-length yields
                    next(body)
                    n_steps += 1
            except StopIteration:
                if tstats is not None:
                    # retire as a first-class event at the completion
                    # instant of the session's last task
                    events.push(clock.now(), PRI_SESSION, payload,
                                TrafficRetire(payload))
                continue
            events.push(clock.now(), PRI_SESSION, payload, payload)
        self._profile(sessions, n_events, n_steps)
        return EpisodeResult(metrics=self._metrics(sessions),
                             sessions=sessions, router=self.router,
                             contention=self.contention,
                             coherence=self._coherence)

    def _profile(self, sessions: List[Session], n_events: int,
                 n_steps: int) -> None:
        """Bulk-accumulate this episode's mechanism counters into the
        process-wide profile table (``benchmarks.run --profile``)."""
        rstats = self.router.stats
        profiling.add("engine.episodes")
        profiling.add("engine.tasks",
                      sum(len(s.traces) for s in sessions))
        profiling.add("engine.events", n_events)
        profiling.add("engine.gen_steps", n_steps)
        # generator resumes the heap round-trip would otherwise have paid
        profiling.add("engine.coalesced_steps",
                      max(0, n_steps - n_events))
        profiling.add("engine.routed", rstats.routed)
        profiling.add("engine.db_loads", self.contention.total_loads)
        profiling.add("engine.replica_installs", rstats.replica_installs)
        if self.sketch is not None:
            profiling.add("sketch.touches", self.sketch.touches)
            profiling.add("sketch.flushes", self.sketch.flushes)
            profiling.add("sketch.ages", self.sketch.ages)
        if self.locality is not None:
            lstats = self.locality.stats
            profiling.add("engine.remote_reads", lstats.remote_reads)
            profiling.add("engine.remote_hop_s", lstats.remote_hop_s)

    def _metrics(self, sessions: List[Session]) -> EpisodeMetrics:
        lat = np.array([tr.time_s for s in sessions for tr in s.traces],
                       np.float64)
        n_tasks = int(lat.size)
        makespan = max((s.clock.now() for s in sessions), default=0.0)
        rstats = self.router.stats
        ts = self.tstats
        fr = self._faults
        recovery_s, unrecovered = fr.recovery_stats() if fr else (0.0, 0)
        fo_p95, steady_p95 = fr.attributed_p95() if fr else (0.0, 0.0)
        rec_pol = self.recovery_policy
        coh = self._coherence
        cpol = self.coherence_policy
        ep = self.endpoints
        pc = self.plan_cache
        pcs = pc.stats if pc is not None else None
        parse_fb = sum(getattr(p, "parse_fallbacks", 0)
                       for p in (self.admission_policy,
                                 getattr(self.replicator, "policy", None),
                                 rec_pol, cpol,
                                 pc.policy if pc is not None else None))
        # token-conservation split (ISSUE 10 satellite): per-trace buckets
        # + off-critical-path decision costs + retry/hedge-loser tokens is
        # the episode's whole bill — the invariant tests recompute each
        # side from the raw objects and assert the sum is exact
        adm_tokens = (getattr(self.admission_policy, "prompt_tokens", 0)
                      + getattr(self.admission_policy, "completion_tokens",
                                0))
        rec_tokens = (getattr(rec_pol, "prompt_tokens", 0)
                      + getattr(rec_pol, "completion_tokens", 0))
        coh_tokens = (getattr(cpol, "prompt_tokens", 0)
                      + getattr(cpol, "completion_tokens", 0))
        rep_tokens = self.replicator.tokens if self.replicator else 0
        pc_tokens = pc.tokens if pc is not None else 0
        retry_tokens = ep.retry_tokens if ep else 0
        tokens_trace = sum(tr.tokens for s in sessions for tr in s.traces)
        tokens_decision = (adm_tokens + rep_tokens + rec_tokens + coh_tokens
                           + pc_tokens + retry_tokens)
        return EpisodeMetrics(
            n_sessions=self.n_sessions,
            n_pods=self.n_pods,
            n_tasks=n_tasks,
            makespan_s=float(makespan),
            throughput_tasks_per_s=(n_tasks / makespan if makespan else 0.0),
            mean_task_latency_s=float(lat.mean()) if n_tasks else 0.0,
            p50_task_latency_s=(float(np.percentile(lat, 50))
                                if n_tasks else 0.0),
            p95_task_latency_s=(float(np.percentile(lat, 95))
                                if n_tasks else 0.0),
            total_stall_s=self.contention.total_stall_s,
            stall_per_task_s=(self.contention.total_stall_s / n_tasks
                              if n_tasks else 0.0),
            stalled_loads=self.contention.stalled_loads,
            total_loads=self.contention.total_loads,
            local_hit_rate=(rstats.local_hits / rstats.routed
                            if rstats.routed else 0.0),
            pod_load_imbalance=self.contention.load_imbalance(),
            cache_miss_replans=sum(tr.cache_miss_replans
                                   for s in sessions for tr in s.traces),
            prefetch_issued=rstats.prefetch_issued,
            prefetch_hits=sum(s.stats.prefetch_hits for s in sessions),
            prefetch_wait_s=sum(s.stats.prefetch_wait_s for s in sessions),
            overlap_credit_s=self.contention.overlap_credit_s,
            joined_loads=rstats.joined_in_flight,
            prefetch_skipped=sum(s.stats.prefetch_skipped for s in sessions),
            admitted=rstats.admitted,
            bypassed=rstats.bypassed,
            bypass_reads=rstats.bypass_reads,
            admission_agreement=getattr(self.admission_policy, "agreement",
                                        1.0),
            admission_tokens=adm_tokens,
            replica_hits=rstats.replica_hits,
            replica_installs=rstats.replica_installs,
            replica_drops=rstats.replica_drops,
            replication_epochs=(self.replicator.stats.epochs
                                if self.replicator else 0),
            replication_promotes=(self.replicator.stats.promotes
                                  if self.replicator else 0),
            replication_demotes=(self.replicator.stats.demotes
                                 if self.replicator else 0),
            replication_agreement=(self.replicator.agreement
                                   if self.replicator else 1.0),
            replication_tokens=rep_tokens,
            locality_local_reads=(self.locality.stats.local_reads
                                  if self.locality else 0),
            locality_remote_reads=(self.locality.stats.remote_reads
                                   if self.locality else 0),
            locality_remote_read_share=(self.locality.stats.remote_share
                                        if self.locality else 0.0),
            locality_remote_hop_s=(self.locality.stats.remote_hop_s
                                   if self.locality else 0.0),
            locality_link_stall_s=(self.locality.stats.link_stall_s
                                   if self.locality else 0.0),
            resilience_failovers=rstats.failovers,
            resilience_restores=fr.restores if fr else 0,
            resilience_scale_outs=rstats.scale_outs,
            resilience_scale_ins=rstats.scale_ins,
            resilience_aborted_loads=rstats.aborted_loads,
            resilience_retried_loads=rstats.retried_loads,
            resilience_timeout_loads=rstats.timeout_loads,
            resilience_retry_wait_s=sum(s.stats.retry_wait_s
                                        for s in sessions),
            resilience_lost_work_s=fr.lost_work_s if fr else 0.0,
            resilience_lost_keys=fr.lost_keys_n if fr else 0,
            resilience_lost_replicas=fr.lost_replicas_n if fr else 0,
            resilience_prefetch_aborted=fr.prefetch_aborted if fr else 0,
            resilience_recovery_s=recovery_s,
            resilience_unrecovered=unrecovered,
            resilience_failover_p95_s=fo_p95,
            resilience_steady_p95_s=steady_p95,
            resilience_incomplete_sessions=sum(
                1 for s in sessions if len(s.traces) < len(s.tasks)),
            recovery_rewarms=fr.rewarms if fr else 0,
            recovery_lazy=fr.lazy if fr else 0,
            recovery_agreement=getattr(rec_pol, "agreement", 1.0),
            recovery_tokens=rec_tokens,
            autoscale_actions=fr.autoscale_actions if fr else 0,
            autoscale_deferred=(self.autoscaler.deferred
                                if self.autoscaler else 0),
            p99_task_latency_s=(float(np.percentile(lat, 99))
                                if n_tasks else 0.0),
            traffic_spawned=ts.spawned if ts else 0,
            traffic_completed=ts.completed if ts else 0,
            traffic_in_system=ts.in_system if ts else 0,
            traffic_offered_rate=ts.offered_rate if ts else 0.0,
            traffic_measured_rate=(ts.measured_rate(float(makespan))
                                   if ts else 0.0),
            traffic_mean_sojourn_s=ts.mean_sojourn_s() if ts else 0.0,
            traffic_mean_in_system=(ts.mean_in_system(float(makespan))
                                    if ts else 0.0),
            traffic_little_residual=(ts.little_residual(float(makespan))
                                     if ts else 0.0),
            coherence_mutations=coh.stats.mutations if coh else 0,
            coherence_invalidations=coh.stats.invalidations if coh else 0,
            coherence_writethroughs=coh.stats.writethroughs if coh else 0,
            coherence_stale_reads=rstats.stale_reads,
            coherence_refresh_loads=rstats.refresh_loads,
            coherence_superseded_fills=rstats.superseded_fills,
            coherence_clamped=coh.stats.clamped if coh else 0,
            coherence_stale_share=coh.stats.stale_share() if coh else 0.0,
            coherence_max_staleness_s=(coh.stats.max_staleness_s
                                       if coh else 0.0),
            coherence_agreement=getattr(cpol, "agreement", 1.0),
            coherence_tokens=coh_tokens,
            llm_calls=ep.llm_calls if ep else 0,
            llm_retries=ep.retries if ep else 0,
            llm_hedges=ep.hedges if ep else 0,
            llm_hedge_wins=ep.hedge_wins if ep else 0,
            llm_rate_limited=ep.rate_limited if ep else 0,
            llm_malformed=ep.malformed if ep else 0,
            llm_parse_fallbacks=parse_fb,
            llm_degraded_decisions=ep.degraded if ep else 0,
            llm_fallback_share=ep.fallback_share if ep else 0.0,
            llm_retry_tokens=retry_tokens,
            llm_retry_wait_s=sum(s.runner.llm_retry_wait_s
                                 for s in sessions) if ep else 0.0,
            llm_breaker_opens=ep.breaker_opens if ep else 0,
            plancache_lookups=pcs.lookups if pcs else 0,
            plancache_hits=pcs.hits if pcs else 0,
            plancache_hit_rate=pcs.hit_rate if pcs else 0.0,
            plancache_installs=pcs.installs if pcs else 0,
            plancache_rejected=pcs.rejected if pcs else 0,
            plancache_evictions=pcs.evictions if pcs else 0,
            plancache_expired=pcs.expired if pcs else 0,
            plancache_invalidations=pcs.invalidations if pcs else 0,
            plancache_stale_served=pcs.stale_served if pcs else 0,
            plancache_agreement=pc.agreement if pc is not None else 1.0,
            plancache_tokens=pc_tokens,
            tokens_trace_total=tokens_trace,
            tokens_decision_total=tokens_decision,
            tokens_fleet_total=tokens_trace + tokens_decision,
        )


def run_episode(n_sessions: int, tasks_per_session: int = 25, *,
                n_pods: int = 4, reuse_rate: float = 0.8, seed: int = 0,
                **engine_kw) -> EpisodeResult:
    """One-call episode: build the engine, run it, return the result.
    Pass ``prefetch=True`` for the async-prefetch data plane."""
    eng = ConcurrentEpisodeEngine(n_sessions, n_pods=n_pods, seed=seed,
                                  **engine_kw)
    return eng.run(tasks_per_session, reuse_rate=reuse_rate)

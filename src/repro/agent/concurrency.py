"""Concurrent multi-session episode engine (discrete-event).

The paper's deployment is "an industry-scale massively parallel platform
spanning hundreds of GPT endpoints": many agent sessions run at once and
contend on the *shared* localized cache. This module models that regime:

* **N sessions**, each with its own logical :class:`SimClock`, its own
  seeded :class:`SimLLM`, and its own task stream (independent work);
* a **next-event scheduler** that always resumes the session with the
  smallest logical clock (ties broken by session id — fully deterministic);
* one shared :class:`PodLocalCacheRouter` + :class:`GeoDataStore`: a key's
  data is cached on exactly one pod, so sessions working on overlapping
  keys hit each other's cache fills — and queue behind each other's loads;
* **per-pod contention**: each pod serves remote DB loads FCFS in schedule
  order. A load that arrives while the pod is busy stalls until the pod
  frees up; the stall is charged to the session's clock and surfaced in
  the episode metrics (p50/p95 task latency, stall totals, per-pod load
  imbalance).

Granularity: sessions interleave at *task* boundaries (one task runs
atomically on its session clock; the scheduler then re-inserts the session
at its new time). Pod busy-windows persist across that interleaving, so a
session that starts a task "in the past" relative to a pod's busy-until
still queues — a conservative FCFS-in-schedule-order approximation that is
exact when task service times are small against task durations.

Single-session behavior is unchanged: ``n_sessions=1`` reproduces the same
answer/token traces as the plain :class:`repro.agent.runtime.Runtime` path
(contention can never fire with one session), and answer-quality aggregates
are independent of N because contention only shifts *time*.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.agent.agent import AgentRunner, TaskTrace
from repro.agent.backends import Profile, SimLLM
from repro.agent.geollm.datastore import GeoDataStore
from repro.agent.geollm.evaluator import Report, evaluate
from repro.agent.geollm.geotools import make_geo_tools
from repro.agent.geollm.simclock import LatencyModel, SimClock
from repro.agent.geollm.workload import Task, WorkloadSampler, compute_gold
from repro.core.controller import ReadPlan
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.tools import ToolRegistry, ToolSpec


# ---------------------------------------------------------------------------
# Contention: per-pod FCFS service of remote DB loads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PodLoadStats:
    loads: int = 0
    stalled_loads: int = 0
    stall_s: float = 0.0
    busy_until: float = 0.0


class PodContention:
    """FCFS queueing model over each pod's load bandwidth."""

    def __init__(self, pod_ids: Sequence[str]):
        self.pods: Dict[str, PodLoadStats] = {
            p: PodLoadStats() for p in pod_ids}

    def acquire(self, pod: str, now: float, service_s: float) -> float:
        """Serve one load; returns the total dwell (stall + service) to
        charge to the calling session's clock."""
        st = self.pods[pod]
        start = max(now, st.busy_until)
        stall = start - now
        st.busy_until = start + service_s
        st.loads += 1
        if stall > 0:
            st.stalled_loads += 1
            st.stall_s += stall
        return stall + service_s

    @property
    def total_stall_s(self) -> float:
        return sum(p.stall_s for p in self.pods.values())

    @property
    def stalled_loads(self) -> int:
        return sum(p.stalled_loads for p in self.pods.values())

    @property
    def total_loads(self) -> int:
        return sum(p.loads for p in self.pods.values())

    def load_imbalance(self) -> float:
        """max/mean loads across pods (1.0 = perfectly balanced)."""
        loads = [p.loads for p in self.pods.values()]
        mean = float(np.mean(loads)) if loads else 0.0
        return float(max(loads)) / mean if mean else 1.0


# ---------------------------------------------------------------------------
# Shared-cache controller + tools (the session-side data plane)
# ---------------------------------------------------------------------------

class SharedCacheController:
    """Read planner against the pod-sharded shared cache.

    Updates are programmatic and happen at load time (the router installs
    every loaded key into its owning pod), so ``update`` is a no-op — the
    multi-session analogue of Table III's programmatic update row. With
    ``decision_eps > 0`` read decisions flip with that probability,
    reproducing the GPT-driven read path's calibrated error rate (misses
    then surface as failed ``read_cache`` calls the agent re-plans around).
    """

    kind = "shared"

    def __init__(self, router: PodLocalCacheRouter, rng=None,
                 decision_eps: float = 0.0):
        self.router = router
        self.rng = rng
        self.decision_eps = decision_eps

    def _cached(self, key: str) -> bool:
        return key in self.router.pods[self.router.owner(key)]

    def plan_reads(self, query: str, required_keys: Sequence[str],
                   few_shot: bool = False) -> ReadPlan:
        choices = {}
        for k in required_keys:
            c = "read_cache" if self._cached(k) else "load_db"
            if (self.decision_eps and self.rng is not None
                    and self.rng.random() < self.decision_eps):
                c = "load_db" if c == "read_cache" else "read_cache"
            choices[k] = c
        return ReadPlan(choices)

    def update(self, loads: Sequence[str], loader: Callable[[str], Any],
               size_of: Callable[[Any], int]) -> None:
        return None


def make_shared_cache_tools(router: PodLocalCacheRouter, store: GeoDataStore,
                            contention: PodContention, clock: SimClock,
                            session_stats: "SessionStats") -> List[ToolSpec]:
    """Per-session ``read_cache`` / ``load_db`` bound to the shared router.

    ``read_cache`` hits the owning pod's local cache (fast, contention-free);
    ``load_db`` queues on the owning pod's load bandwidth, charges the stall
    plus DB service time to the session clock, and installs the frame into
    the pod cache (first fill wins — later sessions hit it).
    """

    # routed counts *successful* acquisitions (one per logical access), so
    # local_hits + remote_loads == routed even when an erroneous read
    # decision misses and the agent re-plans into load_db.
    def read_cache(key: str):
        pod = router.owner(key)
        value = router.pods[pod].get(key)    # raises KeyError on miss
        router.stats.routed += 1
        router.stats.local_hits += 1
        clock.advance(clock.latency.cache_read(value.size_mb))
        return value

    def load_db(key: str):
        pod = router.owner(key)
        frame = store.peek(key)
        store.loads += 1
        router.stats.routed += 1
        router.stats.remote_loads += 1
        service = clock.latency.db_load(frame.size_mb)
        dwell = contention.acquire(pod, clock.now(), service)
        stall = dwell - service
        if stall > 0:
            session_stats.stalled_loads += 1
            session_stats.stall_s += stall
        clock.advance(dwell)
        router.install(pod, key, frame, frame.size_bytes)
        return frame

    return [
        ToolSpec(
            name="read_cache",
            description=("Read imagery metadata for a `dataset-year` key "
                         "from the SHARED POD CACHE. Fast (pod-local). "
                         "Fails if the key is not currently cached."),
            parameters={"key": {"type": "string"}},
            fn=read_cache),
        ToolSpec(
            name="load_db",
            description=("Load imagery metadata for a `dataset-year` key "
                         "from the REMOTE DATABASE. Slow; queues on the "
                         "owning pod under concurrent load."),
            parameters={"key": {"type": "string"}},
            fn=load_db),
    ]


# ---------------------------------------------------------------------------
# Sessions + engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionStats:
    stalled_loads: int = 0
    stall_s: float = 0.0


@dataclasses.dataclass
class Session:
    sid: int
    clock: SimClock
    llm: SimLLM
    runner: AgentRunner
    tasks: List[Task]
    stats: SessionStats
    cursor: int = 0
    traces: List[TaskTrace] = dataclasses.field(default_factory=list)

    def next_task(self) -> Optional[Task]:
        if self.cursor >= len(self.tasks):
            return None
        t = self.tasks[self.cursor]
        self.cursor += 1
        return t


@dataclasses.dataclass
class EpisodeMetrics:
    n_sessions: int
    n_pods: int
    n_tasks: int
    makespan_s: float
    throughput_tasks_per_s: float
    mean_task_latency_s: float
    p50_task_latency_s: float
    p95_task_latency_s: float
    total_stall_s: float
    stall_per_task_s: float
    stalled_loads: int
    total_loads: int
    local_hit_rate: float
    pod_load_imbalance: float
    cache_miss_replans: int

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class EpisodeResult:
    metrics: EpisodeMetrics
    sessions: List[Session]
    router: PodLocalCacheRouter
    contention: PodContention

    def evaluate_answers(self) -> Report:
        """Answer-quality aggregate over every session's tasks/traces
        (independent of contention — time shifts, answers don't)."""
        tasks = [t for s in self.sessions for t in s.tasks]
        traces = [tr for s in self.sessions for tr in s.traces]
        return evaluate(tasks, traces)


def session_seed(seed: int, sid: int) -> int:
    """Per-session derived seed. Additive so a 1-session engine started at
    ``session_seed(seed, sid)`` replays exactly the workload/LLM stream of
    session ``sid`` of an N-session episode (the determinism tests rely on
    this). Answer traces replay bit-identically; *time and token* traces
    may differ because read plans depend on the shared cache state other
    sessions produce — that interaction is the scenario under test."""
    return seed + sid


class ConcurrentEpisodeEngine:
    """Discrete-event execution of N agent sessions over one shared,
    pod-sharded cache. See module docstring for the model."""

    def __init__(self, n_sessions: int, *, n_pods: int = 4,
                 capacity_per_pod: int = 5, model: str = "gpt-4-turbo",
                 prompting: str = "cot", few_shot: bool = True,
                 policy: str = "lru", llm_decisions: bool = True,
                 latency: Optional[LatencyModel] = None, seed: int = 0):
        assert n_sessions >= 1 and n_pods >= 1
        self.n_sessions = n_sessions
        self.n_pods = n_pods
        self.profile = Profile(model, prompting, few_shot)
        self.policy = policy
        self.llm_decisions = llm_decisions
        self.latency = latency or LatencyModel()
        self.seed = seed
        self.capacity_per_pod = capacity_per_pod

        # shared infrastructure: datastore + pod-sharded cache. Pod caches
        # use tick-order recency (no global wall clock exists across
        # session-local clocks; scheduler order IS the global event order).
        self.store = GeoDataStore(SimClock(self.latency))
        self.pod_ids = [f"pod{i}" for i in range(n_pods)]
        self.router = PodLocalCacheRouter(self.pod_ids,
                                          capacity_per_pod=capacity_per_pod,
                                          policy_name=policy)
        self.contention = PodContention(self.pod_ids)

    # -- session assembly ---------------------------------------------------
    def _make_session(self, sid: int, n_tasks: int,
                      reuse_rate: float) -> Session:
        sseed = session_seed(self.seed, sid)
        clock = SimClock(LatencyModel(**dataclasses.asdict(self.latency)))
        llm = SimLLM(self.profile, seed=sseed)
        stats = SessionStats()
        controller = SharedCacheController(
            self.router, rng=llm.rng,
            decision_eps=self.profile.cache_eps if self.llm_decisions else 0.0)
        registry = ToolRegistry(
            make_shared_cache_tools(self.router, self.store, self.contention,
                                    clock, stats)
            + make_geo_tools(clock))
        tasks = WorkloadSampler(reuse_rate, seed=sseed).sample(n_tasks)
        compute_gold(tasks, self.store)
        runner = AgentRunner(registry, controller, llm, clock, self.store,
                             use_cache=True)
        return Session(sid=sid, clock=clock, llm=llm, runner=runner,
                       tasks=tasks, stats=stats)

    # -- next-event loop ----------------------------------------------------
    def run(self, tasks_per_session: int = 25,
            reuse_rate: float = 0.8) -> EpisodeResult:
        sessions = [self._make_session(sid, tasks_per_session, reuse_rate)
                    for sid in range(self.n_sessions)]
        heap = [(0.0, s.sid) for s in sessions]
        heapq.heapify(heap)
        while heap:
            _, sid = heapq.heappop(heap)
            s = sessions[sid]
            task = s.next_task()
            if task is None:
                continue
            s.traces.append(s.runner.run_task(task))
            if s.cursor < len(s.tasks):
                heapq.heappush(heap, (s.clock.now(), sid))
        return EpisodeResult(metrics=self._metrics(sessions),
                             sessions=sessions, router=self.router,
                             contention=self.contention)

    def _metrics(self, sessions: List[Session]) -> EpisodeMetrics:
        lat = np.array([tr.time_s for s in sessions for tr in s.traces],
                       np.float64)
        n_tasks = int(lat.size)
        makespan = max((s.clock.now() for s in sessions), default=0.0)
        rstats = self.router.stats
        return EpisodeMetrics(
            n_sessions=self.n_sessions,
            n_pods=self.n_pods,
            n_tasks=n_tasks,
            makespan_s=float(makespan),
            throughput_tasks_per_s=(n_tasks / makespan if makespan else 0.0),
            mean_task_latency_s=float(lat.mean()) if n_tasks else 0.0,
            p50_task_latency_s=(float(np.percentile(lat, 50))
                                if n_tasks else 0.0),
            p95_task_latency_s=(float(np.percentile(lat, 95))
                                if n_tasks else 0.0),
            total_stall_s=self.contention.total_stall_s,
            stall_per_task_s=(self.contention.total_stall_s / n_tasks
                              if n_tasks else 0.0),
            stalled_loads=self.contention.stalled_loads,
            total_loads=self.contention.total_loads,
            local_hit_rate=(rstats.local_hits / rstats.routed
                            if rstats.routed else 0.0),
            pod_load_imbalance=self.contention.load_imbalance(),
            cache_miss_replans=sum(tr.cache_miss_replans
                                   for s in sessions for tr in s.traces),
        )


def run_episode(n_sessions: int, tasks_per_session: int = 25, *,
                n_pods: int = 4, reuse_rate: float = 0.8, seed: int = 0,
                **engine_kw) -> EpisodeResult:
    """One-call episode: build the engine, run it, return the result."""
    eng = ConcurrentEpisodeEngine(n_sessions, n_pods=n_pods, seed=seed,
                                  **engine_kw)
    return eng.run(tasks_per_session, reuse_rate=reuse_rate)

"""One-call assembly of the full LLM-dCache runtime: clock, datastore,
tools, cache, controller, agent — the harness every benchmark/example uses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.agent.agent import AgentRunner, TaskTrace
from repro.agent.backends import Profile, SimLLM
from repro.agent.geollm.datastore import GeoDataStore
from repro.agent.geollm.evaluator import Report, evaluate
from repro.agent.geollm.geotools import make_geo_tools
from repro.agent.geollm.simclock import SimClock
from repro.agent.geollm.workload import Task, compute_gold, make_benchmark
from repro.core.admission import FrequencySketch, make_admission
from repro.core.cache import DataCache
from repro.core.controller import make_controller
from repro.core.policies import make_policy
from repro.core.tools import ToolRegistry, make_admission_tool, \
    make_cache_tools


@dataclasses.dataclass
class Runtime:
    clock: SimClock
    store: GeoDataStore
    cache: DataCache
    registry: ToolRegistry
    runner: AgentRunner
    llm: SimLLM

    def run(self, tasks: List[Task]) -> List[TaskTrace]:
        return [self.runner.run_task(t) for t in tasks]

    def run_and_evaluate(self, tasks: List[Task]) -> Report:
        traces = self.run(tasks)
        return evaluate(tasks, traces, self.cache.stats)


def build_runtime(*, model: str = "gpt-4-turbo", prompting: str = "cot",
                  few_shot: bool = True, use_cache: bool = True,
                  policy: str = "lru", read_impl: str = "llm",
                  update_impl: str = "llm", capacity: int = 5,
                  seed: int = 0, llm=None, admission: Optional[str] = None,
                  admission_impl: str = "python") -> Runtime:
    """``admission`` (e.g. ``"tinylfu"``) adds the admission gate + shared
    frequency sketch to the cache controller; ``admission_impl="llm"``
    routes the decision through the GPT-driven prompt path. The default
    (``None``) is bit-identical to the pre-admission runtime — Tables I-III
    digests depend on it."""
    clock = SimClock()
    store = GeoDataStore(clock)
    cache = DataCache(capacity, clock=clock.now)
    sim = llm or SimLLM(Profile(model, prompting, few_shot), seed=seed)
    pol = make_policy(policy)
    if not use_cache:
        read_impl = update_impl = "python"
    sketch = adm = None
    if admission is not None:
        sketch = FrequencySketch(clock=clock.now)
        adm = make_admission(admission, impl=admission_impl, llm=sim,
                             few_shot=few_shot)
    controller = make_controller(cache, pol, llm=sim,
                                 read_impl=read_impl,
                                 update_impl=update_impl,
                                 few_shot=few_shot,
                                 admission=adm, sketch=sketch)
    tools = make_cache_tools(cache, store, clock) + make_geo_tools(clock)
    if adm is not None:
        tools.append(make_admission_tool(
            adm, sketch,
            entries_of=lambda key: cache.entries(),
            victim_of=lambda key, entries: pol.victim(entries),
            capacity_of=lambda key: cache.capacity))
    registry = ToolRegistry(tools)
    runner = AgentRunner(registry, controller, sim, clock, store,
                         use_cache=use_cache)
    return Runtime(clock=clock, store=store, cache=cache, registry=registry,
                   runner=runner, llm=sim)


def build_tasks(n: int, reuse_rate: float = 0.8, seed: int = 0,
                store: Optional[GeoDataStore] = None) -> List[Task]:
    if store is None:
        store = GeoDataStore(SimClock())
    return make_benchmark(n, reuse_rate, seed, store)

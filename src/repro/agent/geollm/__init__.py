from repro.agent.geollm.datastore import (  # noqa: F401
    CLASSES,
    DATASETS,
    GeoDataStore,
    GeoFrame,
    all_keys,
    synth_frame,
)
from repro.agent.geollm.evaluator import Report, evaluate, rouge_l  # noqa: F401
from repro.agent.geollm.simclock import LatencyModel, SimClock  # noqa: F401
from repro.agent.geollm.workload import (  # noqa: F401
    Task,
    WorkloadSampler,
    compute_gold,
    make_benchmark,
    model_check,
)

"""Benchmark sampler (paper §IV): multi-step geospatial tasks with a
parameterised data-reuse rate, plus the model-checker that verifies each
generated task's gold plan executes correctly.

The GeoLLM-Engine-1k set is not public; this re-implements its *sampler*:
1,000 multi-step prompts (~50k tool calls) whose probability of requiring
data already in the working set is the ``reuse_rate`` (0.8 for the main
benchmark; 0.0-0.8 for the Table II ablation), and a 500-query mini set.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Any, Dict, List, Optional

import numpy as np

from repro.agent.geollm.datastore import (
    CLASSES,
    REGIONS,
    GeoDataStore,
    GeoFrame,
    all_keys,
)
from repro.agent.geollm import geotools

WORKING_SET = 5   # matches the cache capacity (5 entries)


def mutation_hot_keys(k: int) -> List[str]:
    """The seed-independent mutation-hot key set (ISSUE 8): the first ``k``
    keys of the 0x5EED-shuffled key order — the same shuffle
    ``zipf_global`` / ``affinity_zipf`` use, so every session, every
    MutationPlan generator, and every benchmark cell agree on WHICH keys
    are being written without coordinating through seeds."""
    if k < 1:
        raise ValueError(f"mutation_hot_keys needs k >= 1, got {k}")
    order = list(all_keys())
    random.Random(0x5EED).shuffle(order)
    return order[:k]


@dataclasses.dataclass
class ToolCall:
    name: str
    args: Dict[str, Any]       # "$var" strings reference the env
    out: Optional[str] = None


@dataclasses.dataclass
class Step:
    kind: str                  # detect | lcc | vqa | plot | count | timeseries
    key: str
    prompt: str
    plan: List[ToolCall]
    gold: Any = None


@dataclasses.dataclass
class Task:
    tid: int
    query: str
    steps: List[Step]
    required_keys: List[str]

    @property
    def n_tool_calls(self) -> int:
        return sum(len(s.plan) for s in self.steps) + len(self.required_keys)


def _frame_var(key: str) -> str:
    return f"frame_{key.replace('-', '_')}"


def _mk_step(kind: str, key: str, rng: random.Random) -> Step:
    region = rng.choice(sorted(REGIONS))
    cls = rng.choice(CLASSES)
    fv = "$" + _frame_var(key)
    if kind == "detect":
        prompt = f"Detect {cls}s in the {key} imagery around {region}."
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("filter_clouds", {"frame": "$roi", "max_pct": 60}, "clear"),
            ToolCall("detect_objects", {"frame": "$clear", "class_name": cls},
                     "answer"),
            ToolCall("plot_images", {"frame": "$clear"}, "ui"),
        ]
    elif kind == "lcc":
        prompt = f"Classify the dominant land cover near {region} in {key}."
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("dominant_land_covers", {"frame": "$roi", "top_k": 2},
                     "answer"),
            ToolCall("plot_heatmap", {"frame": "$roi", "value": "land_cover"},
                     "ui"),
        ]
    elif kind == "vqa":
        q = f"What does the {region} area look like?"
        prompt = f"{q} (use {key})"
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("vqa_answer", {"frame": "$roi", "question": q}, "answer"),
        ]
    elif kind == "plot":
        prompt = f"Plot the {cls} scenes from {key} around {region}."
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("filter_class", {"frame": "$roi", "class_name": cls},
                     "sel"),
            ToolCall("plot_images", {"frame": "$sel"}, "answer"),
        ]
    elif kind == "count":
        m0, m1 = sorted(rng.sample(range(1, 13), 2))
        prompt = (f"How many {key} images around {region} were taken between "
                  f"months {m0} and {m1}?")
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("filter_date_range",
                     {"frame": "$roi", "start_month": m0, "end_month": m1},
                     "rng_sel"),
            ToolCall("count_images", {"frame": "$rng_sel"}, "answer"),
        ]
    else:  # timeseries
        prompt = f"Show the monthly acquisition counts for {key} at {region}."
        plan = [
            ToolCall("filter_bbox", {"frame": fv, "region": region}, "roi"),
            ToolCall("sort_by_time", {"frame": "$roi"}, "sorted"),
            ToolCall("timeseries", {"frame": "$sorted", "freq": "month"},
                     "answer"),
        ]
    return Step(kind=kind, key=key, prompt=prompt, plan=plan)


STEP_KINDS = ("detect", "lcc", "vqa", "plot", "count", "timeseries")


class WorkloadSampler:
    """Samples tasks under one of several key-popularity *scenarios*.

    The default ``"working"`` scenario is the paper's: keys repeat out of a
    sliding working set with probability ``reuse_rate`` (its RNG draw
    sequence is untouched by the scenario machinery — Table I-III digests
    depend on it). The additional scenarios stress the shared cache in
    qualitatively different ways (the admission benchmark sweeps them):

    * ``"zipf"`` — stationary skew: keys drawn from a Zipf(``zipf_a``)
      distribution over a seed-shuffled key order. High skew rewards
      keeping the few hot keys resident; the long tail is one-shot
      traffic that churns an admission-less cache.
    * ``"scan"`` — sequential sweep through the whole key space (the
      classic cache-adversarial pattern): every access is a compulsory
      miss, so *nothing* deserves admission once the cache warms.
    * ``"hotspot"`` — shifting phases: for ``phase_len`` key draws a hot
      set of ``hot_k`` keys serves ``hot_p`` of the traffic, then the hot
      set resamples. Tests how quickly admission+aging track drift.
    * ``"affinity_zipf"`` — per-pod hot sets with cross-pod spillover (the
      session->pod affinity regime, ISSUE 5): the key space is partitioned
      round-robin into ``n_groups`` groups over a seed-INDEPENDENT shuffle
      (every session agrees on the partition, like ``zipf_global``), each
      group carries its own Zipf(``zipf_a``) ranking, and a sampler bound
      to ``group`` g draws from its own group's ranking with probability
      ``1 - spill_p``, else from a uniformly chosen *other* group's. The
      concurrent engine binds ``group`` to the session's home pod, so each
      pod's sessions share a hot set — but rendezvous hashing owns those
      keys on arbitrary pods, which is exactly what makes consumer-side
      locality (and consumer-targeted replication) matter.
    * ``"update_heavy"`` — mutation-focused traffic (the mutable-data-plane
      regime, ISSUE 8): ``hot_p`` of the key draws land on the
      seed-independent :func:`mutation_hot_keys` set of size ``hot_k`` —
      the same keys a benchmark-level :class:`MutationPlan` keeps writing
      — so most reads race recent writes and the coherence policy is on
      the critical path.
    * ``"mixed_rw"`` — balanced read/write interleaving: key draws
      alternate deterministically between the mutation-hot set and the
      uniform key space (~50/50 regardless of ``hot_p``), the middle
      ground between ``update_heavy`` and pure-read scenarios.
    * ``"flash_fresh"`` — flash crowd on fresh data: a hot window of
      ``hot_k`` consecutive keys in the 0x5EED-shuffled order serves
      ``hot_p`` of the traffic and advances by one key every
      ``phase_len`` draws. Paired with a periodic ARRIVAL MutationPlan
      over the same order, the crowd keeps piling onto keys whose data
      just changed — worst case for serve-stale bounds.
    """

    def __init__(self, reuse_rate: float = 0.8, seed: int = 0,
                 scenario: str = "working", zipf_a: float = 1.2,
                 zipf_global: bool = False,
                 hot_k: int = 4, hot_p: float = 0.9, phase_len: int = 60,
                 n_groups: int = 4, group: int = 0, spill_p: float = 0.15,
                 repeat_p: float = 0.0, repeat_pool: int = 12):
        # fail-fast parameter validation (ISSUE 7): a bad rate/probability
        # here silently skews every downstream table — reject loudly
        if not 0.0 <= reuse_rate <= 1.0:
            raise ValueError(f"reuse_rate must be in [0, 1], "
                             f"got {reuse_rate}")
        if scenario not in ("working", "zipf", "scan", "hotspot",
                            "affinity_zipf", "update_heavy", "mixed_rw",
                            "flash_fresh"):
            raise ValueError(f"unknown scenario {scenario!r}")
        if zipf_a <= 0.0:
            raise ValueError(f"zipf_a must be > 0, got {zipf_a}")
        if not 0.0 <= hot_p <= 1.0:
            raise ValueError(f"hot_p must be in [0, 1], got {hot_p}")
        if not 0.0 <= spill_p <= 1.0:
            raise ValueError(f"spill_p must be in [0, 1], got {spill_p}")
        if hot_k < 1 or phase_len < 1:
            raise ValueError(f"hot_k/phase_len must be >= 1, "
                             f"got ({hot_k}, {phase_len})")
        self.reuse_rate = reuse_rate
        self.rng = random.Random(seed)
        self.keys = all_keys()
        self.working: List[str] = []
        self.scenario = scenario
        if scenario == "zipf":
            # seed-shuffled rank order (drawn from a separate RNG so the
            # "working" draw stream stays byte-identical to pre-scenario
            # code); cumulative weights for rng.choices' internal bisect.
            # ``zipf_global=True`` fixes the rank order across ALL sessions
            # (seed-independent shuffle): every session then agrees on
            # which keys are hot — the paper's many-endpoints-one-event
            # regime, and the workload where cross-pod replication of
            # super-hot keys has real signal. The default (per-session
            # order) keeps each session's skew private, so the *global*
            # popularity field stays nearly flat even at high zipf_a.
            order = list(self.keys)
            random.Random(0x5EED if zipf_global else seed ^ 0x5EED
                          ).shuffle(order)
            self._zipf_keys = order
            w = [1.0 / (r + 1) ** zipf_a for r in range(len(order))]
            self._zipf_cum = list(itertools.accumulate(w))
        if scenario == "affinity_zipf":
            # seed-independent partition (all sessions agree on the groups)
            order = list(self.keys)
            random.Random(0x5EED).shuffle(order)
            g = max(1, int(n_groups))
            self._aff_groups = [order[i::g] for i in range(g)]
            self._aff_cums = [
                list(itertools.accumulate(1.0 / (r + 1) ** zipf_a
                                          for r in range(len(grp))))
                for grp in self._aff_groups]
            self._aff_group = int(group) % g
            self._aff_spill = spill_p
        if scenario in ("update_heavy", "mixed_rw", "flash_fresh"):
            # seed-independent shuffle (separate RNG: the "working" draw
            # stream stays byte-identical): every session AND every
            # MutationPlan built from mutation_hot_keys() agree on which
            # keys are write-hot.
            order = list(self.keys)
            random.Random(0x5EED).shuffle(order)
            self._mut_order = order
        self._scan_pos = 0
        self.hot_k, self.hot_p, self.phase_len = hot_k, hot_p, phase_len
        self._hot: List[str] = []
        self._draws = 0
        # request-level task repeats (ISSUE 10): with probability
        # ``repeat_p`` a task draw returns a fresh-tid copy of one of
        # ``repeat_pool`` canned tasks — the "users keep asking the same
        # question" pattern that makes a plan cache worth having. The
        # library is seed-INDEPENDENT (like zipf_global / the mutation-hot
        # order), so every session of an episode samples the same canned
        # tasks and repeats collide ACROSS sessions; its keys skew to the
        # head of the shared 0x5EED shuffle so repeated tasks also share
        # data. ``repeat_p == 0`` (the default) skips the gate draw
        # entirely — every pre-existing scenario's RNG stream, and every
        # digest built on it, is byte-identical.
        if not 0.0 <= repeat_p <= 1.0:
            raise ValueError(f"repeat_p must be in [0, 1], got {repeat_p}")
        if repeat_pool < 1:
            raise ValueError(f"repeat_pool must be >= 1, got {repeat_pool}")
        self.repeat_p = repeat_p
        self._library: List[Task] = []
        if repeat_p > 0.0:
            lib_rng = random.Random(0x9A17)
            order = list(self.keys)
            random.Random(0x5EED).shuffle(order)
            head = order[:max(2 * WORKING_SET, hot_k)]
            for i in range(repeat_pool):
                steps, keys = [], []
                for _ in range(lib_rng.randint(3, 5)):
                    kind = lib_rng.choice(STEP_KINDS)
                    key = lib_rng.choice(head)
                    steps.append(_mk_step(kind, key, lib_rng))
                    if key not in keys:
                        keys.append(key)
                self._library.append(Task(
                    tid=-1 - i, query=" Then, ".join(s.prompt for s in steps),
                    steps=steps, required_keys=keys))

    def _sample_key(self) -> str:
        if self.scenario == "zipf":
            return self.rng.choices(self._zipf_keys,
                                    cum_weights=self._zipf_cum)[0]
        if self.scenario == "affinity_zipf":
            gi = self._aff_group
            n = len(self._aff_groups)
            if n > 1 and self.rng.random() < self._aff_spill:
                gi = self.rng.randrange(n - 1)    # spill: another group's
                if gi >= self._aff_group:         # hot set, uniformly
                    gi += 1
            return self.rng.choices(self._aff_groups[gi],
                                    cum_weights=self._aff_cums[gi])[0]
        if self.scenario == "scan":
            key = self.keys[self._scan_pos % len(self.keys)]
            self._scan_pos += 1
            return key
        if self.scenario == "update_heavy":
            if self.rng.random() < self.hot_p:
                return self.rng.choice(self._mut_order[:self.hot_k])
            return self.rng.choice(self.keys)
        if self.scenario == "mixed_rw":
            self._draws += 1
            if self._draws % 2:       # deterministic ~50/50 interleave
                return self.rng.choice(self._mut_order[:self.hot_k])
            return self.rng.choice(self.keys)
        if self.scenario == "flash_fresh":
            w = self._draws // self.phase_len   # window advances per phase
            self._draws += 1
            if self.rng.random() < self.hot_p:
                n = len(self._mut_order)
                win = [self._mut_order[(w + i) % n]
                       for i in range(self.hot_k)]
                return self.rng.choice(win)
            return self.rng.choice(self.keys)
        if self.scenario == "hotspot":
            if self._draws % self.phase_len == 0:
                self._hot = self.rng.sample(self.keys, self.hot_k)
            self._draws += 1
            if self.rng.random() < self.hot_p:
                return self.rng.choice(self._hot)
            return self.rng.choice(self.keys)
        # "working" (default; draw sequence is digest-locked)
        if self.working and self.rng.random() < self.reuse_rate:
            return self.rng.choice(self.working)
        key = self.rng.choice(self.keys)
        self.working.append(key)
        if len(self.working) > WORKING_SET:
            self.working.pop(0)
        return key

    def sample_task(self, tid: int) -> Task:
        if self.repeat_p and self.rng.random() < self.repeat_p:
            lib = self.rng.choice(self._library)
            # fresh-tid copy with per-copy Step objects: compute_gold fills
            # gold per copy, and shared immutable plans/prompts are safe
            return Task(tid=tid, query=lib.query,
                        steps=[Step(kind=s.kind, key=s.key, prompt=s.prompt,
                                    plan=s.plan) for s in lib.steps],
                        required_keys=list(lib.required_keys))
        n_steps = self.rng.randint(3, 5)
        steps, keys = [], []
        for _ in range(n_steps):
            kind = self.rng.choice(STEP_KINDS)
            key = self._sample_key()
            steps.append(_mk_step(kind, key, self.rng))
            if key not in keys:
                keys.append(key)
        query = " Then, ".join(s.prompt for s in steps)
        return Task(tid=tid, query=query, steps=steps, required_keys=keys)

    def sample(self, n: int) -> List[Task]:
        return [self.sample_task(i) for i in range(n)]


# ---------------------------------------------------------------------------
# Gold execution + model checker
# ---------------------------------------------------------------------------

def execute_plan(step: Step, env: Dict[str, Any]) -> Any:
    """Run a step's gold plan against an env of frame variables."""
    fns = {n: getattr(geotools, n) for n in (
        "filter_bbox", "filter_class", "filter_clouds", "filter_date_range",
        "count_images", "detect_objects", "land_cover_stats",
        "dominant_land_covers", "vqa_answer", "image_stats", "sample_images",
        "sort_by_time", "merge_frames", "plot_images", "plot_heatmap",
        "timeseries")}
    local = dict(env)
    answer = None
    for call in step.plan:
        args = {k: (local[v[1:]] if isinstance(v, str) and v.startswith("$")
                    else v) for k, v in call.args.items()}
        out = fns[call.name](**args)
        if call.out:
            local[call.out] = out
        if call.out == "answer":
            answer = out
    return answer


def compute_gold(tasks: List[Task], store: GeoDataStore) -> None:
    """Fill ``step.gold`` (latency-free peek — the checker's oracle)."""
    for t in tasks:
        env = {_frame_var(k): store.peek(k) for k in t.required_keys}
        for s in t.steps:
            s.gold = execute_plan(s, env)


def answers_equal(a: Any, b: Any) -> bool:
    """Structural equality over the answer value domain (dicts, sequences,
    scalars, numpy arrays, GeoFrames). Unlike ``repr`` comparison, numpy's
    print truncation cannot mask a real mismatch in a large array."""
    if a is b:
        return True
    if isinstance(a, GeoFrame) or isinstance(b, GeoFrame):
        if not (isinstance(a, GeoFrame) and isinstance(b, GeoFrame)):
            return False
        return (a.key == b.key and len(a) == len(b)
                and all(np.array_equal(getattr(a, c), getattr(b, c))
                        for c in ("filename", "lon", "lat", "timestamp",
                                  "class_id", "det_count", "land_cover",
                                  "cloud_pct")))
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (np.asarray(a).shape == np.asarray(b).shape
                and np.array_equal(a, b))
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(answers_equal(v, b[k]) for k, v in a.items()))
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (len(a) == len(b)
                and all(answers_equal(x, y) for x, y in zip(a, b)))
    if isinstance(a, bool) or isinstance(b, bool):
        return a == b and isinstance(a, bool) == isinstance(b, bool)
    if isinstance(a, (int, float, np.integer, np.floating)) and \
            isinstance(b, (int, float, np.integer, np.floating)):
        return float(a) == float(b)
    return type(a) is type(b) and a == b


def model_check(tasks: List[Task], store: GeoDataStore) -> List[int]:
    """Paper §IV: 'use the model-checker module to verify the functional
    correctness of the generated tasks'. Returns ids of BROKEN tasks.

    Only the expected failure modes of a malformed task mark it broken:
    ``KeyError`` (a required key the store does not carry, an unresolved
    ``$var`` reference) and ``ValueError`` (a tool rejecting bad arguments,
    the gold mismatch below). Anything else — a ``TypeError`` from a buggy
    tool, an ``AttributeError`` from a bad frame object — is a programming
    error in the checker's own dependencies and must propagate, not be
    silently laundered into "task is broken"."""
    bad = []
    for t in tasks:
        try:
            env = {_frame_var(k): store.peek(k) for k in t.required_keys}
            for s in t.steps:
                a = execute_plan(s, env)
                if a is None or (s.gold is not None and
                                 not answers_equal(a, s.gold)):
                    raise ValueError(f"step gold mismatch in task {t.tid}")
        except (ValueError, KeyError):
            bad.append(t.tid)
    return bad


def make_benchmark(n_tasks: int = 1000, reuse_rate: float = 0.8,
                   seed: int = 0, store: Optional[GeoDataStore] = None,
                   ) -> List[Task]:
    tasks = WorkloadSampler(reuse_rate, seed).sample(n_tasks)
    if store is not None:
        compute_gold(tasks, store)
        assert not model_check(tasks, store)
    return tasks

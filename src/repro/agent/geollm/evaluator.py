"""Agent-metric evaluation (paper §IV "Metrics").

Success Rate, Correctness Ratio, object-detection F1, LCC recall, VQA
ROUGE-L, average tokens/time per task — the Table I columns — plus cache
statistics (hit rate and GPT-hit rate for Table III).

Latency aggregation follows [20] as the paper does: running average per
task with outliers beyond 2 sigma discarded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.agent.geollm.workload import Task


def rouge_l(pred: str, gold: str) -> float:
    a, b = (pred or "").split(), (gold or "").split()
    if not a or not b:
        return 0.0
    dp = np.zeros((len(a) + 1, len(b) + 1), np.int32)
    for i in range(len(a)):
        for j in range(len(b)):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if a[i] == b[j]
                                else max(dp[i, j + 1], dp[i + 1, j]))
    lcs = int(dp[len(a), len(b)])
    prec, rec = lcs / len(a), lcs / len(b)
    return 0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec)


def _det_f1(pred: Optional[Dict], gold: Dict) -> float:
    if not isinstance(pred, dict) or "detections" not in pred:
        return 0.0
    tp = min(pred["detections"], gold["detections"])
    fp = pred["detections"] - tp
    fn = gold["detections"] - tp
    denom = 2 * tp + fp + fn
    return (2 * tp / denom) if denom else 1.0


def _lcc_recall(pred: Optional[List[str]], gold: List[str]) -> float:
    if not isinstance(pred, list) or not gold:
        return 0.0
    return len(set(pred) & set(gold)) / len(set(gold))


@dataclasses.dataclass
class Report:
    n_tasks: int
    success_rate: float
    correctness: float
    obj_det_f1: float
    lcc_recall: float
    vqa_rouge: float
    avg_tokens: float
    avg_time_s: float
    total_tool_calls: int
    cache_hit_rate: float = 0.0
    gpt_hit_rate: float = 1.0

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def trimmed_mean(xs: List[float]) -> float:
    """Running-average policy of [20]: drop outliers beyond 2 sigma."""
    a = np.asarray(xs, np.float64)
    if len(a) < 4:
        return float(a.mean()) if len(a) else 0.0
    m, s = a.mean(), a.std()
    keep = np.abs(a - m) <= 2 * s
    return float(a[keep].mean())


def evaluate(tasks: List[Task], traces: List,
             cache_stats=None) -> Report:
    assert len(tasks) == len(traces)
    succ, f1s, lccs, rouges = [], [], [], []
    good_calls = bad_calls = 0
    for task, tr in zip(tasks, traces):
        succ.append(tr.success and all(
            tr.answers.get(i) is not None for i in range(len(task.steps))))
        good_calls += tr.tool_calls - tr.bad_calls
        bad_calls += tr.bad_calls
        for i, step in enumerate(task.steps):
            pred = tr.answers.get(i)
            if step.kind == "detect":
                f1s.append(_det_f1(pred, step.gold))
            elif step.kind == "lcc":
                lccs.append(_lcc_recall(pred, step.gold))
            elif step.kind == "vqa":
                rouges.append(rouge_l(pred if isinstance(pred, str) else "",
                                      step.gold))
    total_calls = good_calls + bad_calls
    rep = Report(
        n_tasks=len(tasks),
        success_rate=float(np.mean(succ)) if succ else 0.0,
        correctness=good_calls / total_calls if total_calls else 0.0,
        obj_det_f1=float(np.mean(f1s)) if f1s else 0.0,
        lcc_recall=float(np.mean(lccs)) if lccs else 0.0,
        vqa_rouge=float(np.mean(rouges)) if rouges else 0.0,
        avg_tokens=float(np.mean([t.tokens for t in traces])),
        avg_time_s=trimmed_mean([t.time_s for t in traces]),
        total_tool_calls=total_calls,
    )
    if cache_stats is not None:
        rep.cache_hit_rate = cache_stats.hit_rate
        rep.gpt_hit_rate = cache_stats.gpt_hit_rate
    return rep

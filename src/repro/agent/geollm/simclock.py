"""Deterministic latency accounting + discrete-event queue helpers.

The paper measures wall-clock on an Azure deployment with hundreds of GPT
endpoints. Offline we account *modeled* latency on a deterministic clock so
every benchmark is exactly reproducible; constants are calibrated so that
absolute per-task times land in the paper's 5-7 s range and the cache-vs-DB
ratio is in the paper's 5-10x band (DESIGN §9).

:class:`EventQueue` is the scheduling primitive behind the event-granular
concurrent engine (``repro.agent.concurrency``): a time-ordered heap with a
deterministic total order — (time, priority, tiebreak) — so simulations are
bit-reproducible regardless of heap internals. See docs/architecture.md for
the determinism contract.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Iterator, List, Optional, Tuple


@dataclasses.dataclass
class LatencyModel:
    # LLM endpoint
    llm_round_base_s: float = 0.20        # request overhead
    llm_prefill_s_per_tok: float = 2.0e-5
    llm_decode_s_per_tok: float = 6.5e-3
    # data plane
    db_load_base_s: float = 0.62          # remote DB / blob storage
    db_load_s_per_mb: float = 0.003
    cache_read_base_s: float = 0.10       # local (the 5-10x faster path)
    cache_read_s_per_mb: float = 0.0002
    # generic tool execution
    tool_op_s: float = 0.03

    def llm_round(self, prompt_tokens: int, completion_tokens: int) -> float:
        return (self.llm_round_base_s
                + prompt_tokens * self.llm_prefill_s_per_tok
                + completion_tokens * self.llm_decode_s_per_tok)

    def db_load(self, size_mb: float) -> float:
        return self.db_load_base_s + size_mb * self.db_load_s_per_mb

    def cache_read(self, size_mb: float) -> float:
        return self.cache_read_base_s + size_mb * self.cache_read_s_per_mb


class SimClock:
    """Monotonic simulated clock; tools/LLM calls advance it."""

    def __init__(self, latency: LatencyModel | None = None):
        self._t = 0.0
        self.latency = latency or LatencyModel()

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        assert seconds >= 0.0, seconds
        self._t += seconds
        return self._t

    def advance_to(self, t: float) -> float:
        """Advance to absolute time ``t`` (no-op if already past it)."""
        if t > self._t:
            self._t = t
        return self._t


# ---------------------------------------------------------------------------
# Discrete-event queue
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled event (the *wrapper* view; the heap stores plain
    tuples — see :class:`EventQueue`).

    Ordering is (time, priority, tiebreak): lower priority values run first
    at equal times (e.g. pod-load completions *before* session resumes, so a
    session resuming exactly at a load's completion time observes the key
    already installed), and ``tiebreak`` (session id, or an insertion
    sequence number) makes the order total and deterministic.
    """
    time: float
    priority: int
    tiebreak: int
    payload: Any = None

    def sort_key(self) -> Tuple[float, int, int]:
        return (self.time, self.priority, self.tiebreak)


class EventQueue:
    """Deterministic time-ordered event heap for discrete-event simulation.

    ``push``/``pop`` are O(log n); the pop order is the total order
    (time, priority, tiebreak, insertion-seq), never heap insertion order,
    so simulations driven off this queue are bit-reproducible.

    The heap holds plain ``(time, priority, tiebreak, seq, payload)``
    tuples — no per-event object allocation on the hot path (the concurrent
    engine pushes/pops one event per clock advance). ``pop``/``peek``/
    ``drain`` wrap the tuple in an :class:`Event` for callers that want the
    named view; :meth:`pop_payload` is the allocation-free fast path the
    scheduler uses. The unique ``seq`` component also guarantees the tuple
    comparison never reaches ``payload`` (which may be unorderable).
    """

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, int, Any]] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def push(self, time: float, priority: int = 0,
             tiebreak: Optional[int] = None, payload: Any = None) -> None:
        seq = self._seq
        self._seq = seq + 1
        heapq.heappush(self._heap,
                       (time, priority, seq if tiebreak is None else tiebreak,
                        seq, payload))

    def pop(self) -> Event:
        t, pri, tb, _, payload = heapq.heappop(self._heap)
        return Event(t, pri, tb, payload)

    def pop_payload(self) -> Any:
        """Pop the next event, returning only its payload (the scheduler's
        fast path — same total order as :meth:`pop`)."""
        return heapq.heappop(self._heap)[4]

    def pop_timed(self) -> Tuple[float, Any]:
        """Pop the next event as ``(time, payload)`` — the scheduler's fast
        path when it also drives time-epoch work (e.g. the replicator)."""
        item = heapq.heappop(self._heap)
        return item[0], item[4]

    def peek(self) -> Event:
        t, pri, tb, _, payload = self._heap[0]
        return Event(t, pri, tb, payload)

    def drain(self) -> Iterator[Event]:
        """Pop events in order until the queue is empty (events pushed
        while draining are sequenced into the same order)."""
        while self._heap:
            yield self.pop()

"""Deterministic latency accounting.

The paper measures wall-clock on an Azure deployment with hundreds of GPT
endpoints. Offline we account *modeled* latency on a deterministic clock so
every benchmark is exactly reproducible; constants are calibrated so that
absolute per-task times land in the paper's 5-7 s range and the cache-vs-DB
ratio is in the paper's 5-10x band (DESIGN §9).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class LatencyModel:
    # LLM endpoint
    llm_round_base_s: float = 0.20        # request overhead
    llm_prefill_s_per_tok: float = 2.0e-5
    llm_decode_s_per_tok: float = 6.5e-3
    # data plane
    db_load_base_s: float = 0.62          # remote DB / blob storage
    db_load_s_per_mb: float = 0.003
    cache_read_base_s: float = 0.10       # local (the 5-10x faster path)
    cache_read_s_per_mb: float = 0.0002
    # generic tool execution
    tool_op_s: float = 0.03

    def llm_round(self, prompt_tokens: int, completion_tokens: int) -> float:
        return (self.llm_round_base_s
                + prompt_tokens * self.llm_prefill_s_per_tok
                + completion_tokens * self.llm_decode_s_per_tok)

    def db_load(self, size_mb: float) -> float:
        return self.db_load_base_s + size_mb * self.db_load_s_per_mb

    def cache_read(self, size_mb: float) -> float:
        return self.cache_read_base_s + size_mb * self.cache_read_s_per_mb


class SimClock:
    """Monotonic simulated clock; tools/LLM calls advance it."""

    def __init__(self, latency: LatencyModel | None = None):
        self._t = 0.0
        self.latency = latency or LatencyModel()

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        assert seconds >= 0.0, seconds
        self._t += seconds
        return self._t

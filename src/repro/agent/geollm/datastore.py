"""Synthetic geospatial catalog: the "main memory" of the paper.

``GeoFrame`` is a small columnar frame (numpy-backed; GeoPandas is not
available offline — DESIGN §9) holding per-image metadata: filenames,
coordinates, detections, timestamps. ``GeoDataStore`` lazily materialises a
deterministic frame per ``dataset-year`` key (~15k rows each across 8
datasets x 9 years ~= 1.1M images, matching GeoLLM-Engine's catalog scale)
and charges DB-load latency to the SimClock; cache reads are 5-10x cheaper.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional

import numpy as np

DATASETS = ("xview1", "fair1m", "dota", "spacenet", "landsat",
            "sentinel2", "naip", "modis")
YEARS = tuple(range(2015, 2024))
CLASSES = ("airplane", "ship", "vehicle", "building", "storage_tank",
           "harbor", "bridge", "helicopter")
LAND_COVERS = ("urban", "forest", "water", "cropland", "barren", "wetland")
REGIONS = {
    "newport beach": (-117.95, 33.57, -117.85, 33.65),
    "san francisco": (-122.52, 37.70, -122.35, 37.83),
    "houston": (-95.55, 29.60, -95.20, 29.90),
    "miami": (-80.35, 25.70, -80.10, 25.90),
    "seattle": (-122.45, 47.50, -122.20, 47.70),
    "denver": (-105.10, 39.60, -104.80, 39.85),
}


def all_keys() -> List[str]:
    return [f"{d}-{y}" for d in DATASETS for y in YEARS]


@dataclasses.dataclass
class GeoFrame:
    """Columnar per-image metadata for one dataset-year."""
    key: str
    filename: np.ndarray      # (N,) str
    lon: np.ndarray           # (N,) float32
    lat: np.ndarray           # (N,) float32
    timestamp: np.ndarray     # (N,) int64 (unix s)
    class_id: np.ndarray      # (N,) int8  (dominant detection class)
    det_count: np.ndarray     # (N,) int16 (objects of that class)
    land_cover: np.ndarray    # (N,) int8
    cloud_pct: np.ndarray     # (N,) float32

    def __len__(self) -> int:
        return len(self.lon)

    @property
    def size_bytes(self) -> int:
        # model the paper's 50-100 MB per yearly frame
        return int(len(self) * 5200)

    @property
    def size_mb(self) -> float:
        return self.size_bytes / 1e6

    def filter_bbox(self, bbox) -> "GeoFrame":
        x0, y0, x1, y1 = bbox
        m = (self.lon >= x0) & (self.lon <= x1) & \
            (self.lat >= y0) & (self.lat <= y1)
        return self._mask(m)

    def filter_class(self, class_name: str) -> "GeoFrame":
        m = self.class_id == CLASSES.index(class_name)
        return self._mask(m)

    def filter_clouds(self, max_pct: float) -> "GeoFrame":
        return self._mask(self.cloud_pct <= max_pct)

    def _mask(self, m: np.ndarray) -> "GeoFrame":
        return GeoFrame(self.key, self.filename[m], self.lon[m], self.lat[m],
                        self.timestamp[m], self.class_id[m],
                        self.det_count[m], self.land_cover[m],
                        self.cloud_pct[m])


def _seed_for(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(),
                                          digest_size=4).digest(), "big")


def synth_frame(key: str) -> GeoFrame:
    rng = np.random.default_rng(_seed_for(key))
    dataset, year = key.rsplit("-", 1)
    n = int(rng.integers(12_000, 18_000))
    # spatially skewed around regions of interest (the paper's observation)
    centers = np.array([[(b[0] + b[2]) / 2, (b[1] + b[3]) / 2]
                        for b in REGIONS.values()])
    which = rng.integers(0, len(centers), n)
    lon = (centers[which, 0] + rng.normal(0, 0.15, n)).astype(np.float32)
    lat = (centers[which, 1] + rng.normal(0, 0.12, n)).astype(np.float32)
    t0 = np.datetime64(f"{year}-01-01").astype("datetime64[s]").astype(np.int64)
    ts = t0 + rng.integers(0, 365 * 24 * 3600, n)
    return GeoFrame(
        key=key,
        filename=np.array([f"{dataset}_{year}_{i:06d}.tif" for i in range(n)]),
        lon=lon, lat=lat, timestamp=ts,
        class_id=rng.integers(0, len(CLASSES), n).astype(np.int8),
        det_count=rng.integers(0, 40, n).astype(np.int16),
        land_cover=rng.integers(0, len(LAND_COVERS), n).astype(np.int8),
        cloud_pct=rng.uniform(0, 100, n).astype(np.float32),
    )


class GeoDataStore:
    """Main memory. ``load`` charges DB latency; frames are memoised host-side
    (the memo is the *data platform's* store, not the LLM-visible cache)."""

    def __init__(self, clock):
        self.clock = clock
        self._frames: Dict[str, GeoFrame] = {}
        self.loads = 0

    def _frame(self, key: str) -> GeoFrame:
        if key not in self._frames:
            if key not in set(all_keys()):
                raise KeyError(f"unknown dataset-year {key!r}")
            self._frames[key] = synth_frame(key)
        return self._frames[key]

    def load(self, key: str) -> GeoFrame:
        f = self._frame(key)
        self.loads += 1
        self.clock.advance(self.clock.latency.db_load(f.size_mb))
        return f

    def peek(self, key: str) -> GeoFrame:
        """Latency-free access for gold-answer computation only."""
        return self._frame(key)

    def cache_read_latency(self, key: str) -> float:
        return self.clock.latency.cache_read(self._frame(key).size_mb)

"""Synthetic geospatial catalog: the "main memory" of the paper.

``GeoFrame`` is a small columnar frame (numpy-backed; GeoPandas is not
available offline — DESIGN §9) holding per-image metadata: filenames,
coordinates, detections, timestamps. ``GeoDataStore`` lazily materialises a
deterministic frame per ``dataset-year`` key (~15k rows each across 8
datasets x 9 years ~= 1.1M images, matching GeoLLM-Engine's catalog scale)
and charges DB-load latency to the SimClock; cache reads are 5-10x cheaper.

Performance model (this file is the data plane's hot path):

* Filters return **lazy index views**: a view shares the parent's base
  column arrays and holds only an int index into them. Columns gather on
  first access (and memoise per view), so a ``detect`` step that touches
  two of the nine columns never pays for the other seven. Values are
  bit-identical to the old copy-all-columns implementation.
* ``filter_bbox`` results are memoised per (frame, bbox). Root frames are
  shared process-wide (see below), so the datastore effectively memoises
  per (key, region) — the workload's universally-first filter.
* Root frames are immutable and deterministic, so ``synth_frame`` keeps a
  process-wide memo shared by every ``GeoDataStore`` (benchmark cells stop
  re-synthesising the same 72 frames per cell).
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, List, Optional

import numpy as np

DATASETS = ("xview1", "fair1m", "dota", "spacenet", "landsat",
            "sentinel2", "naip", "modis")
YEARS = tuple(range(2015, 2024))
CLASSES = ("airplane", "ship", "vehicle", "building", "storage_tank",
           "harbor", "bridge", "helicopter")
LAND_COVERS = ("urban", "forest", "water", "cropland", "barren", "wetland")
REGIONS = {
    "newport beach": (-117.95, 33.57, -117.85, 33.65),
    "san francisco": (-122.52, 37.70, -122.35, 37.83),
    "houston": (-95.55, 29.60, -95.20, 29.90),
    "miami": (-80.35, 25.70, -80.10, 25.90),
    "seattle": (-122.45, 47.50, -122.20, 47.70),
    "denver": (-105.10, 39.60, -104.80, 39.85),
}

COLUMNS = ("filename", "lon", "lat", "timestamp", "class_id", "det_count",
           "land_cover", "cloud_pct")


def all_keys() -> List[str]:
    return [f"{d}-{y}" for d in DATASETS for y in YEARS]


_ALL_KEYS = frozenset(all_keys())


class GeoFrame:
    """Columnar per-image metadata for one dataset-year.

    Construct with full column arrays (a *root* frame). Filters and sorts
    return index views over the root's columns; views are immutable and may
    be shared between callers (the bbox memo relies on this).
    """

    __slots__ = ("key", "_base", "_index", "_cols", "_bbox_memo", "_op_memo")

    def __init__(self, key: str, filename: np.ndarray, lon: np.ndarray,
                 lat: np.ndarray, timestamp: np.ndarray,
                 class_id: np.ndarray, det_count: np.ndarray,
                 land_cover: np.ndarray, cloud_pct: np.ndarray):
        self.key = key
        self._base = {"filename": filename, "lon": lon, "lat": lat,
                      "timestamp": timestamp, "class_id": class_id,
                      "det_count": det_count, "land_cover": land_cover,
                      "cloud_pct": cloud_pct}
        self._index: Optional[np.ndarray] = None   # None -> root frame
        self._cols: Dict[str, np.ndarray] = {}
        self._bbox_memo: Dict[tuple, "GeoFrame"] = {}
        self._op_memo: Dict[tuple, object] = {}

    # -- lazy columns --------------------------------------------------------
    def _col(self, name: str) -> np.ndarray:
        if self._index is None:
            return self._base[name]
        c = self._cols.get(name)
        if c is None:
            c = self._base[name][self._index]
            self._cols[name] = c
        return c

    @property
    def filename(self) -> np.ndarray:
        return self._col("filename")

    @property
    def lon(self) -> np.ndarray:
        return self._col("lon")

    @property
    def lat(self) -> np.ndarray:
        return self._col("lat")

    @property
    def timestamp(self) -> np.ndarray:
        return self._col("timestamp")

    @property
    def class_id(self) -> np.ndarray:
        return self._col("class_id")

    @property
    def det_count(self) -> np.ndarray:
        return self._col("det_count")

    @property
    def land_cover(self) -> np.ndarray:
        return self._col("land_cover")

    @property
    def cloud_pct(self) -> np.ndarray:
        return self._col("cloud_pct")

    def __len__(self) -> int:
        if self._index is not None:
            return len(self._index)
        return len(self._base["lon"])

    @property
    def size_bytes(self) -> int:
        # model the paper's 50-100 MB per yearly frame
        return int(len(self) * 5200)

    @property
    def size_mb(self) -> float:
        return self.size_bytes / 1e6

    # -- views ---------------------------------------------------------------
    def _take(self, idx: np.ndarray) -> "GeoFrame":
        """Index view: idx positions are relative to *this* frame."""
        view = object.__new__(GeoFrame)
        view.key = self.key
        view._base = self._base
        view._index = idx if self._index is None else self._index[idx]
        view._cols = {}
        view._bbox_memo = {}
        view._op_memo = {}
        return view

    def memo_op(self, op_key: tuple, fn):
        """Memoise a deterministic pure operation on this (immutable) frame.

        Filters, sorts and aggregations over a frame are pure functions of
        its contents, and the bbox memo already shares ROI views across
        every consumer of a root frame — so memoising per (view, op, args)
        makes the whole benchmark grid share one physical execution of each
        distinct tool computation (the gold executor and every benchmark
        cell replay the same plans). Callers that return mutable containers
        copy on the way out; frame results are immutable shared views."""
        hit = self._op_memo.get(op_key)
        if hit is None:
            hit = self._op_memo[op_key] = fn()
        return hit

    def _mask(self, m: np.ndarray) -> "GeoFrame":
        return self._take(np.flatnonzero(m))

    def filter_bbox(self, bbox) -> "GeoFrame":
        bbox = tuple(bbox)
        hit = self._bbox_memo.get(bbox)
        if hit is None:
            x0, y0, x1, y1 = bbox
            lon, lat = self.lon, self.lat
            m = (lon >= x0) & (lon <= x1) & (lat >= y0) & (lat <= y1)
            hit = self._mask(m)
            self._bbox_memo[bbox] = hit
        return hit

    def filter_class(self, class_name: str) -> "GeoFrame":
        return self.memo_op(
            ("class", class_name),
            lambda: self._mask(self.class_id == CLASSES.index(class_name)))

    def filter_clouds(self, max_pct: float) -> "GeoFrame":
        return self.memo_op(
            ("clouds", max_pct),
            lambda: self._mask(self.cloud_pct <= max_pct))


def _seed_for(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(),
                                          digest_size=4).digest(), "big")


def _filenames(dataset: str, year: str, n: int) -> np.ndarray:
    """``{dataset}_{year}_%06d.tif`` for 0..n-1, built as raw UCS4 code
    points and viewed as a unicode array — element-for-element identical to
    ``np.char.mod`` but ~30x faster (the per-element C format loop was the
    single largest cost of synthesising a root frame)."""
    prefix = f"{dataset}_{year}_"
    suffix = ".tif"
    assert n < 10 ** 6            # %06d: six digits always
    width = len(prefix) + 6 + len(suffix)
    codes = np.empty((n, width), dtype=np.uint32)
    codes[:, :len(prefix)] = np.frombuffer(
        prefix.encode("utf-32-le"), dtype=np.uint32)
    digits = np.arange(n, dtype=np.int64)
    for j in range(5, -1, -1):
        codes[:, len(prefix) + j] = 48 + digits % 10      # ord('0') == 48
        digits //= 10
    codes[:, len(prefix) + 6:] = np.frombuffer(
        suffix.encode("utf-32-le"), dtype=np.uint32)
    return codes.view(f"<U{width}").ravel()


# process-wide root-frame memo: synth_frame is deterministic and frames are
# immutable, so every datastore/benchmark cell can share one instance per
# (key, rows_range) — the default band and the widened cost-ablation band
# coexist without collision
_FRAME_MEMO: Dict[tuple, GeoFrame] = {}

DEFAULT_ROWS_RANGE = (12_000, 18_000)   # ~62-94 MB at 5200 B/row


def synth_frame(key: str, rows_range: Optional[tuple] = None) -> GeoFrame:
    memo_key = (key, rows_range)
    cached = _FRAME_MEMO.get(memo_key)
    if cached is not None:
        return cached
    rng = np.random.default_rng(_seed_for(key))
    dataset, year = key.rsplit("-", 1)
    lo, hi = rows_range or DEFAULT_ROWS_RANGE
    n = int(rng.integers(lo, hi))
    # spatially skewed around regions of interest (the paper's observation)
    centers = np.array([[(b[0] + b[2]) / 2, (b[1] + b[3]) / 2]
                        for b in REGIONS.values()])
    which = rng.integers(0, len(centers), n)
    lon = (centers[which, 0] + rng.normal(0, 0.15, n)).astype(np.float32)
    lat = (centers[which, 1] + rng.normal(0, 0.12, n)).astype(np.float32)
    t0 = np.datetime64(f"{year}-01-01").astype("datetime64[s]").astype(np.int64)
    ts = t0 + rng.integers(0, 365 * 24 * 3600, n)
    frame = GeoFrame(
        key=key,
        filename=_filenames(dataset, year, n),
        lon=lon, lat=lat, timestamp=ts,
        class_id=rng.integers(0, len(CLASSES), n).astype(np.int8),
        det_count=rng.integers(0, 40, n).astype(np.int16),
        land_cover=rng.integers(0, len(LAND_COVERS), n).astype(np.int8),
        cloud_pct=rng.uniform(0, 100, n).astype(np.float32),
    )
    _FRAME_MEMO[memo_key] = frame
    return frame


class GeoDataStore:
    """Main memory. ``load`` charges DB latency; frames are memoised host-side
    (the memo is the *data platform's* store, not the LLM-visible cache).

    ``rows_range`` widens (or narrows) the per-frame row-count band — the
    cost-aware admission ablation uses a wide band so frame sizes diverge
    enough for size-weighted decisions to have signal. ``None`` keeps the
    default 12-18k band (62-94 MB), bit-identical to the original store.
    """

    def __init__(self, clock, rows_range: Optional[tuple] = None):
        self.clock = clock
        self.loads = 0
        self.rows_range = rows_range

    def _frame(self, key: str) -> GeoFrame:
        if key not in _ALL_KEYS:
            raise KeyError(f"unknown dataset-year {key!r}")
        return synth_frame(key, self.rows_range)

    def load(self, key: str) -> GeoFrame:
        f = self._frame(key)
        self.loads += 1
        self.clock.advance(self.clock.latency.db_load(f.size_mb))
        return f

    def peek(self, key: str) -> GeoFrame:
        """Latency-free access for gold-answer computation only."""
        return self._frame(key)

    def cache_read_latency(self, key: str) -> float:
        return self.clock.latency.cache_read(self._frame(key).size_mb)

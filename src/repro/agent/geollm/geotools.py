"""GeoLLM-Engine platform tools (beyond the two dCache tools).

Pure functions over ``GeoFrame`` values registered as :class:`ToolSpec`;
the agent resolves ``$var`` references from its variable environment before
dispatch, mirroring function-calling with object handles. Latencies are
charged per call via the SimClock (``tool_op_s``); the heavy ML tools
carry larger constants.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.agent.geollm.datastore import (
    CLASSES,
    LAND_COVERS,
    REGIONS,
    GeoFrame,
)
from repro.core.tools import ToolError, ToolSpec


def _require_frame(f):
    if not isinstance(f, GeoFrame):
        raise ToolError(f"expected a GeoFrame handle, got {type(f).__name__}")
    return f


def filter_bbox(frame, region: str) -> GeoFrame:
    f = _require_frame(frame)
    if region not in REGIONS:
        raise ToolError(f"unknown region {region!r}; known: {sorted(REGIONS)}")
    return f.filter_bbox(REGIONS[region])


def filter_class(frame, class_name: str) -> GeoFrame:
    f = _require_frame(frame)
    if class_name not in CLASSES:
        raise ToolError(f"unknown class {class_name!r}")
    return f.filter_class(class_name)


def filter_clouds(frame, max_pct: float) -> GeoFrame:
    return _require_frame(frame).filter_clouds(float(max_pct))


def filter_date_range(frame, start_month: int, end_month: int) -> GeoFrame:
    f = _require_frame(frame)
    m0, m1 = int(start_month), int(end_month)

    def compute():
        month = ((f.timestamp // (30 * 24 * 3600)) % 12) + 1
        return f._mask((month >= m0) & (month <= m1))

    return f.memo_op(("date_range", m0, m1), compute)


def count_images(frame) -> int:
    return len(_require_frame(frame))


def detect_objects(frame, class_name: str) -> Dict:
    """Object detection over the (already filtered) tile set."""
    f = _require_frame(frame)
    if class_name not in CLASSES:
        raise ToolError(f"unknown class {class_name!r}")

    def compute():
        sub = f.filter_class(class_name)
        return {"class": class_name, "images": len(sub),
                "detections": int(sub.det_count.sum())}

    return dict(f.memo_op(("detect", class_name), compute))


def land_cover_stats(frame) -> Dict[str, float]:
    f = _require_frame(frame)

    def compute():
        if len(f) == 0:
            return {c: 0.0 for c in LAND_COVERS}
        counts = np.bincount(f.land_cover, minlength=len(LAND_COVERS))
        return {c: float(counts[i]) / len(f)
                for i, c in enumerate(LAND_COVERS)}

    return dict(f.memo_op(("lcc_stats",), compute))


def dominant_land_covers(frame, top_k: int = 2) -> List[str]:
    f = _require_frame(frame)
    k = int(top_k)

    def compute():
        stats = land_cover_stats(f)
        return sorted(stats, key=stats.get, reverse=True)[:k]

    return list(f.memo_op(("lcc_top", k), compute))


def vqa_answer(frame, question: str) -> str:
    """Template VQA over frame statistics (deterministic)."""
    f = _require_frame(frame)

    def compute():
        n = len(f)
        dets = int(f.det_count.sum())
        covers = dominant_land_covers(f, 2)
        cloudy = float((f.cloud_pct > 50).mean()) if n else 0.0
        return (f"the region contains {n} images with {dets} detected "
                f"objects ; dominant land cover is {covers[0]} followed by "
                f"{covers[1]} ; {cloudy:.0%} of scenes are cloudy")

    return f.memo_op(("vqa",), compute)


def image_stats(frame) -> Dict:
    f = _require_frame(frame)
    return {"images": len(f),
            "mean_cloud_pct": float(f.cloud_pct.mean()) if len(f) else 0.0,
            "detections": int(f.det_count.sum())}


def sample_images(frame, k: int = 5) -> List[str]:
    f = _require_frame(frame)
    return list(f.filename[: int(k)])


def sort_by_time(frame) -> GeoFrame:
    f = _require_frame(frame)
    return f.memo_op(
        ("sort_time",),
        lambda: f._take(np.argsort(f.timestamp, kind="stable")))


def merge_frames(frame_a, frame_b) -> GeoFrame:
    a, b = _require_frame(frame_a), _require_frame(frame_b)
    return GeoFrame(
        f"{a.key}+{b.key}",
        np.concatenate([a.filename, b.filename]),
        np.concatenate([a.lon, b.lon]), np.concatenate([a.lat, b.lat]),
        np.concatenate([a.timestamp, b.timestamp]),
        np.concatenate([a.class_id, b.class_id]),
        np.concatenate([a.det_count, b.det_count]),
        np.concatenate([a.land_cover, b.land_cover]),
        np.concatenate([a.cloud_pct, b.cloud_pct]))


def plot_images(frame) -> str:
    f = _require_frame(frame)
    return f"<map-layer images={len(f)} src={f.key}>"


def plot_heatmap(frame, value: str = "detections") -> str:
    f = _require_frame(frame)
    return f"<heatmap value={value} n={len(f)}>"


def timeseries(frame, freq: str = "month") -> List[int]:
    f = _require_frame(frame)

    def compute():
        if len(f) == 0:
            return []
        month = ((f.timestamp // (30 * 24 * 3600)) % 12).astype(int)
        return np.bincount(month, minlength=12).tolist()

    return list(f.memo_op(("timeseries", freq), compute))


_ML_LATENCY = 0.12   # detector / classifier endpoints
_UI_LATENCY = 0.05


def make_geo_tools(clock) -> List[ToolSpec]:
    op = clock.latency.tool_op_s
    str_p = {"type": "string"}
    num_p = {"type": "number"}

    def spec(name, fn, desc, params, latency):
        return ToolSpec(name=name, description=desc, parameters=params,
                        fn=fn, latency_s=latency)

    return [
        spec("filter_bbox", filter_bbox,
             "Filter a frame to a named region of interest.",
             {"frame": str_p, "region": str_p}, op),
        spec("filter_class", filter_class,
             "Keep only images whose dominant class matches.",
             {"frame": str_p, "class_name": str_p}, op),
        spec("filter_clouds", filter_clouds,
             "Keep images with cloud cover below a threshold.",
             {"frame": str_p, "max_pct": num_p}, op),
        spec("filter_date_range", filter_date_range,
             "Keep images within [start_month, end_month].",
             {"frame": str_p, "start_month": num_p, "end_month": num_p}, op),
        spec("count_images", count_images, "Number of images in a frame.",
             {"frame": str_p}, op),
        spec("detect_objects", detect_objects,
             "Run the object detector for one class over a frame.",
             {"frame": str_p, "class_name": str_p}, _ML_LATENCY),
        spec("land_cover_stats", land_cover_stats,
             "Land-cover distribution of a frame.", {"frame": str_p},
             _ML_LATENCY),
        spec("dominant_land_covers", dominant_land_covers,
             "Top-k land covers of a frame.",
             {"frame": str_p, "top_k": num_p}, _ML_LATENCY),
        spec("vqa_answer", vqa_answer,
             "Answer a free-form question about a frame.",
             {"frame": str_p, "question": str_p}, _ML_LATENCY),
        spec("image_stats", image_stats, "Summary statistics of a frame.",
             {"frame": str_p}, op),
        spec("sample_images", sample_images, "Sample k image filenames.",
             {"frame": str_p, "k": num_p}, op),
        spec("sort_by_time", sort_by_time, "Sort a frame by timestamp.",
             {"frame": str_p}, op),
        spec("merge_frames", merge_frames, "Concatenate two frames.",
             {"frame_a": str_p, "frame_b": str_p}, op),
        spec("plot_images", plot_images, "Render frame tiles on the map UI.",
             {"frame": str_p}, _UI_LATENCY),
        spec("plot_heatmap", plot_heatmap, "Render a heatmap layer.",
             {"frame": str_p, "value": str_p}, _UI_LATENCY),
        spec("timeseries", timeseries, "Monthly acquisition counts.",
             {"frame": str_p, "freq": str_p}, op),
    ]

"""AdamW with cosine schedule — pure-pytree implementation (no optax offline).

Optimizer moments are stored in fp32 regardless of param dtype; their
sharding follows the parameter sharding (params are already 2D-sharded, so
this is ZeRO-equivalent — DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, \
        {"lr": lr, "grad_norm": gnorm}

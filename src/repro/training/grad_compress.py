"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

Beyond-paper distributed-optimization trick (system-prompt requirement):
gradients are quantised to int8 per block before crossing the DP axis and
the quantisation residual is fed back into the next step's gradient
(error feedback keeps SGD convergence unbiased in the long run). Exposed
both as pure functions (unit-testable) and as a ``shard_map`` collective
wrapper for the mesh path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (BLOCK - n % BLOCK) % BLOCK


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g (any shape) -> (int8 codes, per-block fp32 scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def decompress(codes: jax.Array, scale: jax.Array, shape,
               dtype=jnp.float32) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(g: jax.Array, residual: jax.Array):
    """Error-feedback: compress (g + residual); return codes, scale, and the
    new residual (what the quantisation lost)."""
    corrected = g.astype(jnp.float32) + residual
    codes, scale = compress(corrected)
    approx = decompress(codes, scale, g.shape)
    return codes, scale, corrected - approx


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: int8-compress locally, all-reduce the small codes'
    dequantised values (ring all-reduce of ~1/4 the bytes), return mean."""
    codes, scale = compress(g)
    approx = decompress(codes, scale, g.shape)
    return jax.lax.pmean(approx, axis_name)


def make_compressed_allreduce(mesh, axis_name: str = "data"):
    """shard_map'd gradient mean over the DP axis with int8 compression."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(axis_name), out_specs=P(axis_name), check_rep=False)
    def allreduce(g):
        return compressed_psum(g, axis_name)

    return allreduce

"""Deterministic sharded data pipeline with background prefetch.

Synthetic token streams (per-rank seeded, disjoint) packed to fixed length;
a daemon thread keeps a bounded queue of ready batches so host data work
overlaps device compute (the standard input-pipeline overlap trick).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class TokenStream:
    """Zipf-ish synthetic LM stream; deterministic per (seed, rank)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, rank: int = 0, n_ranks: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.rng = np.random.default_rng((seed, rank))
        self.rank, self.n_ranks = rank, n_ranks
        self._step = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        v = self.cfg.vocab_size
        # mixture of a repeating motif and zipf noise -> learnable signal
        base = self.rng.integers(0, v, (self.batch, self.seq + 1),
                                 dtype=np.int32)
        motif = (np.arange(self.seq + 1) * 7 + self._step) % min(v, 97)
        mask = self.rng.random((self.batch, self.seq + 1)) < 0.5
        tokens = np.where(mask, motif[None, :].astype(np.int32), base)
        self._step += 1
        batch = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
        if self.cfg.is_encdec:
            batch["frames"] = self.rng.normal(
                0, 1, (self.batch, max(self.seq // 2, 4), self.cfg.d_model)
            ).astype(np.float32)
        elif self.cfg.frontend == "vision_patches":
            n = min(self.cfg.n_frontend_tokens, self.seq // 2)
            batch["patches"] = self.rng.normal(
                0, 1, (self.batch, n, self.cfg.d_model)).astype(np.float32)
        return batch


class Prefetcher:
    """Bounded background prefetch queue over a TokenStream."""

    def __init__(self, stream: TokenStream, depth: int = 2):
        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            try:
                self.q.put(self.stream.next_batch(), timeout=0.2)
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)

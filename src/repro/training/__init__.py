from repro.training.data import Prefetcher, TokenStream  # noqa: F401
from repro.training.grad_compress import (  # noqa: F401
    compress,
    compress_with_feedback,
    decompress,
)
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig,
    adamw_update,
    init_opt_state,
    schedule,
)
from repro.training.train_loop import TrainLoop, make_train_step  # noqa: F401

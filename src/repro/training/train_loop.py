"""Training loop: jit'd fused train step (loss -> grad -> AdamW), optional
gradient accumulation, checkpoint/restore hooks, fault-tolerant supervisor.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum_steps: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With ``accum_steps > 1`` the batch's leading dim is split and gradients
    are averaged over microbatches via ``lax.scan`` (activation memory is
    1/accum of the full batch — the standard microbatching trade)."""

    def grads_of(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return grads, metrics

    def step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                g, m = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m

            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, ms = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        params, opt_state, opt_m = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_m)
        return params, opt_state, metrics

    return step


class TrainLoop:
    """Step executor with checkpointing and failure recovery.

    ``failure_injector`` (tests) may raise ``WorkerFailure`` inside a step;
    the loop restores the last checkpoint and repeats the step — the
    single-process analogue of a coordinator restarting a failed worker.
    """

    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig, params,
                 data_iter, checkpointer=None, ckpt_every: int = 50,
                 accum_steps: int = 1, monitor=None,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum_steps))
        self.params = params
        self.opt_state = init_opt_state(params)
        self.data = data_iter
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.monitor = monitor
        self.failure_injector = failure_injector
        self.step_idx = 0
        self.history: list = []

    def restore_if_available(self) -> bool:
        if self.ckpt is None:
            return False
        like = {"params": self.params, "opt_state": self.opt_state,
                "meta": {"step": 0}}
        restored = self.ckpt.restore_latest(like=like)
        if restored is None:
            return False
        self.params = jax.tree.map(
            lambda p, r: jnp.asarray(r, p.dtype), self.params,
            restored["params"])
        self.opt_state = jax.tree.map(
            lambda p, r: jnp.asarray(r, p.dtype), self.opt_state,
            restored["opt_state"])
        self.step_idx = int(restored["meta"]["step"])
        return True

    def _checkpoint(self):
        if self.ckpt is not None:
            self.ckpt.save(self.step_idx,
                           {"params": self.params,
                            "opt_state": self.opt_state,
                            "meta": {"step": self.step_idx}})

    def run(self, n_steps: int, max_retries: int = 3) -> Dict[str, Any]:
        from repro.distributed.fault_tolerance import WorkerFailure
        metrics: Dict[str, Any] = {}
        while self.step_idx < n_steps:
            batch_np = next(self.data)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if self.cfg.jnp_dtype == jnp.bfloat16:
                batch = {k: (v.astype(jnp.bfloat16)
                             if v.dtype == jnp.float32 else v)
                         for k, v in batch.items()}
            attempts = 0
            while True:
                t0 = time.perf_counter()
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(self.step_idx)
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
                    break
                except WorkerFailure:
                    attempts += 1
                    if attempts > max_retries:
                        raise
                    restored = self.restore_if_available()
                    if self.monitor:
                        self.monitor.record_failure(self.step_idx, restored)
            dt = time.perf_counter() - t0
            if self.monitor:
                self.monitor.record_step(self.step_idx, dt)
            self.history.append(float(metrics["loss"]))
            self.step_idx += 1
            if self.ckpt_every and self.step_idx % self.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        return {k: float(v) for k, v in metrics.items()}

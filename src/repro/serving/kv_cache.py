"""Paged KV cache (vLLM-style, TPU-adapted).

Physical storage is a pool of fixed-size pages per layer,
``(n_pages, page_size, kv_dim)``; each sequence owns a growable list of
pages recorded in a page table. Attention gathers the sequence's pages into
a contiguous view (``jnp.take`` — on TPU this lowers to dynamic-gather; the
Pallas decode kernel can consume the gathered view directly). Compared with
the engine's per-slot ring buffers, paging removes per-slot max-length
reservation: memory scales with *tokens in flight*, not slots x max_len.

Host-side allocator (free list, ref-counted pages for prefix sharing) +
device-side gather/scatter helpers, both tested in ``tests/test_paged_kv``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PagedCacheConfig:
    n_layers: int
    kv_dim: int                 # n_kv_heads * head_dim
    page_size: int = 16         # tokens per page
    n_pages: int = 256          # physical pages per layer
    dtype: str = "bfloat16"


class PageAllocator:
    """Host-side free-list allocator with ref counting (prefix sharing)."""

    def __init__(self, n_pages: int):
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.refs: Dict[int, int] = {}

    def alloc(self) -> int:
        if not self.free:
            raise OutOfPages("no free KV pages")
        p = self.free.pop()
        self.refs[p] = 1
        return p

    def share(self, page: int):
        self.refs[page] += 1

    def release(self, page: int):
        self.refs[page] -= 1
        if self.refs[page] == 0:
            del self.refs[page]
            self.free.append(page)

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclasses.dataclass
class SequenceState:
    sid: int
    length: int = 0
    pages: List[int] = dataclasses.field(default_factory=list)


class PagedKVCache:
    """Paged K/V storage for all layers + per-sequence page tables."""

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, cfg.n_pages, cfg.page_size, cfg.kv_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self.alloc = PageAllocator(cfg.n_pages)
        self.seqs: Dict[int, SequenceState] = {}
        self._next_sid = 0

    # -- sequence lifecycle ---------------------------------------------------
    def new_seq(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        self.seqs[sid] = SequenceState(sid)
        return sid

    def free_seq(self, sid: int):
        for p in self.seqs[sid].pages:
            self.alloc.release(p)
        del self.seqs[sid]

    def fork_seq(self, sid: int) -> int:
        """Prefix sharing: new sequence sharing all full pages (copy-on-...
        -append: the last partial page is copied, not shared)."""
        src = self.seqs[sid]
        new = self.new_seq()
        dst = self.seqs[new]
        full = src.length // self.cfg.page_size
        for p in src.pages[:full]:
            self.alloc.share(p)
            dst.pages.append(p)
        dst.length = full * self.cfg.page_size
        if src.length > dst.length:  # copy the partial tail
            tail = src.pages[full]
            cp = self.alloc.alloc()
            self.k = self.k.at[:, cp].set(self.k[:, tail])
            self.v = self.v.at[:, cp].set(self.v[:, tail])
            dst.pages.append(cp)
            dst.length = src.length
        return new

    # -- write ------------------------------------------------------------
    def append(self, sid: int, k_tok: jax.Array, v_tok: jax.Array):
        """Append one token's K/V. k_tok/v_tok: (n_layers, kv_dim)."""
        s = self.seqs[sid]
        ps = self.cfg.page_size
        if s.length % ps == 0:
            s.pages.append(self.alloc.alloc())
        page = s.pages[-1]
        off = s.length % ps
        self.k = self.k.at[:, page, off].set(k_tok)
        self.v = self.v.at[:, page, off].set(v_tok)
        s.length += 1

    def write_prompt(self, sid: int, k_seq: jax.Array, v_seq: jax.Array):
        """Bulk prefill write. k_seq/v_seq: (n_layers, S, kv_dim)."""
        S = k_seq.shape[1]
        s = self.seqs[sid]
        assert s.length == 0, "write_prompt on a non-empty sequence"
        ps = self.cfg.page_size
        n_pages = (S + ps - 1) // ps
        pad = n_pages * ps - S
        if pad:
            z = jnp.zeros((k_seq.shape[0], pad, k_seq.shape[2]), k_seq.dtype)
            k_seq = jnp.concatenate([k_seq, z], axis=1)
            v_seq = jnp.concatenate([v_seq, z], axis=1)
        kp = k_seq.reshape(k_seq.shape[0], n_pages, ps, -1)
        vp = v_seq.reshape(v_seq.shape[0], n_pages, ps, -1)
        for i in range(n_pages):
            page = self.alloc.alloc()
            s.pages.append(page)
            self.k = self.k.at[:, page].set(kp[:, i])
            self.v = self.v.at[:, page].set(vp[:, i])
        s.length = S

    # -- read ------------------------------------------------------------
    def page_table(self, sids: List[int], max_pages: Optional[int] = None
                   ) -> np.ndarray:
        """(B, max_pages) int32 table, padded with page 0 (masked by len)."""
        mp = max_pages or max(len(self.seqs[s].pages) for s in sids)
        t = np.zeros((len(sids), mp), np.int32)
        for i, sid in enumerate(sids):
            pg = self.seqs[sid].pages
            t[i, :len(pg)] = pg
        return t

    def gather(self, sids: List[int]):
        """Contiguous (B, C, kv_dim) views per layer via page-table gather.
        C = max_pages*page_size; positions beyond each seq length are junk
        and must be masked by the caller (lengths returned)."""
        table = jnp.asarray(self.page_table(sids))          # (B, P)
        k = jnp.take(self.k, table, axis=1)                 # (L, B, P, ps, D)
        v = jnp.take(self.v, table, axis=1)
        L, B, P, ps, D = k.shape
        lengths = jnp.asarray([self.seqs[s].length for s in sids], jnp.int32)
        return (k.reshape(L, B, P * ps, D), v.reshape(L, B, P * ps, D),
                lengths)

    # -- stats -------------------------------------------------------------
    def utilization(self) -> float:
        used = self.cfg.n_pages - self.alloc.n_free
        return used / self.cfg.n_pages


def paged_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           lengths: jax.Array, n_kv_heads: int,
                           head_dim: int) -> jax.Array:
    """Reference attention over gathered pages. q: (B, Hq*hd); k/v:
    (B, C, kv_dim); lengths: (B,). Returns (B, Hq*hd)."""
    B, C, _ = k.shape
    kc = k.reshape(B, C, n_kv_heads, head_dim)
    vc = v.reshape(B, C, n_kv_heads, head_dim)
    hq = q.shape[-1] // head_dim
    g = hq // n_kv_heads
    qh = q.reshape(B, n_kv_heads, g, head_dim)
    s = jnp.einsum("bkgh,btkh->bkgt", qh.astype(jnp.float32),
                   kc.astype(jnp.float32)) * (head_dim ** -0.5)
    mask = jnp.arange(C)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", w, vc.astype(jnp.float32))
    return o.reshape(B, -1).astype(q.dtype)

from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.sampler import sample  # noqa: F401
from repro.serving.tokenizer import ByteTokenizer  # noqa: F401

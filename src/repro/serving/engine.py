"""Continuous-batching serving engine.

Slot-based scheduler over the unified model's (prefill_step, decode_step):
a fixed decode batch of ``max_batch`` slots steps in lockstep (one jitted
decode per engine step); requests are admitted into free slots by running a
single-row prefill (prompt bucketed to a power of two to bound recompiles —
right-padding is masked by construction, see ``prefill_step``) and
scattering the row into the batch cache. Completed rows free their slot.

This is the vLLM-style core scaled down: the KV "pages" are per-slot ring
buffers; at production scale the same engine runs under pjit with the cache
sharded (batch -> data, kv -> model) — exactly what the decode dry-run
shapes lower.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import cache_specs, effective_cache_len
from repro.models.model import decode_step, prefill_step
from repro.serving.sampler import sample
from repro.serving.tokenizer import MIN_VOCAB, ByteTokenizer


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new_tokens: int
    temperature: float = 0.0
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


def _bucket(n: int, cap: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 512, tokenizer: Optional[ByteTokenizer] = None):
        assert cfg.vocab_size >= MIN_VOCAB, "byte tokenizer needs vocab>=258"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.tok = tokenizer or ByteTokenizer()
        self.cache = self._empty_cache()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._rid = 0
        self._rng = jax.random.PRNGKey(0)
        self._decode = jax.jit(functools.partial(decode_step, cfg))
        self._prefill = {}
        self.steps = 0

    # -- cache plumbing -------------------------------------------------------
    def _empty_cache(self):
        specs = cache_specs(self.cfg, self.max_batch, self.max_len)
        return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill:
            self._prefill[bucket] = jax.jit(functools.partial(
                prefill_step, self.cfg, max_len=self.max_len))
        return self._prefill[bucket]

    def _install(self, slot: int, row_cache: Dict):
        """Scatter a B=1 prefill cache into slot b of the batch cache."""
        C = effective_cache_len(self.cfg, self.max_len)
        for k, v in row_cache.items():
            cur = self.cache[k]
            if k == "pos":
                self.cache[k] = cur.at[slot].set(v[0])
            elif cur.ndim >= 3 and cur.shape[1] == self.max_batch:
                # (L, B, ...) layer-stacked
                row = v[:, 0]
                if k in ("k", "v"):
                    rc = row.shape[1]
                    if rc < C:
                        pad = jnp.zeros((row.shape[0], C - rc, row.shape[2]),
                                        row.dtype)
                        row = jnp.concatenate([row, pad], axis=1)
                    else:
                        row = row[:, :C]
                self.cache[k] = cur.at[:, slot].set(row)
            else:
                self.cache[k] = cur.at[slot].set(v[0])

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: str, max_new_tokens: int = 32,
               temperature: float = 0.0) -> Request:
        ids = self.tok.encode(prompt)[- (self.max_len // 2):]
        req = Request(rid=self._rid, prompt_ids=ids,
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      submitted_at=time.perf_counter())
        self._rid += 1
        self.waiting.append(req)
        return req

    def _admit(self):
        exact = self.cfg.family in ("ssm", "hybrid")  # recurrent state: no pad
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            n = len(req.prompt_ids)
            bucket = n if exact else _bucket(n, self.max_len)
            ids = req.prompt_ids + [0] * (bucket - n)
            batch = {"tokens": jnp.asarray([ids], jnp.int32)}
            row_cache, logits = self._prefill_fn(bucket)(
                self.params, batch,
                true_lens=jnp.asarray([n], jnp.int32))
            self._install(slot, row_cache)
            self._rng, k = jax.random.split(self._rng)
            tok = sample(logits[:, -1].astype(jnp.float32), k,
                         temperature=req.temperature)
            req.out_ids.append(int(tok[0]))
            req.first_token_at = time.perf_counter()
            self.slots[slot] = req

    def step(self) -> int:
        """One engine step: admit waiting requests, decode all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out_ids[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        self._rng, k = jax.random.split(self._rng)
        nxt = np.asarray(sample(logits[:, -1].astype(jnp.float32), k))
        self.steps += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.out_ids.append(tok)
            limit_hit = len(req.out_ids) >= req.max_new_tokens
            pos_cap = int(self.cache["pos"][i]) >= self.max_len - 1
            if tok == self.tok.eos_id or limit_hit or pos_cap:
                req.done = True
                req.finished_at = time.perf_counter()
                self.finished.append(req)
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_steps: int = 10_000):
        while (self.waiting or any(s is not None for s in self.slots)) \
                and max_steps > 0:
            self.step()
            max_steps -= 1

    def generate_text(self, prompt: str, max_new_tokens: int = 32,
                      temperature: float = 0.0) -> str:
        req = self.submit(prompt, max_new_tokens, temperature)
        self.run_until_done()
        return self.tok.decode(req.out_ids)

    # -- metrics ---------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        done = self.finished
        if not done:
            return {"finished": 0}
        ttft = [r.first_token_at - r.submitted_at for r in done
                if r.first_token_at]
        lat = [r.finished_at - r.submitted_at for r in done if r.finished_at]
        toks = sum(len(r.out_ids) for r in done)
        wall = max(r.finished_at for r in done) - min(
            r.submitted_at for r in done)
        return {"finished": len(done),
                "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
                "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
                "tokens": toks,
                "throughput_tok_s": toks / wall if wall > 0 else 0.0}

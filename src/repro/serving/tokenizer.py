"""Byte-level tokenizer: ids 0-255 = bytes, 256 = BOS, 257 = EOS.

No external vocab needed offline; any model config with vocab >= 258 can
serve text.
"""
from __future__ import annotations

from typing import List

import numpy as np

BOS = 256
EOS = 257
MIN_VOCAB = 258


class ByteTokenizer:
    bos_id = BOS
    eos_id = EOS

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        return ([BOS] + ids) if add_bos else ids

    def decode(self, ids) -> str:
        bs = bytes(int(i) for i in np.asarray(ids).tolist()
                   if 0 <= int(i) < 256)
        return bs.decode("utf-8", errors="replace")

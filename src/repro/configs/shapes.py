"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

``input_specs`` builds the exact pytree of ``jax.ShapeDtypeStruct`` that the
corresponding step function (``train_step`` / ``prefill_step`` /
``decode_step``) takes — no device allocation, weak-type-correct, shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES: Dict[str, ShapeSpec] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (DESIGN §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "pure full-attention arch: 500K dense-KV decode is skipped"
    return None


def effective_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """KV entries actually retained at decode time (SWA/chunk bound it)."""
    cap = seq_len
    if cfg.sliding_window is not None:
        cap = min(cap, cfg.sliding_window)
    if cfg.attn_chunk is not None:
        cap = min(cap, cfg.attn_chunk)
    return cap


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the decode-time cache pytree.

    Layer-stacked leading dim L so the model can ``lax.scan`` over layers.
    """
    dt = cfg.jnp_dtype
    L = cfg.n_layers
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if not cfg.attn_free:
        C = effective_cache_len(cfg, seq_len)
        kv = cfg.n_kv_heads * cfg.head_dim_
        kv_dt = jnp.int8 if cfg.kv_quant else dt
        specs["k"] = jax.ShapeDtypeStruct((L, batch, C, kv), kv_dt)
        specs["v"] = jax.ShapeDtypeStruct((L, batch, C, kv), kv_dt)
        if cfg.kv_quant:
            H = cfg.n_kv_heads
            specs["k_scale"] = jax.ShapeDtypeStruct((L, batch, C, H), dt)
            specs["v_scale"] = jax.ShapeDtypeStruct((L, batch, C, H), dt)
    if cfg.family in ("ssm", "hybrid"):
        ssm = cfg.ssm
        H, hd = cfg.n_ssm_heads, ssm.head_dim
        # rwkv's WKV state is the square (hd_k x hd_v) outer-product matrix
        st = hd if ssm.kind == "rwkv6" else ssm.state_size
        # recurrent state is held in fp32 for numerical stability of the scan
        specs["ssm_state"] = jax.ShapeDtypeStruct((L, batch, H, hd, st), jnp.float32)
        if cfg.family == "ssm":  # rwkv6 token-shift states (time-mix, channel-mix)
            specs["shift_tm"] = jax.ShapeDtypeStruct((L, batch, cfg.d_model), dt)
            specs["shift_cm"] = jax.ShapeDtypeStruct((L, batch, cfg.d_model), dt)
        if cfg.ssm.kind == "mamba" and cfg.ssm.conv_width > 1:
            cw = cfg.ssm.conv_width
            specs["conv_state"] = jax.ShapeDtypeStruct(
                (L, batch, cw - 1, H * hd), dt)
    if cfg.is_encdec:
        enc_len = seq_len // 2
        kvd = cfg.n_kv_heads * cfg.head_dim_
        specs["cross_k"] = jax.ShapeDtypeStruct((L, batch, enc_len, kvd), dt)
        specs["cross_v"] = jax.ShapeDtypeStruct((L, batch, enc_len, kvd), dt)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, object]:
    """Data-argument specs for the step function of ``shape.kind``.

    Modality frontends ([audio]/[vlm]) are STUBS: ``frames``/``patches`` are
    precomputed embeddings handed in directly, per the assignment note.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    tok = jnp.int32

    if shape.kind == "train":
        if cfg.is_encdec:
            enc_len, dec_len = S // 2, S // 2
            return {
                "frames": jax.ShapeDtypeStruct((B, enc_len, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, dec_len), tok),
                "targets": jax.ShapeDtypeStruct((B, dec_len), tok),
            }
        batch = {}
        text_len = S
        if cfg.frontend == "vision_patches":
            n = cfg.n_frontend_tokens
            text_len = S - n
            batch["patches"] = jax.ShapeDtypeStruct((B, n, cfg.d_model), dt)
        batch["tokens"] = jax.ShapeDtypeStruct((B, text_len), tok)
        batch["targets"] = jax.ShapeDtypeStruct((B, text_len), tok)
        return batch

    if shape.kind == "prefill":
        if cfg.is_encdec:
            enc_len, dec_len = S // 2, S // 2
            return {
                "frames": jax.ShapeDtypeStruct((B, enc_len, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, dec_len), tok),
            }
        batch = {}
        text_len = S
        if cfg.frontend == "vision_patches":
            n = cfg.n_frontend_tokens
            text_len = S - n
            batch["patches"] = jax.ShapeDtypeStruct((B, n, cfg.d_model), dt)
        batch["tokens"] = jax.ShapeDtypeStruct((B, text_len), tok)
        return batch

    assert shape.kind == "decode"
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), tok),
        "cache": cache_specs(cfg, B, S),
    }


# ---------------------------------------------------------------------------
# Logical sharding axes for the data-argument pytrees (dry-run in_shardings)
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    "pos": ("batch",),
    "k": ("layers", "batch", "cache_seq", "kv"),
    "v": ("layers", "batch", "cache_seq", "kv"),
    "k_scale": ("layers", "batch", "cache_seq", ""),
    "v_scale": ("layers", "batch", "cache_seq", ""),
    "ssm_state": ("layers", "batch", "", "", ""),
    "shift_tm": ("layers", "batch", "act_embed"),
    "shift_cm": ("layers", "batch", "act_embed"),
    "conv_state": ("layers", "batch", "", "ssm_dim"),
    "cross_k": ("layers", "batch", "cache_seq", "kv"),
    "cross_v": ("layers", "batch", "cache_seq", "kv"),
}

_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "frames": ("batch", "seq", "act_embed"),
    "patches": ("batch", "seq", "act_embed"),
}


def input_axes(cfg: ModelConfig, shape: ShapeSpec):
    """Logical-axes pytree matching ``input_specs`` structure."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out["cache"] = {ck: _CACHE_AXES[ck] for ck in v}
        else:
            out[k] = _BATCH_AXES[k]
    return out

"""llava-next-34b [vlm] — decoder-only LM backbone; anyres tiling enters as
more precomputed patch embeddings via the STUB frontend.
[hf:llava-hf/llava-v1.6-*; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision_patches",
    n_frontend_tokens=2880,   # anyres: 5 tiles x 576 patches
)

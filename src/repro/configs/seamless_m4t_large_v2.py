"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone, MHA kv=16.
The speech frontend is a STUB: ``input_specs`` hands in precomputed frame
embeddings (B, S_enc, d_model). [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,    # encoder layers (24L each side)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio_frames",
    act="gelu",
)

"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    SHAPES,
    ShapeSpec,
    cache_specs,
    effective_cache_len,
    input_axes,
    input_specs,
    shape_applicable,
)

# arch-id (CLI form, dashed) -> module name
_ARCH_MODULES: Dict[str, str] = {
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-3-2b": "granite_3_2b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen3-4b": "qwen3_4b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-34b": "llava_next_34b",
    "hymba-1.5b": "hymba_1_5b",
    # the paper's own workload (agent decision model)
    "dcache-agent-150m": "dcache_agent_150m",
}

ARCH_IDS: List[str] = [a for a in _ARCH_MODULES if a != "dcache-agent-150m"]
ALL_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG

"""dcache-agent-150m — the paper's own workload: a small tool-calling agent
LM served by ``repro.serving`` and used as the ``JaxLLM`` decision model in
examples/tests (trainable on CPU at reduced size)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dcache-agent-150m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=32768,
    tie_embeddings=True,
)

"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay WKV recurrence.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # 64 WKV heads of dim 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,          # channel-mix hidden
    vocab_size=65536,
    ssm=SSMConfig(state_size=64, head_dim=64, conv_width=0, kind="rwkv6"),
    act="gelu",          # rwkv channel-mix uses squared relu; see models/rwkv.py
)

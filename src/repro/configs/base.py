"""Model/shape configuration system.

Every assigned architecture is an instance of :class:`ModelConfig`; the
unified model in ``repro.models.model`` consumes only this dataclass, so
adding an architecture means adding one config file.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    # 1 = every layer is MoE, 2 = every other layer (interleaved dense/MoE)
    interleave: int = 1
    n_shared_experts: int = 0  # llama4-style always-on shared expert


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Covers RWKV6 time-mix and Mamba-style heads (hymba)."""
    state_size: int = 16          # per-head recurrent state width
    head_dim: int = 64            # SSM head dim
    conv_width: int = 4           # local conv (mamba); 0 disables
    kind: str = "rwkv6"           # "rwkv6" | "mamba"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // n_heads
    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA window (mixtral, hymba)
    attn_chunk: Optional[int] = None      # chunked local attention (llama4)
    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: fraction of heads that are SSM heads (hymba: parallel heads)
    ssm_head_ratio: float = 0.0
    # enc-dec
    n_encoder_layers: int = 0             # >0 => encoder-decoder
    # modality frontend stub: "none" | "audio_frames" | "vision_patches"
    frontend: str = "none"
    n_frontend_tokens: int = 0            # patches/frames prepended in train/prefill
    # misc
    act: str = "swiglu"                   # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # remat policy for train_step: "none" | "block" | "dots"
    remat: str = "block"
    # unroll all lax.scan loops into python loops (dry-run cost probes only:
    # XLA's cost_analysis counts a while-loop body ONCE, so per-layer costs
    # are measured from small unrolled models and extrapolated)
    unroll: bool = False
    # int8 KV cache (per-token-per-head symmetric scales) — serving
    # optimization for memory-bound decode (EXPERIMENTS.md §Perf)
    kv_quant: bool = False

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding table shards cleanly (see DESIGN §5)."""
        return pad_to_multiple(self.vocab_size, 256)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is sub-quadratic (bounded KV or O(1) state)."""
        return (
            self.attn_free
            or self.sliding_window is not None
            or self.attn_chunk is not None
        )

    @property
    def n_attn_heads(self) -> int:
        """Heads doing attention (hybrid splits heads between attn and SSM)."""
        if self.family == "hybrid":
            n_ssm = int(round(self.n_heads * self.ssm_head_ratio))
            return self.n_heads - n_ssm
        return self.n_heads

    @property
    def n_ssm_heads(self) -> int:
        if self.family == "ssm":
            return self.d_model // (self.ssm.head_dim if self.ssm else 64)
        if self.family == "hybrid":
            return self.n_heads - self.n_attn_heads
        return 0

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        """Which decoder layers are MoE layers."""
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        k = self.moe.interleave
        return tuple((i % k) == (k - 1) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim_
        qdim = self.n_attn_heads * hd
        kvdim = self.n_kv_heads * hd
        attn = d * qdim + 2 * d * kvdim + qdim * d
        if self.family == "hybrid" or self.family == "ssm":
            sd = (self.ssm.head_dim if self.ssm else 64) * self.n_ssm_heads
            st = self.ssm.state_size if self.ssm else 16
            # rwkv/mamba time-mix: in/out proj + decay/gate params
            ssm_p = 2 * d * sd + sd * d + sd * st * 2
            attn = (attn if self.family == "hybrid" else 0) + ssm_p
        n_ff_mats = 3 if self.act == "swiglu" else 2
        dense_ff = n_ff_mats * d * f
        total = 0
        mask = self.moe_layer_mask()
        for i in range(self.n_layers):
            total += attn + 2 * d  # norms
            if self.moe is not None and mask[i]:
                e = self.moe.n_experts + self.moe.n_shared_experts
                total += e * n_ff_mats * d * f + d * self.moe.n_experts
            else:
                total += dense_ff
        if self.is_encdec:
            # encoder layers: self-attn + dense ff; decoder adds cross-attn
            enc = self.n_encoder_layers * (attn + dense_ff + 2 * d)
            cross = self.n_layers * (d * qdim + 2 * d * kvdim + qdim * d)
            total += enc + cross
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k active) — for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_ff_mats = 3 if self.act == "swiglu" else 2
        per_expert = n_ff_mats * d * f
        inactive = 0
        for m in self.moe_layer_mask():
            if m:
                inactive += (self.moe.n_experts - self.moe.top_k) * per_expert
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=257,   # deliberately non-round: exercises vocab padding
            head_dim=16,
            sliding_window=8 if self.sliding_window else None,
            attn_chunk=8 if self.attn_chunk else None,
            n_encoder_layers=2 if self.is_encdec else 0,
            n_frontend_tokens=4 if self.frontend != "none" else 0,
        )
        if self.moe:
            kw["moe"] = MoEConfig(
                n_experts=min(4, self.moe.n_experts),
                top_k=min(self.moe.top_k, 2),
                interleave=self.moe.interleave,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
            )
        if self.ssm:
            st = 16 if self.ssm.kind == "rwkv6" else 8  # rwkv: st == hd
            kw["ssm"] = SSMConfig(state_size=st, head_dim=16,
                                  kind=self.ssm.kind)
        if self.family == "hybrid":
            kw["ssm_head_ratio"] = 0.5
        return dataclasses.replace(self, **kw)

"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
chunked local attention (iRoPE-style), MoE every other layer (matches the
400B-total / 17B-active naming). [hf:meta-llama/Llama-4-*; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attn_chunk=8192,
    moe=MoEConfig(n_experts=128, top_k=1, interleave=2, n_shared_experts=1),
    rope_theta=500_000.0,
)

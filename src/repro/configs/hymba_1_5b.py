"""hymba-1.5b [hybrid] — parallel attention + Mamba heads in every layer,
SWA on the attention heads, ssm_state=16. [arXiv:2411.13676; hf]

25 heads x 64 dim = 1600 = d_model. ssm_head_ratio=0.4 gives 10 SSM heads and
15 attention heads (divisible by the 5 KV heads for GQA).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    ssm=SSMConfig(state_size=16, head_dim=64, conv_width=4, kind="mamba"),
    ssm_head_ratio=0.4,
)

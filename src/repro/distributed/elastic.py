"""Elastic scaling: re-shard a logically-stored checkpoint onto a different
mesh (grow/shrink the fleet between runs, or drop a failed pod).

Checkpoints (``repro.distributed.checkpoint``) store arrays at full logical
shape; ``reshard_tree`` just lays them out on the new mesh with shardings
re-derived from the same logical axes + rules — the divisibility fallback
in ``sharding.py`` guarantees a valid placement on ANY mesh shape.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import named_sharding


def reshard_tree(values, axes_tree, mesh: Mesh, rules: Dict):
    """Place a host-side pytree onto ``mesh`` with rule-derived shardings."""
    def place(v, ax):
        arr = np.asarray(v)
        sh = named_sharding(ax, arr.shape, mesh, rules)
        return jax.device_put(arr, sh)

    return jax.tree.map(
        place, values, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(a, str) for a in x))


def mesh_transition_plan(old_shape: Dict[str, int],
                         new_shape: Dict[str, int]) -> Dict[str, str]:
    """Human-readable elastic transition summary (logged by the launcher)."""
    plan = {}
    for ax in sorted(set(old_shape) | set(new_shape)):
        o, n = old_shape.get(ax, 1), new_shape.get(ax, 1)
        if o == n:
            plan[ax] = f"keep {o}"
        elif n > o:
            plan[ax] = f"grow {o}->{n} (re-shard, {n // max(o,1)}x more slices)"
        else:
            plan[ax] = f"shrink {o}->{n} (gather + re-slice)"
    return plan

"""Fault tolerance: heartbeats, straggler detection, preemption handling.

Single-process analogues of the coordinator-side machinery a 1000-node run
needs; every piece is exercised by tests with injected failures:

* ``HeartbeatMonitor``   — per-step timing, straggler z-score detection
                           (the mitigation at scale: re-dispatch the slow
                           host's shard / exclude it at the next re-mesh);
* ``WorkerFailure``      — the injected fault; ``TrainLoop`` restores the
                           last checkpoint and retries (bounded);
* ``PreemptionGuard``    — SIGTERM-style notice -> synchronous checkpoint
                           before exit (testable by invoking the handler).
"""
from __future__ import annotations

import signal
import statistics
import threading
import time
from typing import Callable, Dict, List, Optional


class WorkerFailure(RuntimeError):
    """A (simulated) worker/node failure inside a training step."""


class HeartbeatMonitor:
    def __init__(self, window: int = 50, straggler_sigma: float = 3.0,
                 timeout_s: Optional[float] = None):
        self.window = window
        self.sigma = straggler_sigma
        self.timeout_s = timeout_s
        self.step_times: List[float] = []
        self.stragglers: List[int] = []
        self.failures: List[Dict] = []
        self.last_beat = time.monotonic()

    def record_step(self, step: int, dt: float):
        self.last_beat = time.monotonic()
        hist = self.step_times[-self.window:]
        if len(hist) >= 8:
            mu = statistics.fmean(hist)
            sd = statistics.pstdev(hist) or 1e-9
            if dt > mu + self.sigma * sd:
                self.stragglers.append(step)
        self.step_times.append(dt)

    def record_failure(self, step: int, restored: bool):
        self.failures.append({"step": step, "restored": restored,
                              "t": time.monotonic()})

    def is_straggling(self, dt: float) -> bool:
        hist = self.step_times[-self.window:]
        if len(hist) < 8:
            return False
        mu = statistics.fmean(hist)
        sd = statistics.pstdev(hist) or 1e-9
        return dt > mu + self.sigma * sd

    def healthy(self) -> bool:
        if self.timeout_s is None:
            return True
        return (time.monotonic() - self.last_beat) < self.timeout_s


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps once."""

    def __init__(self, fail_at: List[int]):
        self.fail_at = set(fail_at)
        self.fired = set()

    def __call__(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected node failure at step {step}")


class PreemptionGuard:
    """Checkpoint-on-preemption: arm a signal (or call ``notify`` directly in
    tests); the guard runs ``on_preempt`` exactly once."""

    def __init__(self, on_preempt: Callable[[], None],
                 sig: Optional[int] = None):
        self.on_preempt = on_preempt
        self._fired = threading.Event()
        if sig is not None:
            signal.signal(sig, lambda *_: self.notify())

    def notify(self):
        if not self._fired.is_set():
            self._fired.set()
            self.on_preempt()

    @property
    def preempted(self) -> bool:
        return self._fired.is_set()

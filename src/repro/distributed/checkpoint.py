"""Sharded, atomic, async checkpointing (msgpack + zstd; orbax-free).

Layout:  <dir>/step_<n>/shard_<r>.ckpt + MANIFEST.json, committed by
atomic rename of the temp directory; partial/corrupt checkpoints are
detected (manifest + per-shard blake2 digests) and skipped at restore.
``save_async`` snapshots to host memory synchronously and writes on a
background thread, so the train loop overlaps I/O with compute.

Checkpoints are *mesh-independent*: arrays are stored logically (full
shape) with their logical sharding axes, so restore can re-shard onto a
different mesh (elastic scaling — see ``repro.distributed.elastic``).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import msgpack
import numpy as np

try:                                   # zstd is optional; zlib ships with
    import zstandard                   # CPython and keeps checkpoints
except ImportError:                    # readable on minimal images
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


class _ZlibCompressor:
    def __init__(self, level: int = 6):
        self.level = level

    def compress(self, data: bytes) -> bytes:
        import zlib
        return zlib.compress(data, self.level)


def _decompress(blob: bytes) -> bytes:
    """Codec-sniffing decompress so repos written with either codec restore."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard module is unavailable")
        return zstandard.ZstdDecompressor().decompress(blob)
    import zlib
    return zlib.decompress(blob)


def _tree_to_records(tree) -> List[Dict[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    recs = []
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype == np.dtype("bfloat16"):
            payload = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            payload = arr
            dtype = arr.dtype.str
        recs.append({
            "path": jax.tree_util.keystr(path),
            "dtype": dtype,
            "shape": list(arr.shape),
            "data": payload.tobytes(),
        })
    return recs


def _records_to_leaves(recs: List[Dict[str, Any]]):
    leaves = {}
    for r in recs:
        if r["dtype"] == "bfloat16":
            arr = np.frombuffer(r["data"], np.uint16).reshape(
                r["shape"]).view(np.dtype("bfloat16"))
        else:
            arr = np.frombuffer(r["data"], np.dtype(r["dtype"])).reshape(
                r["shape"])
        leaves[r["path"]] = arr
    return leaves


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, shard_id: int = 0,
                 n_shards: int = 1):
        self.dir = directory
        self.keep = keep
        self.shard_id = shard_id
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._zc = (zstandard.ZstdCompressor(level=3)
                    if zstandard is not None else _ZlibCompressor(6))

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def available_steps(self) -> List[int]:
        steps = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_"):
                continue
            d = os.path.join(self.dir, name)
            if self._valid(d):
                steps.append(int(name.split("_")[1]))
        return steps

    def _valid(self, d: str) -> bool:
        man = os.path.join(d, "MANIFEST.json")
        if not os.path.exists(man):
            return False
        try:
            manifest = json.load(open(man))
            for shard, digest in manifest["shards"].items():
                p = os.path.join(d, shard)
                if not os.path.exists(p):
                    return False
                h = hashlib.blake2b(open(p, "rb").read(),
                                    digest_size=16).hexdigest()
                if h != digest:
                    return False
            return True
        except (json.JSONDecodeError, KeyError, OSError):
            return False

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        recs = _tree_to_records(tree)
        return self._write(step, recs)

    def save_async(self, step: int, tree) -> threading.Thread:
        recs = _tree_to_records(tree)  # synchronous host snapshot
        if self._async_thread is not None:
            self._async_thread.join()
        t = threading.Thread(target=self._write, args=(step, recs),
                             daemon=True)
        t.start()
        self._async_thread = t
        return t

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, recs) -> str:
        final = self._step_dir(step)
        tmp = final + f".tmp{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        shard_name = f"shard_{self.shard_id:04d}.ckpt"
        blob = self._zc.compress(msgpack.packb(recs, use_bin_type=True))
        with open(os.path.join(tmp, shard_name), "wb") as f:
            f.write(blob)
        digest = hashlib.blake2b(blob, digest_size=16).hexdigest()
        manifest = {"step": step, "n_shards": self.n_shards,
                    "shards": {shard_name: digest}}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: int, like=None):
        d = self._step_dir(step)
        if not self._valid(d):
            raise FileNotFoundError(f"no valid checkpoint at step {step}")
        leaves: Dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if not name.endswith(".ckpt"):
                continue
            recs = msgpack.unpackb(
                _decompress(open(os.path.join(d, name), "rb").read()),
                raw=False)
            leaves.update(_records_to_leaves(recs))
        if like is None:
            return leaves
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            if key not in leaves:
                raise KeyError(f"checkpoint missing leaf {key}")
            out.append(leaves[key])
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)

    def restore_latest(self, like=None):
        steps = self.available_steps()
        if not steps:
            return None
        # walk backwards past any corrupt tail
        for s in reversed(steps):
            try:
                return self.restore(s, like=like)
            except (FileNotFoundError, KeyError, ValueError):
                continue
        return None

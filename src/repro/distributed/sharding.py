"""Logical-axis sharding with divisibility fallback (DESIGN §5).

Every parameter / activation dimension carries a logical name; ``rules`` map
names to mesh axes. Any assignment whose dimension is not divisible by the
mesh-axis extent silently falls back to replication — this single mechanism
is what lets all 40 (arch x shape) cells compile on both production meshes
(49,155-entry vocabs, 25-head attention, 8-expert MoE on a 16-way axis...).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]  # logical axis names, one per tensor dim ("" = none)
Rule = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def single_pod_rules() -> Dict[str, Rule]:
    return {
        # weights
        "vocab": "model",
        "embed": "data",        # FSDP axis
        "mlp": "model",         # tensor parallel
        "heads": "model",       # flattened n_heads*head_dim
        "kv": "model",          # flattened n_kv_heads*head_dim
        "experts": None,
        "layers": None,
        "lora": None,
        "ssm_dim": "model",     # flattened ssm_heads*head_dim
        "ssm_state": None,
        "conv": None,
        # activations
        "batch": "data",
        "seq": None,
        "act_embed": None,
        "act_mlp": "model",
        "act_heads": "model",
        "act_kv": "model",
        "cache_seq": None,
        # MoE dispatch buffers (G,E,C,D): token-group dim in baseline
        "moe_tokens": "data",
    }


def multi_pod_rules() -> Dict[str, Rule]:
    r = single_pod_rules()
    # FSDP over all 512 chips; data parallel batch over pod x data
    r["embed"] = ("pod", "data")
    r["batch"] = ("pod", "data")
    r["moe_tokens"] = ("pod", "data")
    return r


# ---------------------------------------------------------------------------
# Hillclimb variants (EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

def expert_parallel_rules(base: Dict[str, Rule]) -> Dict[str, Rule]:
    """Expert parallelism: expert weights shard over the FSDP axis instead
    of being replicated + FSDP-gathered; tokens all-to-all into expert
    shards (dispatch buffers switch from token-sharded to expert-sharded).
    Requires n_experts %% data == 0 (divisibility fallback keeps it safe)."""
    r = dict(base)
    r["experts"] = base["embed"]   # E takes over the FSDP axis
    r["moe_tokens"] = None
    # expert weight tensors are (layers, experts, embed, mlp): "experts"
    # precedes "embed", so the one-axis-per-spec dedupe automatically drops
    # the FSDP axis from the embed dim of expert weights only.
    return r


def serve_rules(base: Dict[str, Rule]) -> Dict[str, Rule]:
    """Decode-time weight layout: pure TP for the dense weights (no per-step
    FSDP all-gather — the decode step is too small to amortise one) plus
    expert parallelism for MoE weights. Dense per-chip footprint grows to
    P_dense*2/|model|, which fits for every assigned arch."""
    r = expert_parallel_rules(base)
    r["embed"] = None          # dense weights: replicate over data, TP on model
    return r


# ---------------------------------------------------------------------------
# Spec derivation
# ---------------------------------------------------------------------------

def _axis_entry(dim: int, rule: Rule, mesh: Mesh) -> Rule:
    """Mesh assignment for one dim, dropping it if not divisible."""
    if rule is None:
        return None
    names = (rule,) if isinstance(rule, str) else tuple(rule)
    names = tuple(n for n in names if n in mesh.shape)
    if not names:
        return None
    size = 1
    for n in names:
        size *= mesh.shape[n]
    if dim % size != 0:
        # try progressively shorter prefixes before replicating
        for k in range(len(names) - 1, 0, -1):
            sz = 1
            for n in names[:k]:
                sz *= mesh.shape[n]
            if dim % sz == 0:
                return names[:k] if k > 1 else names[0]
        return None
    return names if len(names) > 1 else names[0]


def logical_to_spec(axes: Axes, shape: Sequence[int], mesh: Mesh,
                    rules: Dict[str, Rule]) -> P:
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} rank != shape {tuple(shape)} rank")
    entries, used = [], set()
    for dim, name in zip(shape, axes):
        e = _axis_entry(dim, rules.get(name), mesh) if name else None
        # a mesh axis may appear at most once in a PartitionSpec
        if e is not None:
            flat = (e,) if isinstance(e, str) else e
            if any(f in used for f in flat):
                e = None
            else:
                used.update(flat)
        entries.append(e)
    return P(*entries)


def named_sharding(axes: Axes, shape: Sequence[int], mesh: Mesh,
                   rules: Dict[str, Rule]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules: Dict[str, Rule]):
    """Map (axes pytree, ShapeDtypeStruct pytree) -> NamedSharding pytree."""
    return jax.tree.map(
        lambda ax, s: named_sharding(ax, s.shape, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, str) for a in x),
    )


# ---------------------------------------------------------------------------
# Activation-constraint context (no-op outside a mesh context)
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, Rule]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Dict[str, Rule]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def constrain(x: jax.Array, axes: Axes) -> jax.Array:
    """``with_sharding_constraint`` under the active context; identity if none."""
    if _CTX.mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, _CTX.mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))

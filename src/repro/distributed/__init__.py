from repro.distributed.checkpoint import Checkpointer  # noqa: F401
from repro.distributed.elastic import mesh_transition_plan, reshard_tree  # noqa: F401
from repro.distributed.fault_tolerance import (  # noqa: F401
    FailureInjector,
    HeartbeatMonitor,
    PreemptionGuard,
    WorkerFailure,
)
from repro.distributed.sharding import (  # noqa: F401
    constrain,
    logical_to_spec,
    multi_pod_rules,
    named_sharding,
    sharding_context,
    single_pod_rules,
    tree_shardings,
)

"""Benchmark driver: one function per paper table + system micro-benches.

    PYTHONPATH=src python -m benchmarks.run            # standard (fast)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale 1k tasks
    PYTHONPATH=src python -m benchmarks.run --parallel # cells on a thread pool
    PYTHONPATH=src python -m benchmarks.run --json BENCH_dcache.json

Prints CSV (``name,value,derived``-style rows per table) and a summary
comparing the reproduction against the paper's headline claims. ``--json``
additionally writes a machine-readable record (wall-time, simulated-time
and speedup metrics per table) so the perf trajectory is tracked across
PRs — see benchmarks/README.md for the schema.
"""
from __future__ import annotations

import argparse
import json
import platform
import time


def _csv_field(rows, prefix, field_idx, row_field=None, cast=float):
    """Pull one field out of a table's CSV rows (summary extraction)."""
    for r in rows:
        cells = r.split(",")
        if cells[0] == prefix and (row_field is None or row_field(cells)):
            try:
                return cast(cells[field_idx])
            except (ValueError, IndexError):
                return None
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 1000 tasks (Table I), 500 (ablations)")
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the jax serving/kernel micro-benches")
    ap.add_argument("--parallel", action="store_true",
                    help="run independent benchmark cells on a thread pool "
                         "(numbers are unchanged; cells are deterministic)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write wall/sim/speedup metrics per table as "
                         "JSON (perf trajectory tracking across PRs)")
    ap.add_argument("--profile", action="store_true",
                    help="include cumulative per-phase mechanism counters "
                         "(engine events, coalesced generator steps, sketch "
                         "touches/flushes, task-memo hits, ...) in the JSON "
                         "record")
    ap.add_argument("--pr3-grid", action="store_true",
                    help="run exactly the PR-3 benchmark grid (no ISSUE-4 "
                         "scale/adaptive/cost/replication cells) — the "
                         "wall-budget and digest-lock reference")
    args = ap.parse_args()

    if args.json:
        with open(args.json, "a"):    # fail fast on an unwritable path,
            pass                      # not after minutes of benchmarking

    n1 = 1000 if args.full else 300
    n23 = 500 if args.full else 200
    conc_tasks = 50 if args.full else 25

    from benchmarks import tables
    from repro.core import profiling

    t0 = time.time()
    sections = []

    def section(sid, title, fn, **kw):
        s0 = time.time()
        p0 = profiling.snapshot()
        rows = fn(**kw)
        sec = {"id": sid, "name": title,
               "wall_s": round(time.time() - s0, 3), "rows": rows}
        if args.profile:
            sec["profile"] = profiling.delta(p0, profiling.snapshot())
        sections.append(sec)

    print(f"# LLM-dCache benchmarks (n_table1={n1}, n_ablation={n23})",
          flush=True)

    par = args.parallel
    section("table1", "Table I (models x prompting, +/- dCache)",
            tables.table1, n=n1, parallel=par)
    section("table2", "Table II (reuse rates & policies)",
            tables.table2, n=n23, parallel=par)
    section("table3", "Table III (GPT-driven vs programmatic)",
            tables.table3, n=n23, parallel=par)
    pr3 = args.pr3_grid
    section("concurrency", "Concurrency (N sessions on the shared pod cache)",
            tables.table_concurrency, tasks_per_session=conc_tasks,
            parallel=par, **({"scale": ()} if pr3 else {}))
    section("prefetch", "Async prefetch (lazy vs plan-time pod loads)",
            tables.table_prefetch, tasks_per_session=conc_tasks,
            parallel=par, adaptive=not pr3)
    section("admission", "Cross-session admission (TinyLFU vs install-all)",
            tables.table_admission, tasks_per_session=conc_tasks,
            parallel=par, extras=not pr3, scan_adaptive=not pr3)
    if not pr3:
        section("replication",
                "Hot-key replication (epoch + spill, zipf-global)",
                tables.table_replication, tasks_per_session=conc_tasks,
                parallel=par)
        section("locality",
                "Session->pod affinity (cross-pod read penalty sweep)",
                tables.table_locality, tasks_per_session=conc_tasks,
                parallel=par)
        section("resilience",
                "Fault-injected elastic fleet (failover + recovery)",
                tables.table_resilience, tasks_per_session=conc_tasks,
                parallel=par)
        section("capacity",
                "Open-loop capacity sweep (Poisson arrivals, SLO knee)",
                tables.table_capacity, parallel=par)
        section("coherence",
                "Mutable data plane (write streams x coherence policies)",
                tables.table_coherence, parallel=par)
        section("llmfault",
                "Decision-plane resilience (endpoint faults x mitigation)",
                tables.table_llmfault, parallel=par)
        section("plancache",
                "Plan-cache tier (repeat-share x impl, faulted regime)",
                tables.table_plancache, parallel=par)
    section("belady", "Beyond-paper: Belady oracle bound",
            tables.belady_bound, n=n23)

    if not args.skip_jax:
        from benchmarks import serving_bench
        section("serving", "Serving engine (CPU wall-time)",
                serving_bench.bench_serving)
        section("cache_ops", "Cache ops", serving_bench.bench_cache_ops)
        section("kernels", "Kernels (interpret mode)",
                serving_bench.bench_kernels)

    for sec in sections:
        print(f"\n## {sec['name']}  [{sec['wall_s']}s]")
        for r in sec["rows"]:
            print(r)
    total_wall = time.time() - t0
    print(f"\n# done in {total_wall:.1f}s")

    if args.json:
        by_id = {s["id"]: s["rows"] for s in sections}
        t1_rows = by_id.get("table1", [])
        conc_rows = by_id.get("concurrency", [])
        conc = [r.split(",") for r in conc_rows if r.startswith("concurrency")]
        conc_max = max(conc, key=lambda c: int(c[1])) if conc else None
        pf_all = [r.split(",") for r in by_id.get("prefetch", [])
                  if r.startswith("prefetch,")]
        pf_rows = [c for c in pf_all if c[3] == "prefetch"]
        pf_adaptive = {(int(c[1]), int(c[2])): c for c in pf_all
                       if c[3] == "adaptive"}
        # the <=2:1 grid rows (8 pods) vs the 4:1 saturation row (4 pods)
        pf_grid = [c for c in pf_rows if int(c[2]) == 8]
        pf_max = max(pf_grid, key=lambda c: int(c[1])) if pf_grid else None
        pf_sat = next((c for c in pf_rows
                       if int(c[1]) == 16 and int(c[2]) == 4), None)
        adm_rows = [r.split(",") for r in by_id.get("admission", [])
                    if r.startswith("admission,")]
        adm_cell = {c[4]: c for c in adm_rows
                    if c[1] == "working-low" and c[2] == "16"}
        adm_256 = {c[4]: c for c in adm_rows
                   if c[1] == "working-low" and c[2] == "256"}
        adm_wide = {c[4]: c for c in adm_rows if c[1] == "sized-wide"}
        rep_rows = [r.split(",") for r in by_id.get("replication", [])
                    if r.startswith("replication,")]
        rep_cell = {c[4]: c for c in rep_rows if c[2] == "16"}
        loc_rows = [r.split(",") for r in by_id.get("locality", [])
                    if r.startswith("locality,")]
        # headline cell: 16 sessions / 4 pods at each penalty, by config
        loc_cell = {(float(c[4]), c[5]): c for c in loc_rows
                    if c[2] == "16"}
        loc_256 = {(float(c[4]), c[5]): c for c in loc_rows
                   if c[2] == "256"}
        res_rows = [r.split(",") for r in by_id.get("resilience", [])
                    if r.startswith("resilience,")]
        # acceptance cells: the single-pod fail+restore fault at seeds 1-3,
        # replication off vs on — mean hit-EWMA recovery time
        def _res_mean_recovery(config):
            vals = [float(c[22]) for c in res_rows
                    if c[4] == "single" and c[5] == config]
            return round(sum(vals) / len(vals), 3) if vals else None
        res_llm = next((c for c in res_rows if c[5] == "rec-llm"), None)
        res_auto = next((c for c in res_rows if c[4] == "autoscale"), None)
        cap_all = [r.split(",") for r in by_id.get("capacity", [])]
        cap_rows = [c for c in cap_all if c[0] == "capacity"]
        cap_knee = {c[2]: (float(c[3]) if c[3] else None)
                    for c in cap_all if c[0] == "capacity_knee"}
        cap_arr = [c for c in cap_all if c[0] == "capacity_arrival"]
        coh_rows = [r.split(",") for r in by_id.get("coherence", [])
                    if r.startswith("coherence,")]
        # headline cell: update_heavy at the base write rate, by policy
        coh_cell = {c[4]: c for c in coh_rows
                    if c[1] == "update_heavy" and float(c[5]) == 0.2}
        llf_rows = [r.split(",") for r in by_id.get("llmfault", [])
                    if r.startswith("llmfault,")]
        llf_cell = {(c[4], c[5]): c for c in llf_rows}
        pc_rows = [r.split(",") for r in by_id.get("plancache", [])
                   if r.startswith("plancache,")]
        # cells keyed (regime, repeat_pct, impl)
        pc_cell = {(c[4], c[5], c[6]): c for c in pc_rows}
        # scan-resistant admission rows (ISSUE-9 carried follow-up)
        adm_scan = {c[4]: c for c in adm_rows
                    if c[1] == "scan" and c[2] == "16"}
        adm_z11 = {c[4]: c for c in adm_rows
                   if c[1] == "zipf-1.1" and c[2] == "16"}

        def _coh_share_monotone_ok():
            """1 when the serve-stale stale-read share is non-decreasing
            in the mutation rate (update_heavy stale20 rows, all rates)."""
            if not coh_rows:
                return None
            pts = sorted((float(c[5]), float(c[16])) for c in coh_rows
                         if (c[1], c[4]) == ("update_heavy", "stale20"))
            return int(all(pts[i][1] <= pts[i + 1][1] + 1e-12
                           for i in range(len(pts) - 1)))

        def _cap_monotone_ok():
            """1 when every config's SLO attainment is non-increasing in
            the offered rate (rows are emitted in sweep order)."""
            if not cap_rows:
                return None
            by_cfg = {}
            for c in cap_rows:
                by_cfg.setdefault(c[2], []).append(float(c[12]))
            return int(all(
                all(f[i] >= f[i + 1] - 1e-12 for i in range(len(f) - 1))
                for f in by_cfg.values()))
        record = {
            "schema": "bench_dcache/v9",
            "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "platform": {"python": platform.python_version(),
                         "machine": platform.machine()},
            "args": {"full": args.full, "skip_jax": args.skip_jax,
                     "parallel": args.parallel, "pr3_grid": args.pr3_grid,
                     "n_table1": n1, "n_ablation": n23},
            "total_wall_s": round(total_wall, 3),
            "sections": [{"id": s["id"], "name": s["name"],
                          "wall_s": s["wall_s"],
                          "n_rows": len(s["rows"])} for s in sections],
            "summary": {
                "table1_mean_sim_speedup": _csv_field(
                    t1_rows, "table1_summary", 2),
                "table1_dcache_mean_sim_time_s": _mean_sim_time(t1_rows),
                "concurrency_max_sessions": (int(conc_max[1])
                                             if conc_max else None),
                "concurrency_p95_latency_s": (float(conc_max[5])
                                              if conc_max else None),
                "concurrency_stall_total_s": (float(conc_max[9])
                                              if conc_max else None),
                "concurrency_local_hit_pct": (float(conc_max[13])
                                              if conc_max else None),
                "prefetch_max_sessions": (int(pf_max[1]) if pf_max else None),
                "prefetch_p95_latency_s": (float(pf_max[5])
                                           if pf_max else None),
                "prefetch_p95_speedup": (float(pf_max[15])
                                         if pf_max else None),
                "prefetch_overlap_s": (float(pf_max[13]) if pf_max else None),
                # 4:1 saturation cell (16 sessions / 4 pods): the
                # queueing-aware budget must keep this >= 1.0
                "prefetch_p95_speedup_4to1": (float(pf_sat[15])
                                              if pf_sat else None),
                # admission headline (working-set low reuse, 16 sessions /
                # 4 pods): baseline vs TinyLFU local-hit % and p95
                "admission_base_local_hit_pct": _adm(adm_cell, "none", 6),
                "admission_tinylfu_local_hit_pct": _adm(adm_cell, "tinylfu",
                                                        6),
                "admission_base_p95_s": _adm(adm_cell, "none", 8),
                "admission_tinylfu_p95_s": _adm(adm_cell, "tinylfu", 8),
                "admission_bypassed": _adm(adm_cell, "tinylfu", 11,
                                           cast=int),
                "admission_llm_agreement_pct": _adm(adm_cell, "llm-tinylfu",
                                                    13),
                # ISSUE-4 scale cells (batched sketch + de-Pythonized loop)
                "admission_256_local_hit_pct": _adm(adm_256, "tinylfu", 6),
                "admission_256_p95_s": _adm(adm_256, "tinylfu", 8),
                # cost-aware ablation on the widened 10-208 MB band
                "admission_cost_hit_delta_pp": _adm(adm_wide, "tinylfu-cost",
                                                    16),
                # adaptive depth guard: the recovered 8/8 mid-range win and
                # the held 4:1 saturation cell
                "prefetch_adaptive_p95_speedup_8_8": (
                    float(pf_adaptive[(8, 8)][15])
                    if (8, 8) in pf_adaptive else None),
                "prefetch_adaptive_p95_speedup_4to1": (
                    float(pf_adaptive[(16, 4)][15])
                    if (16, 4) in pf_adaptive else None),
                # hot-key replication, 16 sessions / 4 pods zipf-global:
                # vs the same-admission baseline of the cell
                "replication_hit_delta_pp": _adm(rep_cell, "tinylfu+repl",
                                                 18),
                "replication_p95_speedup": _adm(rep_cell, "tinylfu+repl",
                                                17),
                "replication_vs_none_hit_delta_pp": _adm(rep_cell, "repl",
                                                         18),
                "replication_llm_agreement_pct": _adm(rep_cell, "llm-repl",
                                                      15),
                # session->pod affinity (ISSUE 5): the 16/4 penalty-2x
                # acceptance cell — replication must beat
                # install-everything by >1.07x p95, with the win carried
                # by remote-read-share conversion
                "locality_base_p95_2x_s": _adm(loc_cell, (2.0, "none"), 12),
                "locality_repl_p95_speedup_2x": _adm(loc_cell,
                                                     (2.0, "repl"), 17),
                "locality_repl_p95_speedup_4x": _adm(loc_cell,
                                                     (4.0, "repl"), 17),
                "locality_base_remote_read_pct_2x": _adm(loc_cell,
                                                         (2.0, "none"), 7),
                "locality_repl_remote_read_pct_2x": _adm(loc_cell,
                                                         (2.0, "repl"), 7),
                "locality_repl_hit_delta_pp_2x": _adm(loc_cell,
                                                      (2.0, "repl"), 18),
                "locality_llm_agreement_pct": _adm(loc_cell,
                                                   (2.0, "llm-repl"), 15),
                "locality_256_repl_p95_speedup": _adm(loc_256,
                                                      (2.0, "repl"), 17),
                # fault-injected fleet (ISSUE 6): hit-EWMA recovery time
                # after the worst-case single-pod failure, mean over seeds
                # 1-3 — replication-on must be measurably shorter
                "resilience_recovery_s_repl_off": _res_mean_recovery(
                    "repl-off"),
                "resilience_recovery_s_repl_on": _res_mean_recovery(
                    "repl-on"),
                # zero-stall-forever gate: total unfinished sessions
                # across every fault-matrix cell (must be 0)
                "resilience_incomplete_total": (
                    sum(int(c[32]) for c in res_rows) if res_rows else None),
                "resilience_llm_agreement_pct": (float(res_llm[29])
                                                 if res_llm else None),
                "resilience_autoscale_actions": (int(res_auto[31])
                                                 if res_auto else None),
                # open-loop capacity sweep (ISSUE 7): max sustainable
                # Poisson arrival rate per config under the p99 SLO. The
                # headline is the tinylfu:base knee ratio — admission is
                # a CAPACITY feature under offered load
                "capacity_slo_p99_s": (float(cap_rows[0][4])
                                       if cap_rows else None),
                "capacity_knee_base_sps": cap_knee.get("base"),
                "capacity_knee_tinylfu_sps": cap_knee.get("tinylfu"),
                "capacity_knee_repl_sps": cap_knee.get("repl"),
                "capacity_knee_sticky2x_sps": cap_knee.get("sticky2x"),
                # queueing locks aggregated over every swept cell: flow
                # imbalance (spawned - completed - in_system, must be 0),
                # unfinished sessions (must be 0), and SLO-attainment
                # monotonicity per config (must be 1)
                "capacity_flow_imbalance_total": (
                    sum(int(c[5]) - int(c[6]) - int(c[7])
                        for c in cap_rows) if cap_rows else None),
                "capacity_incomplete_total": (
                    sum(int(c[17]) for c in cap_rows)
                    if cap_rows else None),
                "capacity_slo_monotone_ok": _cap_monotone_ok(),
                # non-Poisson arrival axes (ISSUE 8 satellite): the same
                # flow-balance and zero-incomplete gates on the diurnal
                # and MMPP rows
                "capacity_arrival_flow_imbalance_total": (
                    sum(int(c[5]) - int(c[6]) - int(c[7])
                        for c in cap_arr) if cap_arr else None),
                "capacity_arrival_incomplete_total": (
                    sum(int(c[17]) for c in cap_arr) if cap_arr else None),
                # mutable data plane (ISSUE 8): stale reads under
                # write-invalidate summed over every cell (must be 0),
                # the GPT-driven serve-stale headline (update_heavy llm
                # vs wi p95, must be > 1 at a bounded stale share), the
                # graded agreement of the cache_update verdicts, and the
                # stale-share-monotone-in-write-rate lock (must be 1)
                "coherence_wi_stale_reads_total": (
                    sum(int(c[12]) for c in coh_rows if c[4] == "wi")
                    if coh_rows else None),
                "coherence_mutations_total": (
                    sum(int(c[9]) for c in coh_rows) if coh_rows else None),
                "coherence_headline_p95_speedup": _adm(coh_cell, "llm", 20),
                "coherence_headline_stale_share_pct": _adm(coh_cell, "llm",
                                                           16),
                "coherence_llm_agreement_pct": _adm(coh_cell, "llm", 18),
                "coherence_stale20_max_staleness_s": _adm(coh_cell,
                                                          "stale20", 17),
                "coherence_share_monotone_ok": _coh_share_monotone_ok(),
                # decision-plane resilience (ISSUE 9): the no-fault
                # baseline p95 and the mixed-regime (10% staggered
                # outages + 8x straggler) p95 ratio per mitigation tier —
                # the headline is breaker-fallback holding <= ~1.1x while
                # naive retry degrades far worse
                "llmfault_base_p95_s": _adm(llf_cell, ("none", "naive"), 20),
                "llmfault_mixed_naive_p95_vs_base": _adm(
                    llf_cell, ("mixed", "naive"), 21),
                "llmfault_mixed_hedge_p95_vs_base": _adm(
                    llf_cell, ("mixed", "hedge"), 21),
                "llmfault_mixed_breaker_p95_vs_base": _adm(
                    llf_cell, ("mixed", "breaker"), 21),
                # blackout cell: the decision plane is gone — cache-op
                # decisions degrade to the programmatic twin instead of
                # stalling (structural never-stall-forever)
                "llmfault_blackout_breaker_degraded": _adm(
                    llf_cell, ("blackout", "breaker"), 13, cast=int),
                "llmfault_blackout_breaker_fallback_share_pct": _adm(
                    llf_cell, ("blackout", "breaker"), 14),
                "llmfault_flaky_parse_fallbacks": _adm(
                    llf_cell, ("flaky", "breaker"), 12, cast=int),
                "llmfault_breaker_adm_agreement_pct": _adm(
                    llf_cell, ("mixed", "breaker"), 18),
                # zero-stall gate across the whole regime x tier matrix
                "llmfault_incomplete_total": (
                    sum(int(c[22]) for c in llf_rows) if llf_rows else None),
                # scan-resistant admission (carried follow-up): the gated
                # variant must close most of the install-all-vs-TinyLFU
                # hit gap on the scan sweep without giving back the
                # TinyLFU win on zipf
                "admission_scan_base_local_hit_pct": _adm(adm_scan, "none",
                                                          6),
                "admission_scan_tinylfu_local_hit_pct": _adm(
                    adm_scan, "tinylfu", 6),
                "admission_scan_gated_local_hit_pct": _adm(
                    adm_scan, "scan-tinylfu", 6),
                "admission_zipf_gated_hit_delta_pp": _adm(
                    adm_z11, "scan-tinylfu", 16),
                # plan-cache tier (ISSUE 10): repeat-heavy hit rate, token
                # cut at p95 parity on the clean regime, and the faulted
                # headline — hits restore p95 toward the no-fault baseline
                # under the mixed outage+straggler regime at the
                # retry-only tier (p95_vs_off strictly < 1.0)
                "plancache_repeat60_hit_rate_pct": _adm(
                    pc_cell, ("none", "60", "python"), 9),
                "plancache_repeat60_p95_vs_off": _adm(
                    pc_cell, ("none", "60", "python"), 23),
                "plancache_repeat60_fleet_tokens": _adm(
                    pc_cell, ("none", "60", "python"), 19, cast=int),
                "plancache_repeat60_off_fleet_tokens": _adm(
                    pc_cell, ("none", "60", "off"), 19, cast=int),
                "plancache_zero_repeat_hits": _adm(
                    pc_cell, ("none", "0", "python"), 8, cast=int),
                "plancache_mixed_off_p95_s": _adm(
                    pc_cell, ("mixed", "60", "off"), 22),
                "plancache_mixed_python_p95_vs_off": _adm(
                    pc_cell, ("mixed", "60", "python"), 23),
                "plancache_mixed_llm_p95_vs_off": _adm(
                    pc_cell, ("mixed", "60", "llm"), 23),
                "plancache_llm_agreement_pct": _adm(
                    pc_cell, ("none", "60", "llm"), 16),
                "plancache_llm_tokens": _adm(
                    pc_cell, ("none", "60", "llm"), 17, cast=int),
                # zero-stale gate across every cell (measured, not trusted)
                "plancache_stale_served_total": (
                    sum(int(c[15]) for c in pc_rows) if pc_rows else None),
            },
        }
        if args.profile:
            record["profile"] = {
                s["id"]: s.get("profile", {}) for s in sections}
            record["profile"]["cumulative"] = {
                k: round(v, 6)
                for k, v in sorted(profiling.COUNTERS.items())}
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}")


def _adm(cell_by_admission, admission, idx, cast=float):
    """Pull one field from the admission headline cell's row for the given
    admission mode (None when the row is missing)."""
    row = cell_by_admission.get(admission)
    if row is None:
        return None
    try:
        return cast(row[idx])
    except (ValueError, IndexError):
        return None


def _mean_sim_time(t1_rows) -> float:
    """Mean simulated per-task latency across Table I's dCache-on cells."""
    vals = [float(r.split(",")[11]) for r in t1_rows
            if r.startswith("table1,") and r.split(",")[4] == "on"]
    return round(sum(vals) / len(vals), 4) if vals else None


if __name__ == "__main__":
    main()

"""Benchmark driver: one function per paper table + system micro-benches.

    PYTHONPATH=src python -m benchmarks.run            # standard (fast)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale 1k tasks

Prints CSV (``name,value,derived``-style rows per table) and a summary
comparing the reproduction against the paper's headline claims.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 1000 tasks (Table I), 500 (ablations)")
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the jax serving/kernel micro-benches")
    args = ap.parse_args()

    n1 = 1000 if args.full else 300
    n23 = 500 if args.full else 200

    from benchmarks import tables

    t0 = time.time()
    sections = []
    print(f"# LLM-dCache benchmarks (n_table1={n1}, n_ablation={n23})",
          flush=True)

    sections.append(("Table I (models x prompting, +/- dCache)",
                     tables.table1(n=n1)))
    sections.append(("Table II (reuse rates & policies)",
                     tables.table2(n=n23)))
    sections.append(("Table III (GPT-driven vs programmatic)",
                     tables.table3(n=n23)))
    sections.append(("Beyond-paper: Belady oracle bound",
                     tables.belady_bound(n=n23)))

    if not args.skip_jax:
        from benchmarks import serving_bench
        sections.append(("Serving engine (CPU wall-time)",
                         serving_bench.bench_serving()))
        sections.append(("Cache ops", serving_bench.bench_cache_ops()))
        sections.append(("Kernels (interpret mode)",
                         serving_bench.bench_kernels()))

    for title, rows in sections:
        print(f"\n## {title}")
        for r in rows:
            print(r)
    print(f"\n# done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Roofline report: renders the dry-run JSON into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.roofline [dryrun_results.json]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def render(results: List[Dict], mesh: str = "16x16") -> List[str]:
    rows = ["arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
            "t_memory_upper_s,bottleneck,useful_flop_ratio,"
            "roofline_fraction"]
    for c in results:
        if c.get("mesh") != mesh:
            continue
        if "skipped" in c:
            rows.append(f"{c['arch']},{c['shape']},{mesh},,,,,"
                        f"SKIP({c['skipped'][:40]}),,")
            continue
        if "error" in c:
            rows.append(f"{c['arch']},{c['shape']},{mesh},,,,,ERROR,,")
            continue
        tc, tm, tl = (c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
        tmu = c.get("t_memory_upper_s", 0.0)
        # roofline fraction: useful-compute time / achievable step time
        # (bound = max of the three terms; fraction = t_useful / bound)
        t_useful = c["model_flops_per_chip"] / 197e12
        bound = max(tc, tm, tl)
        frac = t_useful / bound if bound else 0.0
        rows.append(
            f"{c['arch']},{c['shape']},{mesh},{tc:.4g},{tm:.4g},{tl:.4g},"
            f"{tmu:.4g},{c['bottleneck']},{c['useful_flop_ratio']:.3f},"
            f"{frac:.3f}")
    return rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    results = json.load(open(path))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n# mesh {mesh}")
        for r in render(results, mesh):
            print(r)


if __name__ == "__main__":
    main()

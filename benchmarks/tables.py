"""Paper-table benchmarks (Tables I-III) on the GeoLLM-Engine sim.

Each function returns a list of CSV rows; ``benchmarks.run`` drives them.

Perf notes: benchmark cells are independent, seeded, and deterministic, so
(a) the task sets (including gold answers + model-check) are memoised per
(n, reuse, seed) and shared across cells — a cell re-runs the *agent*, not
the workload generator; (b) root GeoFrames are shared process-wide via the
datastore's frame memo; (c) with ``parallel=True`` the cells of a table run
on a thread pool (row order, and every number, is unchanged).
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.agent import build_runtime, build_tasks, run_episode
from repro.core.faults import FaultPlan

# paper reference numbers for the summary comparison
PAPER_MEAN_SPEEDUP = 1.24
PAPER_SPEEDUP_RANGE = (1.15, 1.33)
PAPER_GPT_HIT = (0.962, 0.977)

_TASK_MEMO: Dict[tuple, list] = {}


def _tasks(n: int, reuse: float, seed: int = 1) -> list:
    """Shared, gold-annotated task sets (immutable once built)."""
    key = (n, reuse, seed)
    if key not in _TASK_MEMO:
        from repro.agent.geollm.datastore import GeoDataStore
        from repro.agent.geollm.simclock import SimClock
        _TASK_MEMO[key] = build_tasks(n, reuse_rate=reuse, seed=seed,
                                      store=GeoDataStore(SimClock()))
    return _TASK_MEMO[key]


def _run_cells(cells: Sequence[Callable[[], object]],
               parallel: bool = False) -> List[object]:
    """Evaluate independent cell thunks, optionally on a thread pool.
    Results come back in input order either way."""
    if not parallel or len(cells) <= 1:
        return [c() for c in cells]
    workers = min(len(cells), os.cpu_count() or 4)
    with ThreadPoolExecutor(max_workers=workers) as ex:
        return list(ex.map(lambda c: c(), cells))


def _cell(model, prompting, few_shot, use_cache, *, n, reuse=0.8, seed=0,
          policy="lru", read_impl="llm", update_impl="llm"):
    rt = build_runtime(model=model, prompting=prompting, few_shot=few_shot,
                       use_cache=use_cache, seed=seed, policy=policy,
                       read_impl=read_impl, update_impl=update_impl)
    return rt.run_and_evaluate(_tasks(n, reuse))


def table1(n: int = 300, parallel: bool = False) -> List[str]:
    """Models x prompting x shot, with/without LLM-dCache."""
    rows = ["table,model,prompting,few_shot,dcache,success,correctness,"
            "obj_det_f1,lcc_recall,vqa_rouge,avg_tokens,avg_time_s,speedup"]
    grid = [(model, prompting, fs)
            for model in ("gpt-3.5-turbo", "gpt-4-turbo")
            for prompting in ("cot", "react")
            for fs in (False, True)]
    _tasks(n, 0.8)     # prewarm the shared set before the pool fans out
    cells = [lambda m=m, p=p, f=f, u=u: _cell(m, p, f, u, n=n)
             for (m, p, f) in grid for u in (False, True)]
    reports = _run_cells(cells, parallel)
    speedups = []
    for i, (model, prompting, fs) in enumerate(grid):
        base, dc = reports[2 * i], reports[2 * i + 1]
        sp = base.avg_time_s / dc.avg_time_s
        speedups.append(sp)
        for tag, r, s in (("off", base, ""),
                          ("on", dc, f"{sp:.2f}")):
            rows.append(
                f"table1,{model},{prompting},{int(fs)},{tag},"
                f"{r.success_rate:.4f},{r.correctness:.4f},"
                f"{r.obj_det_f1:.4f},{r.lcc_recall:.4f},"
                f"{r.vqa_rouge:.4f},{r.avg_tokens:.0f},"
                f"{r.avg_time_s:.3f},{s}")
    mean_sp = float(np.mean(speedups))
    rows.append(f"table1_summary,mean_speedup,{mean_sp:.3f},"
                f"paper={PAPER_MEAN_SPEEDUP},"
                f"in_paper_range={PAPER_SPEEDUP_RANGE[0] <= mean_sp <= PAPER_SPEEDUP_RANGE[1] + 0.05}")
    return rows


def table2(n: int = 200, parallel: bool = False) -> List[str]:
    """Reuse-rate sweep + cache-policy ablation (mini 500-query style).

    Reuse rate changes the sampled tasks themselves (more distinct keys at
    low reuse), so the no-cache baseline is re-measured per rate and the
    paper's claim is read off the per-rate speedup column."""
    rows = ["table,config,value,avg_time_s,no_cache_time_s,speedup"]
    rates = (0.0, 0.2, 0.4, 0.6, 0.8)
    pols = ("lru", "lfu", "rr", "fifo")
    if parallel:
        for rr in rates:
            _tasks(n, rr)
    cells = [lambda rr=rr, u=u: _cell("gpt-3.5-turbo", "cot", False, u,
                                      n=n, reuse=rr)
             for rr in rates for u in (False, True)]
    cells += [lambda p=p: _cell("gpt-3.5-turbo", "cot", False, True,
                                n=n, policy=p)
              for p in pols]
    reports = _run_cells(cells, parallel)
    for i, rr in enumerate(rates):
        r0, r1 = reports[2 * i], reports[2 * i + 1]
        rows.append(f"table2,reuse_rate,{rr},{r1.avg_time_s:.3f},"
                    f"{r0.avg_time_s:.3f},"
                    f"{r0.avg_time_s / r1.avg_time_s:.3f}")
    for j, pol in enumerate(pols):
        r = reports[2 * len(rates) + j]
        rows.append(f"table2,policy,{pol},{r.avg_time_s:.3f},,")
    return rows


def table3(n: int = 200, parallel: bool = False) -> List[str]:
    """GPT-driven vs programmatic cache read/update (gpt-4 CoT few-shot)."""
    rows = ["table,read_impl,update_impl,cache_hit_pct,gpt_hit_pct,success,"
            "correctness,obj_det_f1,lcc_recall,vqa_rouge,avg_tokens,"
            "avg_time_s"]
    grid = (("python", "python"), ("llm", "python"),
            ("python", "llm"), ("llm", "llm"))
    _tasks(n, 0.8)
    cells = [lambda ri=ri, ui=ui: _cell("gpt-4-turbo", "cot", True, True,
                                        n=n, read_impl=ri, update_impl=ui)
             for ri, ui in grid]
    reports = _run_cells(cells, parallel)
    for (read_impl, update_impl), r in zip(grid, reports):
        rows.append(
            f"table3,{read_impl},{update_impl},{100*r.cache_hit_rate:.2f},"
            f"{100*r.gpt_hit_rate:.2f},{r.success_rate:.4f},"
            f"{r.correctness:.4f},{r.obj_det_f1:.4f},{r.lcc_recall:.4f},"
            f"{r.vqa_rouge:.4f},{r.avg_tokens:.0f},{r.avg_time_s:.3f}")
    return rows


def table_concurrency(tasks_per_session: int = 25,
                      sessions: Sequence[int] = (1, 2, 4, 8, 16),
                      n_pods: int = 4,
                      scale: Sequence[Sequence[int]] = ((128, 16),
                                                       (256, 32)),
                      parallel: bool = False,
                      engine_kw: Dict = None) -> List[str]:
    """Beyond-paper: N concurrent sessions contending on the pod-sharded
    cache (the paper's "hundreds of GPT endpoints" regime). Latency
    percentiles are per-task simulated seconds; stalls are time spent
    queued behind another session's DB load on the same pod.

    The ``scale`` cells (128 and 256 sessions, pods scaled to keep the
    8:1 pressure of the 4-pod grid's top cell) exist because of the
    ISSUE-4 batching work — the per-clock-advance Python stepping of the
    old engine capped the default bench at 64 sessions. They run 10 tasks
    per session (the ``tasks`` column reports the total): session COUNT is
    the scaled dimension, and a shorter stream keeps the default run's
    wall budget. The original ``sessions`` x ``n_pods`` rows are
    bit-identical to PR 3 (digest-locked)."""
    rows = ["table,n_sessions,n_pods,tasks,p50_s,p95_s,mean_s,makespan_s,"
            "throughput_tps,stall_total_s,stall_per_task_s,stalled_loads,"
            "total_loads,local_hit_pct,pod_imbalance,miss_replans"]
    configs = ([(ns, n_pods, tasks_per_session) for ns in sessions]
               + [(c[0], c[1], min(10, tasks_per_session)) for c in scale])
    # engine_kw threads extra engine kwargs into every cell — the
    # degeneracy digest tests replay this table under traffic="closed"
    ekw = dict(engine_kw or {})
    cells = [lambda ns=ns, npod=npod, tps=tps: run_episode(
                 ns, tps, n_pods=npod, seed=0, **ekw)
             for ns, npod, tps in configs]
    for res in _run_cells(cells, parallel):
        m = res.metrics
        rows.append(
            f"concurrency,{m.n_sessions},{m.n_pods},{m.n_tasks},"
            f"{m.p50_task_latency_s:.3f},{m.p95_task_latency_s:.3f},"
            f"{m.mean_task_latency_s:.3f},{m.makespan_s:.3f},"
            f"{m.throughput_tasks_per_s:.4f},{m.total_stall_s:.3f},"
            f"{m.stall_per_task_s:.4f},{m.stalled_loads},{m.total_loads},"
            f"{100*m.local_hit_rate:.2f},{m.pod_load_imbalance:.3f},"
            f"{m.cache_miss_replans}")
    return rows


def table_prefetch(tasks_per_session: int = 25,
                   sessions: Sequence[int] = (1, 4, 8, 16),
                   n_pods: int = 8,
                   saturated: Sequence[Sequence[int]] = ((16, 4),),
                   adaptive: bool = True,
                   parallel: bool = False) -> List[str]:
    """Beyond-paper: lazy vs async-prefetch data plane on the event-granular
    engine. ``prefetch`` issues a session's planned ``load_db`` keys the
    moment its ReadPlan lands, overlapping DB service with the planning LLM
    round; ``lazy`` loads each key on demand after planning. Same seeds,
    same answers — only time moves. ``p95_speedup`` is lazy/prefetch p95
    task latency; ``overlap_s`` is DB service hidden behind LLM work.

    The default grid is 8 pods (sessions:pods <= 2:1, the paper's
    many-endpoint regime) plus the ``saturated`` ratio cells (16
    sessions / 4 pods = 4:1). The queueing-aware budget — consume-horizon
    + per-pod depth guard over observed service times — keeps p95 strictly
    reduced at <= 2:1 AND no worse than lazy at 4:1, where the old
    planning-latency budget shut prefetch off entirely. ``pf_skipped``
    counts planned loads the budget left lazy.

    The ``adaptive`` rows run the same cells with the ISSUE-4 adaptive
    depth guard (``prefetch_adaptive=True``): the fixed threshold is
    replaced by a proportional controller on the fleet's observed
    stall-plus-late-prefetch rate, which lifts the guard in the mid-range
    (recovering the 8/8 win the fixed guard trims) and clamps it past
    saturation. The lazy/prefetch rows are bit-identical to PR 3."""
    rows = ["table,n_sessions,n_pods,mode,p50_s,p95_s,mean_s,stall_total_s,"
            "stalled_loads,pf_issued,pf_skipped,pf_hits,pf_wait_s,overlap_s,"
            "joined_loads,p95_speedup"]
    configs = [(ns, n_pods) for ns in sessions] + [tuple(c) for c in saturated]
    # the fixed-guard mode pins prefetch_adaptive=False: since ISSUE 5 the
    # engine defaults the adaptive guard ON, and these rows are the PR-3/4
    # digest-locked fixed-guard reference
    modes = (("lazy", {}),
             ("prefetch", {"prefetch": True, "prefetch_adaptive": False}))
    if adaptive:
        modes += (("adaptive", {"prefetch": True,
                                "prefetch_adaptive": True}),)
    cells = [lambda ns=ns, npod=npod, kw=kw: run_episode(
                 ns, tasks_per_session, n_pods=npod, seed=0, **kw)
             for ns, npod in configs for _, kw in modes]
    results = _run_cells(cells, parallel)
    nm = len(modes)
    for i, (ns, npod) in enumerate(configs):
        lazy = results[nm * i].metrics
        for j, (mode, _) in enumerate(modes):
            m = results[nm * i + j].metrics
            sp = ("" if j == 0 else
                  f"{lazy.p95_task_latency_s / m.p95_task_latency_s:.3f}")
            rows.append(
                f"prefetch,{ns},{npod},{mode},{m.p50_task_latency_s:.3f},"
                f"{m.p95_task_latency_s:.3f},{m.mean_task_latency_s:.3f},"
                f"{m.total_stall_s:.3f},{m.stalled_loads},"
                f"{m.prefetch_issued},{m.prefetch_skipped},"
                f"{m.prefetch_hits},{m.prefetch_wait_s:.3f},"
                f"{m.overlap_credit_s:.3f},{m.joined_loads},{sp}")
    return rows


def table_admission(tasks_per_session: int = 25, extras: bool = True,
                    parallel: bool = False,
                    scan_adaptive: bool = False) -> List[str]:
    """Beyond-paper: cross-session cache admission on the shared pod cache.

    Every cell pairs the PR-2 baseline (``admission=None``: install every
    load) against TinyLFU admission (shared count-min frequency sketch,
    aged on sim time; rejected keys bypass without evicting residents) on
    the same seeds — answers are identical, only cache state and time move.
    The scenario column sweeps qualitatively different key-popularity
    regimes (see ``WorkloadSampler``), and the scale rows push the
    contention to 32 and 64 sessions. The headline row (working-set low
    reuse, 16 sessions / 4 pods) additionally runs the GPT-driven admission
    path (``llm-tinylfu``): the policy is described to the LLM in natural
    language and graded against the programmatic rule (``agreement_pct``).

    ``hit_delta_pp`` is the local-hit percentage-point gain over the
    baseline row of the same cell; ``p95_speedup`` is baseline p95 over
    this row's p95 (>1 = admission is faster).
    """
    rows = ["table,scenario,n_sessions,n_pods,admission,reuse,local_hit_pct,"
            "p50_s,p95_s,stall_total_s,admitted,bypassed,bypass_reads,"
            "agreement_pct,adm_tokens,p95_speedup,hit_delta_pp"]
    configs = [
        # (label, engine_kw, n_sessions, n_pods, reuse)
        ("working-low", {}, 16, 4, 0.3),
        ("zipf-1.1", {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.1}},
         16, 4, 0.3),
        ("zipf-1.5", {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.5}},
         16, 4, 0.3),
        ("scan", {"scenario": "scan"}, 16, 4, 0.3),
        ("hotspot", {"scenario": "hotspot"}, 16, 4, 0.3),
        ("working-low", {}, 32, 4, 0.3),
        ("working-low", {}, 64, 8, 0.3),
    ]
    grid = [(cfg, adm) for cfg in configs for adm in (None, "tinylfu")]
    grid.append((configs[0], "llm-tinylfu"))    # GPT-driven headline cell
    # ISSUE-4 appendix rows (the PR-3 grid above is digest-locked):
    # 128/256-session scale cells — feasible in the default run only
    # because of the batched sketch + de-Pythonized event loop (they run
    # 10 tasks/session: session count is the scaled dimension) — and the
    # cost-aware ablation on a widened frame-size band (10-208 MB), where
    # slot value = frequency x miss penalty has signal.
    if extras:
        scale_cfgs = [("working-low", {}, 128, 16, 0.3),
                      ("working-low", {}, 256, 16, 0.3)]
        grid += [(cfg, adm) for cfg in scale_cfgs
                 for adm in (None, "tinylfu")]
        wide = ("sized-wide", {"rows_range": (2_000, 40_000)}, 16, 4, 0.3)
        grid += [(wide, adm) for adm in (None, "tinylfu", "tinylfu-cost")]
    # ISSUE-9 carried follow-up: scan-resistant admission. The detector
    # tracks the EWMA of the key-vs-victim frequency balance per admit
    # call — a sequential scan (uniform popularity) sits near 0.5 and
    # opens the TinyLFU gate (install-all), skewed traffic closes it.
    # Default-off: the PR-3/PR-4 admission grid above is digest-locked.
    if scan_adaptive:
        grid += [(configs[3], "scan-tinylfu"),   # scan scenario
                 (configs[1], "scan-tinylfu")]   # zipf-1.1 control
    scale_tps = min(10, tasks_per_session)
    cells = [lambda cfg=cfg, adm=adm: run_episode(
                 cfg[2],
                 scale_tps if cfg[2] >= 128 else tasks_per_session,
                 n_pods=cfg[3], reuse_rate=cfg[4], seed=0,
                 admission=(None if adm is None else
                            adm if adm in ("tinylfu-cost", "scan-tinylfu")
                            else "tinylfu"),
                 admission_impl=("llm" if adm == "llm-tinylfu"
                                 else "python"),
                 **cfg[1])
             for cfg, adm in grid]
    results = _run_cells(cells, parallel)
    base_hit: Dict[tuple, float] = {}
    base_p95: Dict[tuple, float] = {}
    for ((label, _, ns, npod, reuse), adm), res in zip(grid, results):
        m = res.metrics
        key = (label, ns, npod)
        if adm is None:
            base_hit[key] = m.local_hit_rate
            base_p95[key] = m.p95_task_latency_s
            sp = delta = ""
        else:
            sp = f"{base_p95[key] / m.p95_task_latency_s:.3f}"
            delta = f"{100 * (m.local_hit_rate - base_hit[key]):.2f}"
        rows.append(
            f"admission,{label},{ns},{npod},{adm or 'none'},{reuse},"
            f"{100 * m.local_hit_rate:.2f},{m.p50_task_latency_s:.3f},"
            f"{m.p95_task_latency_s:.3f},{m.total_stall_s:.3f},"
            f"{m.admitted},{m.bypassed},{m.bypass_reads},"
            f"{100 * m.admission_agreement:.2f},{m.admission_tokens},"
            f"{sp},{delta}")
    return rows


def table_replication(tasks_per_session: int = 25,
                      parallel: bool = False) -> List[str]:
    """Beyond-paper: cross-pod replication of super-hot keys (ISSUE 4).

    Workload: globally-aligned zipf skew (``zipf_global=True`` — every
    session agrees on which keys are hot, the paper's
    many-endpoints-one-event regime; the per-session zipf of the admission
    table leaves the *global* popularity field nearly flat). Each cell
    pairs baselines against ``replication=True`` on the same seeds: the
    :class:`~repro.core.replication.HotKeyReplicator` promotes
    hot-but-homeless keys (epoch top-missed feed + admission-bypass spill),
    placing bounded-fanout copies where the displaced resident is globally
    coldest, and demotes by frequency hysteresis plus a usage veto.

    Row semantics: ``hit_delta_pp``/``p95_speedup`` compare each row
    against the *same-admission* baseline of its cell (tinylfu+repl vs
    tinylfu; repl-only vs none), so the replication effect is isolated
    from the admission effect. The acceptance cell is 16 sessions/4 pods:
    tinylfu+repl must hold local hits strictly above tinylfu with p95 no
    worse (install-everything+repl shows the bigger, seed-robust win:
    +2-4 hit points, p95 reduced). The ``llm-repl`` row routes every
    promote/drop/hold decision through the GPT prompt path, graded
    against the programmatic threshold rule (``agreement_pct``)."""
    rows = ["table,scenario,n_sessions,n_pods,config,local_hit_pct,p50_s,"
            "p95_s,stall_total_s,replica_hits,replica_installs,"
            "replica_drops,promotes,demotes,epochs,agreement_pct,"
            "repl_tokens,p95_speedup,hit_delta_pp"]
    zipfg = {"scenario": "zipf",
             "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}}
    # measured operating point (see repro/core/replication.py)
    rkw = {"epoch_s": 20.0, "max_replicated": 10, "promote_min": 4,
           "miss_min": 2, "gain_ratio": 2.0}
    # (config label, engine kwargs, baseline config label for deltas)
    modes = [
        ("none", {}, None),
        ("repl", {"replication": True, "replication_kw": rkw}, "none"),
        ("tinylfu", {"admission": "tinylfu"}, None),
        ("tinylfu+repl", {"admission": "tinylfu", "replication": True,
                          "replication_kw": rkw}, "tinylfu"),
        ("llm-repl", {"admission": "tinylfu", "replication": True,
                      "replication_impl": "llm", "replication_kw": rkw},
         "tinylfu"),
    ]
    configs = [(16, 4), (64, 8)]
    cells = [lambda ns=ns, npod=npod, kw=kw: run_episode(
                 ns, tasks_per_session, n_pods=npod, reuse_rate=0.3,
                 seed=0, **dict(zipfg, **kw))
             for ns, npod in configs for _, kw, _b in modes]
    results = _run_cells(cells, parallel)
    nm = len(modes)
    for i, (ns, npod) in enumerate(configs):
        base = {label: results[nm * i + j].metrics
                for j, (label, _, _b) in enumerate(modes)}
        for label, _, bline in modes:
            m = base[label]
            if bline is None:
                sp = delta = ""
            else:
                b = base[bline]
                sp = f"{b.p95_task_latency_s / m.p95_task_latency_s:.3f}"
                delta = f"{100 * (m.local_hit_rate - b.local_hit_rate):.2f}"
            rows.append(
                f"replication,zipfg-1.1,{ns},{npod},{label},"
                f"{100 * m.local_hit_rate:.2f},{m.p50_task_latency_s:.3f},"
                f"{m.p95_task_latency_s:.3f},{m.total_stall_s:.3f},"
                f"{m.replica_hits},{m.replica_installs},{m.replica_drops},"
                f"{m.replication_promotes},{m.replication_demotes},"
                f"{m.replication_epochs},"
                f"{100 * m.replication_agreement:.2f},"
                f"{m.replication_tokens},{sp},{delta}")
    return rows


def table_locality(tasks_per_session: int = 25,
                   parallel: bool = False) -> List[str]:
    """Beyond-paper: session->pod affinity with a cross-pod read penalty
    (ISSUE 5) — the consumer-side locality model that makes "localized"
    caching real.

    Workload: ``affinity_zipf`` (per-pod hot sets with 10% cross-pod
    spillover, zipf 1.8 within each group): each home pod's sessions agree
    on which keys are hot, but rendezvous hashing owns those keys on
    arbitrary pods — without placement, ~79% of all reads are served
    off-home and pay the penalty. Sessions are pinned by ``sticky``
    affinity; the headline grid (16 sessions / 4 pods) runs a DOUBLE-length
    task stream (placement is an equilibrium — the longer stream reads p95
    off the converged regime) and sweeps the penalty 1x/2x/4x with
    replication off/on; the scale rows (64/8, 256/16 at 10 tasks/session)
    hold the penalty at 2x.

    Row semantics: ``p95_speedup``/``hit_delta_pp`` compare each ``repl``
    row against the ``none`` row of the same (sessions, pods, penalty)
    cell. The acceptance cell is penalty 2x at 16/4: replication must beat
    install-everything by >1.07x p95 (the PR-4 locality-free headline),
    with the win now carried by the *local-read share* — remote reads drop
    from ~79% to ~48% of all reads because promotion feeds on consumer
    demand and placement targets the demanding home pod (locality-blind
    PR-4 replication at penalty 1x leaves the share at ~77%). The p95 win
    is NOT monotone in the penalty: hops slow consumers down, which
    decongests the pod queues of the closed-loop fleet (benchmarks/README
    documents the effect); the share conversion is monotone and is the
    paper-faithful term. ``llm-repl`` routes every decision through the
    locality-aware prompt path (home-pod demand rendered as evidence),
    graded against the programmatic rule."""
    rows = ["table,scenario,n_sessions,n_pods,penalty,config,local_hit_pct,"
            "remote_read_pct,remote_reads,remote_hop_s,link_stall_s,p50_s,"
            "p95_s,stall_total_s,replica_hits,agreement_pct,repl_tokens,"
            "p95_speedup,hit_delta_pp"]
    affz = {"scenario": "affinity_zipf",
            "scenario_kw": {"zipf_a": 1.8, "spill_p": 0.1}}
    # measured operating point (see repro/core/locality.py + tests):
    # short epochs + a permissive gate — consumer-pod copies are cheap to
    # re-place when install-everything churn evicts them
    rkw = {"epoch_s": 10.0, "max_replicated": 12, "promote_min": 3,
           "miss_min": 1, "gain_ratio": 1.2, "top_k": 12}
    modes = [
        ("none", {}, None),
        ("repl", {"replication": True, "replication_kw": rkw}, "none"),
    ]
    llm_mode = ("llm-repl", {"replication": True, "replication_impl": "llm",
                             "replication_kw": rkw}, "none")
    head_tps = 2 * tasks_per_session
    scale_tps = min(10, tasks_per_session)
    # (n_sessions, n_pods, penalty, tasks/session, mode)
    grid = [(16, 4, pen, head_tps, m)
            for pen in (1.0, 2.0, 4.0) for m in modes]
    grid.append((16, 4, 2.0, head_tps, llm_mode))
    grid += [(ns, npod, 2.0, scale_tps, m)
             for ns, npod in ((64, 8), (256, 16)) for m in modes]
    cells = [lambda ns=ns, npod=npod, pen=pen, tps=tps, kw=m[1]: run_episode(
                 ns, tps, n_pods=npod, reuse_rate=0.3, seed=0,
                 affinity="sticky", remote_read_penalty=pen,
                 **dict(affz, **kw))
             for ns, npod, pen, tps, m in grid]
    results = _run_cells(cells, parallel)
    base_hit: Dict[tuple, float] = {}
    base_p95: Dict[tuple, float] = {}
    for (ns, npod, pen, _tps, (label, _, bline)), res in zip(grid, results):
        m = res.metrics
        key = (ns, npod, pen)
        if bline is None:
            base_hit[key] = m.local_hit_rate
            base_p95[key] = m.p95_task_latency_s
            sp = delta = ""
        else:
            sp = f"{base_p95[key] / m.p95_task_latency_s:.3f}"
            delta = f"{100 * (m.local_hit_rate - base_hit[key]):.2f}"
        rows.append(
            f"locality,affz-1.8,{ns},{npod},{pen:g},{label},"
            f"{100 * m.local_hit_rate:.2f},"
            f"{100 * m.locality_remote_read_share:.2f},"
            f"{m.locality_remote_reads},{m.locality_remote_hop_s:.3f},"
            f"{m.locality_link_stall_s:.3f},{m.p50_task_latency_s:.3f},"
            f"{m.p95_task_latency_s:.3f},{m.total_stall_s:.3f},"
            f"{m.replica_hits},{100 * m.replication_agreement:.2f},"
            f"{m.replication_tokens},{sp},{delta}")
    return rows


def table_resilience(tasks_per_session: int = 20,
                     parallel: bool = False,
                     engine_kw: Dict = None) -> List[str]:
    """Beyond-paper: fault-injected elastic fleet (ISSUE 6).

    Workload: the replication table's globally-aligned zipf skew at
    ``capacity_per_pod=8`` (deeper caches mean a failure destroys real
    state — at tiny capacities every lost key self-heals on its next
    demand access and there is no transient to measure). The failed pod is
    always **pod3**: under ``zipf_global`` the hot ranking is
    seed-independent and rendezvous hashing owns the two globally hottest
    keys (plus 4 of the top 8) on pod3 — the worst-case single-pod loss.

    Fault matrix (all sim-time :class:`~repro.core.faults.FaultPlan`
    schedules): ``none`` (EMPTY plan — the degeneracy reference: the fault
    layer runs every hook yet replays the fault-free engine bit-identically,
    locked by tests/test_faults.py), ``single`` (fail pod3 @60s, restore
    @75s), ``double`` (correlated pod1+pod3 @60s, 15s downtime), ``churn``
    (periodic round-robin failures every 30s), ``elastic`` (scale pod4 out
    @40s, in @100s), and ``autoscale`` (no plan — the
    :class:`~repro.core.faults.BacklogAutoscaler` drives scale_out/in from
    the PR-4 backlog signals).

    Config axis: replication off vs on — replication uses the
    **durability feed** (``durability=True``: the sketch's global top-k is
    judged alongside the miss feed, so hot *resident* keys get copies that
    buy no latency but survive owner loss). The acceptance comparison is
    the ``single`` fault at seeds 1-3: mean hit-EWMA recovery time must be
    measurably shorter with replication on (replicas keep serving the lost
    owner's hot keys, so the post-restore re-warm transient mostly
    disappears). ``rec-thr``/``rec-llm`` rows add the post-failover
    recovery policy (threshold re-warm vs GPT-prompted re-warm/lazy per
    lost key, graded like admission/replication).

    Row semantics: ``recovery_s`` is the mean time for the fast hit-EWMA
    to regain ``recover_frac`` of the slow pre-failure baseline after
    dipping (0 when a failure never dents the hit rate); ``fo_p95_s`` vs
    ``steady_p95_s`` split task latency by whether the task ended inside a
    failure->recovery window; ``incomplete`` counts sessions that never
    finished their stream — the zero-stall-forever gate (always 0)."""
    rows = ["table,scenario,n_sessions,n_pods,fault,config,seed,"
            "local_hit_pct,p50_s,p95_s,failovers,restores,scale_outs,"
            "scale_ins,aborted,retried,timeouts,lost_keys,lost_replicas,"
            "pf_aborted,retry_wait_s,lost_work_s,recovery_s,unrecovered,"
            "fo_p95_s,steady_p95_s,replica_hits,rewarms,lazy,"
            "rec_agreement_pct,rec_tokens,autoscale_actions,incomplete"]
    zipfg = {"scenario": "zipf",
             "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}}
    # durability replication (see repro/core/replication.py): fanout 1
    # keeps the replica capacity tax low enough that the survivors'
    # caches are not crowded out during the down window
    rkw = {"epoch_s": 20.0, "max_replicated": 8, "promote_min": 4,
           "miss_min": 2, "gain_ratio": 2.0, "durability": True,
           "fanout": 1}
    pods = [f"pod{i}" for i in range(4)]
    plans = {
        "none": FaultPlan(),
        "single": FaultPlan.single("pod3", 60.0, restore_at=75.0),
        "double": FaultPlan.correlated(["pod1", "pod3"], 60.0,
                                       downtime_s=15.0),
        "churn": FaultPlan.periodic(pods, period_s=30.0, downtime_s=10.0,
                                    start_s=30.0, horizon_s=120.0),
        "elastic": FaultPlan.elastic("pod4", 40.0, in_at=100.0),
    }
    repl = {"replication": True, "replication_kw": rkw}
    auto_kw = {"autoscale": True,
               "autoscale_kw": {"check_every_s": 15.0, "high_backlog_s": 0.5,
                                "low_backlog_s": 0.05, "max_extra": 2,
                                "cooldown_s": 30.0}}
    # (fault label, config label, seed, engine kwargs)
    grid = []
    for fault, plan in plans.items():
        seeds = (1, 2, 3) if fault == "single" else (1,)
        for seed in seeds:
            grid.append((fault, "repl-off", seed, {"fault_plan": plan}))
            grid.append((fault, "repl-on", seed,
                         dict({"fault_plan": plan}, **repl)))
    grid.append(("autoscale", "repl-off", 1, dict(auto_kw)))
    single = plans["single"]
    grid.append(("single", "rec-thr", 1,
                 {"fault_plan": single, "recovery_impl": "python"}))
    grid.append(("single", "rec-llm", 1,
                 {"fault_plan": single, "recovery_impl": "llm"}))
    ekw = dict(engine_kw or {})   # degeneracy replays: traffic="closed"
    cells = [lambda seed=seed, kw=kw: run_episode(
                 16, tasks_per_session, n_pods=4, reuse_rate=0.3, seed=seed,
                 prefetch=True, capacity_per_pod=8,
                 **dict(zipfg, **dict(kw, **ekw)))
             for _f, _c, seed, kw in grid]
    results = _run_cells(cells, parallel)
    for (fault, config, seed, _kw), res in zip(grid, results):
        m = res.metrics
        rows.append(
            f"resilience,zipfg-1.1,16,4,{fault},{config},{seed},"
            f"{100 * m.local_hit_rate:.2f},{m.p50_task_latency_s:.3f},"
            f"{m.p95_task_latency_s:.3f},{m.resilience_failovers},"
            f"{m.resilience_restores},{m.resilience_scale_outs},"
            f"{m.resilience_scale_ins},{m.resilience_aborted_loads},"
            f"{m.resilience_retried_loads},{m.resilience_timeout_loads},"
            f"{m.resilience_lost_keys},{m.resilience_lost_replicas},"
            f"{m.resilience_prefetch_aborted},"
            f"{m.resilience_retry_wait_s:.3f},"
            f"{m.resilience_lost_work_s:.3f},"
            f"{m.resilience_recovery_s:.3f},{m.resilience_unrecovered},"
            f"{m.resilience_failover_p95_s:.3f},"
            f"{m.resilience_steady_p95_s:.3f},{m.replica_hits},"
            f"{m.recovery_rewarms},{m.recovery_lazy},"
            f"{100 * m.recovery_agreement:.2f},{m.recovery_tokens},"
            f"{m.autoscale_actions},{m.resilience_incomplete_sessions}")
    return rows


def table_capacity(rates: Sequence[float] = (0.1, 0.2, 0.4, 0.8),
                   horizon_s: float = 150.0, slo_p99_s: float = 10.0,
                   lifetime_tasks: int = 6, n_pods: int = 4,
                   parallel: bool = False) -> List[str]:
    """Beyond-paper: open-loop capacity sweep (ISSUE 7).

    The closed-loop tables measure a FIXED population racing to drain its
    task streams; this table measures *offered load*: Poisson session
    arrivals at ``rate_sps`` sessions/s over ``horizon_s``, each session a
    bounded ``lifetime_tasks``-task visit (spawn and retire are
    first-class scheduler events — see repro/core/traffic.py). Workload is
    the resilience table's globally-aligned zipf skew (every session
    agrees on the hot set, so cache state carries between visits — an
    open-loop system with no key reuse across sessions has no cache story
    to measure).

    Config axis — (admission, replication, affinity), the same levers as
    the closed-loop tables: ``base`` (install-everything), ``tinylfu``
    (shared-sketch admission), ``repl`` (hot-key replication), and
    ``sticky2x`` (sticky session->pod affinity at a 2x cross-pod read
    penalty). For each config the sweep reports goodput (completed
    tasks/s over the makespan), the latency tail (p50/p95/p99), and
    SLO attainment (fraction of tasks under ``slo_p99_s``); the final
    ``capacity_knee`` row per config is the **max sustainable arrival
    rate**: the largest swept rate whose p99 still meets the SLO.
    Headline (seed 1, defaults): TinyLFU admission sustains 2x the
    arrival rate of install-everything (knee 0.8/s vs 0.4/s) — under
    offered load, keeping one-shot tail keys out of the cache is a
    *capacity* feature, not just a latency one.

    Row invariants (locked by tests/test_traffic.py on every cell):
    flow balance ``spawned == completed + in_system`` with
    ``in_system == 0`` at episode end, ``incomplete == 0`` (the PR-6
    zero-stall-forever gate carried over), a Little's-law residual
    |L - lambda*W| at float precision, and ``slo_frac`` monotone
    non-increasing in the offered rate per config."""
    from repro.core.traffic import (DiurnalTraffic, MMPPTraffic,
                                    PoissonTraffic, find_knee,
                                    slo_attainment)

    if slo_p99_s <= 0.0:
        raise ValueError(f"slo_p99_s must be > 0, got {slo_p99_s}")
    rows = ["table,scenario,config,rate_sps,slo_s,spawned,completed,"
            "in_system,goodput_tps,p50_s,p95_s,p99_s,slo_frac,"
            "mean_sojourn_s,mean_in_system,little_resid,local_hit_pct,"
            "incomplete"]
    zipfg = {"scenario": "zipf",
             "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}}
    rkw = {"epoch_s": 20.0, "max_replicated": 8, "promote_min": 4,
           "miss_min": 2, "gain_ratio": 2.0}
    configs = (
        ("base", {}),
        ("tinylfu", {"admission": "tinylfu"}),
        ("repl", {"replication": True, "replication_kw": rkw}),
        ("sticky2x", {"affinity": "sticky", "remote_read_penalty": 2.0}),
    )
    grid = [(name, kw, rate) for name, kw in configs for rate in rates]
    cells = [lambda kw=kw, rate=rate: run_episode(
                 1, 25, n_pods=n_pods, reuse_rate=0.3, seed=1,
                 prefetch=True, capacity_per_pod=8,
                 traffic=PoissonTraffic(rate, horizon_s, seed=1,
                                        lifetime_tasks=lifetime_tasks),
                 **dict(zipfg, **kw))
             for _n, kw, rate in grid]
    results = _run_cells(cells, parallel)
    knees: Dict[str, List[tuple]] = {}
    for (name, _kw, rate), res in zip(grid, results):
        m = res.metrics
        lats = [tr.time_s for s in res.sessions for tr in s.traces]
        frac = slo_attainment(lats, slo_p99_s)
        knees.setdefault(name, []).append((rate, m.p99_task_latency_s))
        rows.append(
            f"capacity,zipfg-1.1,{name},{rate},{slo_p99_s},"
            f"{m.traffic_spawned},{m.traffic_completed},"
            f"{m.traffic_in_system},{m.throughput_tasks_per_s:.4f},"
            f"{m.p50_task_latency_s:.3f},{m.p95_task_latency_s:.3f},"
            f"{m.p99_task_latency_s:.3f},{frac:.4f},"
            f"{m.traffic_mean_sojourn_s:.3f},"
            f"{m.traffic_mean_in_system:.3f},"
            f"{m.traffic_little_residual:.2e},{100*m.local_hit_rate:.2f},"
            f"{m.resilience_incomplete_sessions}")
    for name, pts in knees.items():
        knee = find_knee(pts, slo_p99_s)
        rows.append(f"capacity_knee,zipfg-1.1,{name},"
                    f"{knee if knee is not None else ''},{slo_p99_s}")
    # ISSUE-8 satellite: non-Poisson arrival axes — diurnal (Lewis-Shedler
    # thinned day/night sinusoid) and 2-state MMPP bursts — on the tinylfu
    # config. Rows carry the "capacity_arrival" prefix so the committed
    # "capacity" rows, the knee rows, and the 12-cell CI capacity smoke
    # stay bit-identical; columns match the capacity rows, with the config
    # tagged by the arrival process and rate_sps reporting the process's
    # MEAN offered rate (both obey the same flow-balance and Little's-law
    # locks, applied in tests/test_coherence.py).
    arrivals = (
        ("diurnal", lambda: DiurnalTraffic(
            0.4, horizon_s, amplitude=0.8, period_s=60.0, seed=1,
            lifetime_tasks=lifetime_tasks)),
        ("mmpp", lambda: MMPPTraffic(
            0.2, 1.2, horizon_s, dwell_low_s=40.0, dwell_high_s=15.0,
            seed=1, lifetime_tasks=lifetime_tasks)),
    )
    acells = [lambda mk=mk: run_episode(
                  1, 25, n_pods=n_pods, reuse_rate=0.3, seed=1,
                  prefetch=True, capacity_per_pod=8, admission="tinylfu",
                  traffic=mk(), **zipfg)
              for _n, mk in arrivals]
    for (name, mk), res in zip(arrivals, _run_cells(acells, parallel)):
        m = res.metrics
        lats = [tr.time_s for s in res.sessions for tr in s.traces]
        frac = slo_attainment(lats, slo_p99_s)
        rate = mk().offered_rate
        rows.append(
            f"capacity_arrival,zipfg-1.1,tinylfu+{name},{rate:.3f},"
            f"{slo_p99_s},{m.traffic_spawned},{m.traffic_completed},"
            f"{m.traffic_in_system},{m.throughput_tasks_per_s:.4f},"
            f"{m.p50_task_latency_s:.3f},{m.p95_task_latency_s:.3f},"
            f"{m.p99_task_latency_s:.3f},{frac:.4f},"
            f"{m.traffic_mean_sojourn_s:.3f},"
            f"{m.traffic_mean_in_system:.3f},"
            f"{m.traffic_little_residual:.2e},{100*m.local_hit_rate:.2f},"
            f"{m.resilience_incomplete_sessions}")
    return rows


def table_coherence(tasks_per_session: int = 12,
                    parallel: bool = False,
                    engine_kw: Dict = None) -> List[str]:
    """Beyond-paper: mutable data plane with cache coherence (ISSUE 8).

    The read-only tables assume a key's data never changes; this table
    runs seeded :class:`~repro.core.coherence.MutationPlan` write streams
    against the mutation-facing workloads (``update_heavy`` /
    ``mixed_rw`` / ``flash_fresh`` — see ``WorkloadSampler``) and sweeps
    the coherence policy axis on identical seeds:

    * ``wi`` — write-invalidate: every write drops all cached copies
      (replicas included); no consumed value is ever stale (locked).
    * ``wt`` — write-through: every write re-stamps all cached copies to
      the new version in place; no stale reads, no invalidation misses.
    * ``ttl30`` — copies served until staleness exceeds 30s, then
      refreshed on consume.
    * ``stale20`` — bounded staleness: a version-lagged copy is served
      as long as its staleness is within 20s, else refreshed; the bound
      is a hard clamp (locked).
    * ``llm`` — the GPT-driven ``cache_update`` path on the stale20
      rule: the refresh-vs-serve-stale verdict comes from the prompted
      decision model, graded against the programmatic rule
      (``agreement_pct``); the engine clamp keeps a slipped verdict from
      ever violating the bound.

    ``p95_speedup`` compares each policy row against the same-scenario
    ``wi`` row (>1 = serving bounded-stale copies beats refreshing
    eagerly). The headline is the ``update_heavy`` cell: ``llm`` must
    beat ``wi`` on p95 at a bounded stale-read share. The two extra
    ``stale20`` rows sweep the mutation rate (monotonicity lock:
    stale-read share is non-decreasing in the write rate — see
    tests/test_coherence.py)."""
    from repro.agent.geollm.workload import mutation_hot_keys
    from repro.core.coherence import ARRIVAL, MutationPlan

    rows = ["table,scenario,n_sessions,n_pods,policy,mut_rate,p50_s,p95_s,"
            "stall_total_s,mutations,invalidations,writethroughs,"
            "stale_reads,refresh_loads,superseded,clamped,stale_share_pct,"
            "max_staleness_s,agreement_pct,coh_tokens,p95_speedup"]
    horizon = 150.0
    hot = mutation_hot_keys(4)

    def plan_for(scenario: str, rate: float) -> MutationPlan:
        if scenario == "flash_fresh":
            # a feed of new scenes walking the same shuffled order the
            # flash crowd's hot window advances over
            return MutationPlan.periodic(hot, 1.0 / rate, start_s=5.0,
                                         horizon_s=horizon, kind=ARRIVAL)
        return MutationPlan.random_plan(hot, rate, horizon, seed=5)

    policies = [
        ("wi", {"coherence": "write-invalidate"}),
        ("wt", {"coherence": "write-through"}),
        ("ttl30", {"coherence": "ttl", "coherence_kw": {"ttl_s": 30.0}}),
        ("stale20", {"coherence": "serve-stale",
                     "coherence_kw": {"bound_s": 20.0}}),
        ("llm", {"coherence": "serve-stale", "coherence_impl": "llm",
                 "coherence_kw": {"bound_s": 20.0}}),
    ]
    scen_kw = {
        "update_heavy": {"scenario": "update_heavy",
                         "scenario_kw": {"hot_k": 4, "hot_p": 0.85}},
        "mixed_rw": {"scenario": "mixed_rw", "scenario_kw": {"hot_k": 4}},
        "flash_fresh": {"scenario": "flash_fresh",
                        "scenario_kw": {"hot_k": 4, "hot_p": 0.85,
                                        "phase_len": 30}},
    }
    base_rate = 0.2
    grid = [(sc, pol, base_rate) for sc in scen_kw for pol in policies]
    # mutation-rate monotonicity axis (update_heavy, serve-stale)
    grid += [("update_heavy", policies[3], r) for r in (0.05, 0.5)]
    ekw = dict(engine_kw or {})   # degeneracy replays: empty endpoint plan
    cells = [lambda sc=sc, kw=pol[1], rate=rate: run_episode(
                 16, tasks_per_session, n_pods=4, reuse_rate=0.3, seed=0,
                 mutations=plan_for(sc, rate),
                 **dict(scen_kw[sc], **dict(kw, **ekw)))
             for sc, pol, rate in grid]
    results = _run_cells(cells, parallel)
    base_p95: Dict[str, float] = {}
    for (sc, (label, _), rate), res in zip(grid, results):
        m = res.metrics
        if label == "wi":
            base_p95[sc] = m.p95_task_latency_s
            sp = ""
        elif rate != base_rate:
            sp = ""     # different write stream: not comparable to wi
        else:
            sp = f"{base_p95[sc] / m.p95_task_latency_s:.3f}"
        rows.append(
            f"coherence,{sc},16,4,{label},{rate:g},"
            f"{m.p50_task_latency_s:.3f},{m.p95_task_latency_s:.3f},"
            f"{m.total_stall_s:.3f},{m.coherence_mutations},"
            f"{m.coherence_invalidations},{m.coherence_writethroughs},"
            f"{m.coherence_stale_reads},{m.coherence_refresh_loads},"
            f"{m.coherence_superseded_fills},{m.coherence_clamped},"
            f"{100 * m.coherence_stale_share:.2f},"
            f"{m.coherence_max_staleness_s:.3f},"
            f"{100 * m.coherence_agreement:.2f},{m.coherence_tokens},{sp}")
    return rows


def table_llmfault(tasks_per_session: int = 10,
                   parallel: bool = False) -> List[str]:
    """Beyond-paper: decision-plane resilience (ISSUE 9).

    Every GPT call — the per-round planning penalty and the cache-op
    decisions (admission here) — is routed through a pool of 4 simulated
    endpoints under seeded :class:`~repro.core.endpoints.EndpointFaultPlan`
    fault schedules, sweeping regime x mitigation tier on the zipf_global
    16/4 replication-table cell:

    Regimes: ``none`` (empty plan — the degeneracy reference, also the
    p95 baseline), ``mixed`` (``outage_straggler``: ~10% staggered outages
    over three endpoints plus one 8x straggler for the whole horizon — the
    case retries alone cannot fix), ``blackout`` (correlated 12s
    all-endpoint outage: the decision plane is GONE and only programmatic
    fallback keeps cache-op decisions flowing), ``flaky`` (malformed-reply
    windows on two endpoints plus a rate-limit window: parse fallbacks and
    retry-after waits, no hard downtime).

    Tiers are cumulative: ``naive`` = bounded retry/backoff only;
    ``hedge`` adds EWMA-p95 hedged requests (second request to a different
    endpoint, first wins, loser's tokens still charged); ``breaker`` adds
    the per-endpoint circuit breaker whose open state steers calls away
    from bad endpoints and trips cache-op decisions into the programmatic
    twin (``degraded``/``fallback_share_pct``; those decisions are not
    graded — ``adm_agreement_pct`` covers genuine LLM replies only).

    Headline: on ``mixed``, the ``breaker`` tier must hold ``p95_vs_base``
    within ~1.1x of the no-fault baseline while ``naive`` degrades far
    worse (it keeps paying the straggler's 8x rounds and the outage
    backoff on the session clock). ``incomplete`` is the structural
    never-stall-forever gate — 0 in every cell."""
    from repro.core.endpoints import EndpointFaultPlan, LIMIT, MALFORM

    rows = ["table,scenario,n_sessions,n_pods,regime,tier,llm_calls,"
            "retries,hedges,hedge_wins,rate_limited,malformed,"
            "parse_fallbacks,degraded,fallback_share_pct,retry_tokens,"
            "retry_wait_s,breaker_opens,adm_agreement_pct,p50_s,p95_s,"
            "p95_vs_base,incomplete"]
    zipfg = {"scenario": "zipf",
             "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}}
    eps = [f"ep{i}" for i in range(4)]
    horizon = 200.0
    plans = {
        "mixed": EndpointFaultPlan.outage_straggler(eps, horizon_s=horizon),
        "blackout": EndpointFaultPlan.correlated(eps, at=30.0,
                                                 downtime_s=12.0),
        "flaky": (EndpointFaultPlan.single("ep1", 10.0, horizon,
                                           kind=MALFORM, value=0.25)
                  + EndpointFaultPlan.single("ep2", 20.0, horizon,
                                             kind=MALFORM, value=0.25)
                  + EndpointFaultPlan.single("ep0", 40.0, 80.0,
                                             kind=LIMIT, value=5.0)),
    }
    tiers = {"naive": {"hedge": False, "breaker": False},
             "hedge": {"hedge": True, "breaker": False},
             "breaker": {"hedge": True, "breaker": True}}
    # (regime, tier) grid: the no-fault baseline once, mixed and blackout
    # across all three tiers, flaky at the bracketing tiers
    grid = [("none", "naive")]
    grid += [("mixed", t) for t in tiers]
    grid += [("blackout", t) for t in tiers]
    grid += [("flaky", t) for t in ("naive", "breaker")]
    cells = [lambda regime=regime, tier=tier: run_episode(
                 16, tasks_per_session, n_pods=4, reuse_rate=0.3, seed=1,
                 prefetch=True, capacity_per_pod=8,
                 admission="tinylfu", admission_impl="llm",
                 endpoint_fault_plan=plans.get(regime, EndpointFaultPlan()),
                 endpoint_kw=tiers[tier], **zipfg)
             for regime, tier in grid]
    results = _run_cells(cells, parallel)
    base_p95 = results[0].metrics.p95_task_latency_s
    for (regime, tier), res in zip(grid, results):
        m = res.metrics
        rows.append(
            f"llmfault,zipfg-1.1,16,4,{regime},{tier},{m.llm_calls},"
            f"{m.llm_retries},{m.llm_hedges},{m.llm_hedge_wins},"
            f"{m.llm_rate_limited},{m.llm_malformed},"
            f"{m.llm_parse_fallbacks},{m.llm_degraded_decisions},"
            f"{100 * m.llm_fallback_share:.2f},{m.llm_retry_tokens},"
            f"{m.llm_retry_wait_s:.3f},{m.llm_breaker_opens},"
            f"{100 * m.admission_agreement:.2f},"
            f"{m.p50_task_latency_s:.3f},{m.p95_task_latency_s:.3f},"
            f"{m.p95_task_latency_s / base_p95:.3f},"
            f"{m.resilience_incomplete_sessions}")
    return rows


def table_plancache(tasks_per_session: int = 10,
                    parallel: bool = False) -> List[str]:
    """Beyond-paper: the plan-cache tier (ISSUE 10).

    The planning round is the single largest sim-time item; this table
    sweeps repeat-share x plan-cache impl on the zipf_global 16/4 cell,
    then replays the repeat-heavy cell under PR 9's mixed
    outage+straggler regime at the retry-only mitigation tier (the case
    hedging is not there to mask — every straggler-landed planning round
    pays the 8x service time on the session clock).

    Cells: ``none`` regime at repeat 0% (off vs python: the zero-hit
    lock — a non-repeating stream cannot hit, the tier costs one cache
    read per task and nothing else) and repeat 60% (off / python / llm;
    the GPT path runs at capacity 16 so eviction pressure actually
    consults the model — a free-slot install never prompts); ``mixed``
    regime at repeat 60% across the same three impls.

    Headline (the acceptance gate tests/test_plan_cache.py and CI's
    smoke cell hold): on ``mixed``, both cached impls must show
    ``p95_vs_off`` strictly below 1.0 — a plan-cache hit skips the
    planning round entirely, so repeated templates never touch the
    straggler — while ``none``-regime hits hold p95 parity and cut mean
    latency and trace tokens. ``stale_served`` is 0 in every cell (the
    digest embeds datastore versions + residency; version-lagged plans
    are unreachable by construction, and the serve-time guard measures
    it)."""
    from repro.core.endpoints import EndpointFaultPlan

    rows = ["table,scenario,n_sessions,n_pods,regime,repeat_pct,impl,"
            "lookups,hits,hit_rate_pct,installs,rejected,evictions,"
            "expired,invalidations,stale_served,pc_agreement_pct,"
            "pc_tokens,trace_tokens,fleet_tokens,mean_s,p50_s,p95_s,"
            "p95_vs_off,incomplete"]
    eps = [f"ep{i}" for i in range(4)]
    mixed = EndpointFaultPlan.outage_straggler(eps, horizon_s=400.0)
    impls = {"off": {}, "python": {"plan_cache": "python"},
             "llm": {"plan_cache": "llm", "plan_cache_kw": {"capacity": 16}}}
    grid = [("none", 0.0, "off"), ("none", 0.0, "python"),
            ("none", 0.6, "off"), ("none", 0.6, "python"),
            ("none", 0.6, "llm"),
            ("mixed", 0.6, "off"), ("mixed", 0.6, "python"),
            ("mixed", 0.6, "llm")]

    def _cell(regime, repeat, impl):
        skw = {"zipf_a": 1.1, "zipf_global": True}
        if repeat:
            skw["repeat_p"] = repeat
        kw = dict(impls[impl])
        if regime == "mixed":
            kw["endpoint_fault_plan"] = mixed
            kw["endpoint_kw"] = {"hedge": False, "breaker": False}
        return run_episode(16, tasks_per_session, n_pods=4, reuse_rate=0.3,
                           seed=1, prefetch=True, capacity_per_pod=8,
                           scenario="zipf", scenario_kw=skw, **kw)

    cells = [lambda g=g: _cell(*g) for g in grid]
    results = _run_cells(cells, parallel)
    off_p95 = {(regime, repeat): res.metrics.p95_task_latency_s
               for (regime, repeat, impl), res in zip(grid, results)
               if impl == "off"}
    for (regime, repeat, impl), res in zip(grid, results):
        m = res.metrics
        rows.append(
            f"plancache,zipfg-1.1,16,4,{regime},{100 * repeat:g},{impl},"
            f"{m.plancache_lookups},{m.plancache_hits},"
            f"{100 * m.plancache_hit_rate:.2f},{m.plancache_installs},"
            f"{m.plancache_rejected},{m.plancache_evictions},"
            f"{m.plancache_expired},{m.plancache_invalidations},"
            f"{m.plancache_stale_served},"
            f"{100 * m.plancache_agreement:.2f},{m.plancache_tokens},"
            f"{m.tokens_trace_total},{m.tokens_fleet_total},"
            f"{m.mean_task_latency_s:.3f},{m.p50_task_latency_s:.3f},"
            f"{m.p95_task_latency_s:.3f},"
            f"{m.p95_task_latency_s / off_p95[(regime, repeat)]:.3f},"
            f"{m.resilience_incomplete_sessions}")
    return rows


def belady_bound(n: int = 200, parallel: bool = False) -> List[str]:
    """Beyond-paper: Belady/MIN oracle as the eviction upper bound.

    The oracle is given the full upcoming key sequence once (indexed into
    per-key position lists by the policy) and its ``cursor`` advances as
    tasks consume requests — O(1) per task instead of re-slicing the
    remaining stream (identical victims: next-use comparisons shift by a
    constant)."""
    from repro.agent.geollm.evaluator import evaluate

    rows = ["table,policy,avg_time_s,cache_hit_pct"]
    for pol in ("lru", "belady"):
        rt = build_runtime(model="gpt-3.5-turbo", prompting="cot",
                           few_shot=False, use_cache=True, policy=pol,
                           read_impl="python", update_impl="python")
        tasks = _tasks(n, 0.8)
        future = [k for t in tasks for k in t.required_keys]
        if pol == "belady":
            rt.runner.controller.policy.future = future
        traces, consumed = [], 0
        for t in tasks:
            if pol == "belady":
                rt.runner.controller.policy.cursor = consumed
            consumed += len(t.required_keys)
            traces.append(rt.runner.run_task(t))
        r = evaluate(tasks, traces, rt.cache.stats)
        rows.append(f"belady,{pol},{r.avg_time_s:.3f},"
                    f"{100*r.cache_hit_rate:.2f}")
    return rows

"""Paper-table benchmarks (Tables I-III) on the GeoLLM-Engine sim.

Each function returns a list of CSV rows; ``benchmarks.run`` drives them.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.agent import build_runtime, build_tasks

# paper reference numbers for the summary comparison
PAPER_MEAN_SPEEDUP = 1.24
PAPER_SPEEDUP_RANGE = (1.15, 1.33)
PAPER_GPT_HIT = (0.962, 0.977)


def _cell(model, prompting, few_shot, use_cache, *, n, reuse=0.8, seed=0,
          policy="lru", read_impl="llm", update_impl="llm"):
    rt = build_runtime(model=model, prompting=prompting, few_shot=few_shot,
                       use_cache=use_cache, seed=seed, policy=policy,
                       read_impl=read_impl, update_impl=update_impl)
    tasks = build_tasks(n, reuse_rate=reuse, seed=1, store=rt.store)
    return rt.run_and_evaluate(tasks)


def table1(n: int = 300) -> List[str]:
    """Models x prompting x shot, with/without LLM-dCache."""
    rows = ["table,model,prompting,few_shot,dcache,success,correctness,"
            "obj_det_f1,lcc_recall,vqa_rouge,avg_tokens,avg_time_s,speedup"]
    speedups = []
    for model in ("gpt-3.5-turbo", "gpt-4-turbo"):
        for prompting in ("cot", "react"):
            for fs in (False, True):
                base = _cell(model, prompting, fs, False, n=n)
                dc = _cell(model, prompting, fs, True, n=n)
                sp = base.avg_time_s / dc.avg_time_s
                speedups.append(sp)
                for tag, r, s in (("off", base, ""),
                                  ("on", dc, f"{sp:.2f}")):
                    rows.append(
                        f"table1,{model},{prompting},{int(fs)},{tag},"
                        f"{r.success_rate:.4f},{r.correctness:.4f},"
                        f"{r.obj_det_f1:.4f},{r.lcc_recall:.4f},"
                        f"{r.vqa_rouge:.4f},{r.avg_tokens:.0f},"
                        f"{r.avg_time_s:.3f},{s}")
    mean_sp = float(np.mean(speedups))
    rows.append(f"table1_summary,mean_speedup,{mean_sp:.3f},"
                f"paper={PAPER_MEAN_SPEEDUP},"
                f"in_paper_range={PAPER_SPEEDUP_RANGE[0] <= mean_sp <= PAPER_SPEEDUP_RANGE[1] + 0.05}")
    return rows


def table2(n: int = 200) -> List[str]:
    """Reuse-rate sweep + cache-policy ablation (mini 500-query style).

    Reuse rate changes the sampled tasks themselves (more distinct keys at
    low reuse), so the no-cache baseline is re-measured per rate and the
    paper's claim is read off the per-rate speedup column."""
    rows = ["table,config,value,avg_time_s,no_cache_time_s,speedup"]
    for rr in (0.0, 0.2, 0.4, 0.6, 0.8):
        r0 = _cell("gpt-3.5-turbo", "cot", False, False, n=n, reuse=rr)
        r1 = _cell("gpt-3.5-turbo", "cot", False, True, n=n, reuse=rr)
        rows.append(f"table2,reuse_rate,{rr},{r1.avg_time_s:.3f},"
                    f"{r0.avg_time_s:.3f},"
                    f"{r0.avg_time_s / r1.avg_time_s:.3f}")
    for pol in ("lru", "lfu", "rr", "fifo"):
        r = _cell("gpt-3.5-turbo", "cot", False, True, n=n, policy=pol)
        rows.append(f"table2,policy,{pol},{r.avg_time_s:.3f},,")
    return rows


def table3(n: int = 200) -> List[str]:
    """GPT-driven vs programmatic cache read/update (gpt-4 CoT few-shot)."""
    rows = ["table,read_impl,update_impl,cache_hit_pct,gpt_hit_pct,success,"
            "correctness,obj_det_f1,lcc_recall,vqa_rouge,avg_tokens,"
            "avg_time_s"]
    for read_impl, update_impl in (("python", "python"), ("llm", "python"),
                                   ("python", "llm"), ("llm", "llm")):
        r = _cell("gpt-4-turbo", "cot", True, True, n=n,
                  read_impl=read_impl, update_impl=update_impl)
        rows.append(
            f"table3,{read_impl},{update_impl},{100*r.cache_hit_rate:.2f},"
            f"{100*r.gpt_hit_rate:.2f},{r.success_rate:.4f},"
            f"{r.correctness:.4f},{r.obj_det_f1:.4f},{r.lcc_recall:.4f},"
            f"{r.vqa_rouge:.4f},{r.avg_tokens:.0f},{r.avg_time_s:.3f}")
    return rows


def belady_bound(n: int = 200) -> List[str]:
    """Beyond-paper: Belady/MIN oracle as the eviction upper bound.

    The oracle's future-request list is refreshed before each task with the
    exact upcoming key sequence (possible offline; a real system would
    approximate it with a predictor)."""
    from repro.agent.geollm.evaluator import evaluate

    rows = ["table,policy,avg_time_s,cache_hit_pct"]
    for pol in ("lru", "belady"):
        rt = build_runtime(model="gpt-3.5-turbo", prompting="cot",
                           few_shot=False, use_cache=True, policy=pol,
                           read_impl="python", update_impl="python")
        tasks = build_tasks(n, reuse_rate=0.8, seed=1, store=rt.store)
        future = [k for t in tasks for k in t.required_keys]
        traces, consumed = [], 0
        for t in tasks:
            if pol == "belady":
                rt.runner.controller.policy.future = future[consumed:]
            consumed += len(t.required_keys)
            traces.append(rt.runner.run_task(t))
        r = evaluate(tasks, traces, rt.cache.stats)
        rows.append(f"belady,{pol},{r.avg_time_s:.3f},"
                    f"{100*r.cache_hit_rate:.2f}")
    return rows

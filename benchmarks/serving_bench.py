"""Serving-engine and kernel micro-benchmarks (real wall time on CPU).

us_per_call numbers are CPU-interpret figures — the TPU target is what the
dry-run/roofline reports; these catch regressions and prove the paths run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Init, init_model, unbox


def bench_serving(n_requests: int = 6, max_new: int = 8) -> List[str]:
    from repro.serving import ServingEngine
    cfg = dataclasses.replace(get_config("dcache-agent-150m").reduced(),
                              vocab_size=512)
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=128)
    for i in range(n_requests):
        eng.submit(f"benchmark request number {i}", max_new_tokens=max_new)
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    s = eng.stats()
    return [
        "bench,metric,value",
        f"serving,requests,{s['finished']}",
        f"serving,wall_s,{dt:.3f}",
        f"serving,throughput_tok_s,{s['throughput_tok_s']:.2f}",
        f"serving,mean_ttft_s,{s['mean_ttft_s']:.3f}",
    ]


def bench_cache_ops(n: int = 5_000) -> List[str]:
    """Host-side cache op latency (the actual mechanism the paper adds)."""
    from repro.core.cache import DataCache
    from repro.core.policies import make_policy
    c = DataCache(capacity=5)
    pol = make_policy("lru")
    keys = [f"d{i}-20{i % 10:02d}" for i in range(40)]
    t0 = time.perf_counter()
    for i in range(n):
        k = keys[i % len(keys)]
        if k in c:
            c.get(k)
        else:
            victim = pol.victim(c.entries()) if len(c) >= 5 else None
            c.put(k, i, 1, victim=victim)
    us = (time.perf_counter() - t0) / n * 1e6
    return [f"cache_ops,us_per_call,{us:.2f}"]


def bench_kernels() -> List[str]:
    from repro.kernels import ops
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(ops.flash_attention(q, k, v, block_q=128,
                                                  block_k=128))
    rows.append(f"kernel_flash_attn_interpret,us_per_call,"
                f"{(time.perf_counter()-t0)/3*1e6:.0f}")
    return rows

"""Train a ~100M-class config (reduced for CPU) for a few hundred steps,
with checkpointing, an injected node failure + automatic restore, and
a resumable cold restart — the fault-tolerance path end to end.

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import tempfile
import time

import jax

from repro.configs import get_config
from repro.distributed import Checkpointer, FailureInjector, HeartbeatMonitor
from repro.models import Init, init_model, unbox
from repro.training import AdamWConfig, Prefetcher, TokenStream, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name}  params={cfg.param_count()/1e6:.2f}M  "
          f"devices={jax.device_count()}")
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))

    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir, keep=2)
        mon = HeartbeatMonitor()
        data = Prefetcher(TokenStream(cfg, batch=8, seq=64, seed=0))
        fail_at = [args.steps // 3, args.steps // 2]
        loop = TrainLoop(
            cfg, AdamWConfig(lr=2e-3, warmup_steps=10,
                             total_steps=args.steps),
            params, data, checkpointer=ck, ckpt_every=20, monitor=mon,
            failure_injector=FailureInjector(fail_at))
        t0 = time.time()
        loop.run(args.steps)
        dt = time.time() - t0
        print(f"loss {loop.history[0]:.3f} -> {loop.history[-1]:.3f} "
              f"({args.steps} steps, {dt:.1f}s, "
              f"{8*64*args.steps/dt:.0f} tok/s)")
        print(f"injected failures at {fail_at}: "
              f"{len(mon.failures)} recovered via checkpoint restore")
        print(f"checkpoints kept: {ck.available_steps()}")

        # cold restart: resume from the last checkpoint
        loop2 = TrainLoop(cfg, AdamWConfig(), params,
                          Prefetcher(TokenStream(cfg, 8, 64, seed=0)),
                          checkpointer=ck)
        assert loop2.restore_if_available()
        print(f"cold restart resumes at step {loop2.step_idx} OK")
        data.close()


if __name__ == "__main__":
    main()

"""End-to-end driver (the paper's kind is agent *serving*): train a small
LM briefly, then serve batched requests through the continuous-batching
engine — including using it as the ``JaxLLM`` cache-decision backend.

    PYTHONPATH=src python examples/serve_llm.py [--steps 120]
"""
import argparse
import dataclasses
import time

import jax

from repro.agent.backends import JaxLLM
from repro.configs import get_config
from repro.models import Init, init_model, unbox
from repro.serving import ServingEngine
from repro.training import AdamWConfig, TokenStream, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("dcache-agent-150m").reduced(),
                              vocab_size=512, n_layers=4, d_model=128,
                              d_ff=512, n_heads=4, n_kv_heads=2)
    print(f"model: {cfg.param_count()/1e6:.2f}M params")
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))

    # -- short training run -------------------------------------------------
    stream = TokenStream(cfg, batch=8, seq=64, seed=0)
    loop = TrainLoop(cfg, AdamWConfig(lr=1e-3, warmup_steps=5,
                                      total_steps=args.steps),
                     params, iter(stream.next_batch, None), ckpt_every=0)
    t0 = time.time()
    loop.run(args.steps)
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s: "
          f"loss {loop.history[0]:.3f} -> {loop.history[-1]:.3f}")

    # -- batched serving ----------------------------------------------------
    eng = ServingEngine(cfg, loop.params, max_batch=4, max_len=192)
    prompts = [
        "Plot the xview1 images from 2022",
        "Detect airplanes around Newport Beach",
        "Show fair1m and xview1 imagery",
        "Classify land cover near Houston",
        "Count ships in Miami 2021",
        "Heatmap of detections for Seattle",
        "Describe the Denver area",
        "List cloudy sentinel2 scenes",
    ][: args.requests]
    reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
    t0 = time.time()
    eng.run_until_done()
    s = eng.stats()
    print(f"\nserved {s['finished']} requests in {time.time()-t0:.1f}s "
          f"({s['throughput_tok_s']:.1f} tok/s, "
          f"ttft {s['mean_ttft_s']*1e3:.0f} ms)")
    for r in reqs[:3]:
        print(f"  [{r.rid}] -> {eng.tok.decode(r.out_ids)!r}")

    # -- the served model as the cache-decision LLM -------------------------
    llm = JaxLLM(eng, max_new_tokens=24)
    out = llm.complete("Cache: {}  Required keys: [\"xview1-2022\"]  "
                       "Answer (JSON): ")
    print(f"\nJaxLLM cache-decision completion (untuned byte-LM): {out!r}")
    print("(the SimLLM backend provides the calibrated decisions for the "
          "benchmarks; this shows the real serving path wired end-to-end)")


if __name__ == "__main__":
    main()

"""Quickstart: LLM-dCache in 60 seconds.

Builds the GeoLLM-Engine sim + tool-calling agent, runs the same workload
with and without GPT-driven caching, and prints the paper's headline
numbers (speedup, GPT-hit rate, unchanged agent metrics).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.agent import build_runtime, build_tasks
from repro.core.prompts import read_decision_prompt

N_TASKS = 100


def main():
    print("=" * 70)
    print("LLM-dCache quickstart — GPT-driven localized data caching")
    print("=" * 70)

    # --- what the LLM actually sees for a cache-read decision -------------
    prompt = read_decision_prompt(
        "Show fair1m and xview1 imgs from 2022",
        ["fair1m-2022", "xview1-2022"],
        '{"xview1-2022": {"last_access": 3.1, "access_count": 2}}',
        few_shot=True)
    print("\n--- cache-read decision prompt (truncated) ---")
    print(prompt[:600] + " [...]\n")

    # --- run the benchmark both ways --------------------------------------
    reports = {}
    for use_cache in (False, True):
        rt = build_runtime(model="gpt-4-turbo", prompting="cot",
                           few_shot=True, use_cache=use_cache, seed=0)
        tasks = build_tasks(N_TASKS, reuse_rate=0.8, seed=1, store=rt.store)
        reports[use_cache] = (rt.run_and_evaluate(tasks), rt)

    r0, _ = reports[False]
    r1, rt1 = reports[True]
    print(f"{'':24s}{'no cache':>12s}{'LLM-dCache':>12s}")
    for name, a, b in (
            ("success rate", r0.success_rate, r1.success_rate),
            ("correctness", r0.correctness, r1.correctness),
            ("obj-det F1", r0.obj_det_f1, r1.obj_det_f1),
            ("VQA ROUGE-L", r0.vqa_rouge, r1.vqa_rouge)):
        print(f"{name:24s}{a:12.3f}{b:12.3f}")
    print(f"{'avg tokens/task':24s}{r0.avg_tokens:12.0f}{r1.avg_tokens:12.0f}")
    print(f"{'avg time/task (s)':24s}{r0.avg_time_s:12.2f}"
          f"{r1.avg_time_s:12.2f}")
    print(f"\nspeedup: {r0.avg_time_s / r1.avg_time_s:.2f}x "
          f"(paper: 1.24x avg)")
    st = rt1.cache.stats
    print(f"cache hit rate: {100 * st.hit_rate:.1f}%   "
          f"GPT-hit rate: {100 * st.gpt_hit_rate:.1f}% (paper: ~96-98%)")
    print(f"cache contents now: {sorted(rt1.cache.keys())}")


if __name__ == "__main__":
    main()

"""Multi-pod localized caching (DESIGN §3): rendezvous-hashed pod-local
cache shards, pod-affinity routing, and failover when a pod dies.

    PYTHONPATH=src python examples/multi_pod_cache.py
"""
import json

from repro.agent.geollm.datastore import GeoDataStore
from repro.agent.geollm.simclock import SimClock
from repro.agent.geollm.workload import WorkloadSampler
from repro.core.distributed_cache import PodLocalCacheRouter


def main():
    clock = SimClock()
    store = GeoDataStore(clock)
    pods = [f"pod{i}" for i in range(4)]
    router = PodLocalCacheRouter(pods, capacity_per_pod=5)

    sampler = WorkloadSampler(reuse_rate=0.8, seed=0)
    tasks = sampler.sample(300)
    keys = [k for t in tasks for k in t.required_keys]

    loader = store.peek
    size = lambda f: f.size_bytes

    t_mark = None
    for i, k in enumerate(keys):
        router.fetch(k, loader, size)
        if i == len(keys) // 2 and t_mark is None:
            # kill a pod mid-stream: its keys fail over deterministically
            victim_pod = router.owner(k)
            print(f"--- killing {victim_pod} at request {i} ---")
            router.fail_pod(victim_pod)
            t_mark = i

    s = router.summary()
    print(json.dumps(s, indent=2))
    print(f"\nlocal hit rate with pod-affinity routing: "
          f"{100 * s['local_hit_rate']:.1f}% over {s['routed']} requests "
          f"({s['failovers']} pod failure)")
    print("rendezvous property: only the dead pod's keys moved; "
          "survivors kept their entire cache (see tests/test_distributed_cache.py)")


if __name__ == "__main__":
    main()

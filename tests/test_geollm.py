import numpy as np

from repro.agent.geollm.datastore import GeoDataStore, all_keys, synth_frame
from repro.agent.geollm.evaluator import rouge_l
from repro.agent.geollm.simclock import SimClock
from repro.agent.geollm.workload import (
    WorkloadSampler,
    compute_gold,
    make_benchmark,
    model_check,
)
from repro.agent.geollm import geotools


def test_catalog_scale():
    keys = all_keys()
    assert len(keys) == 72
    # paper platform: >1.1M images total (sampled estimate on 6 keys)
    sizes = [len(synth_frame(k)) for k in keys[:6]]
    est_total = np.mean(sizes) * len(keys)
    assert est_total > 0.8e6


def test_frames_deterministic():
    f1, f2 = synth_frame("xview1-2022"), synth_frame("xview1-2022")
    np.testing.assert_array_equal(f1.lon, f2.lon)
    np.testing.assert_array_equal(f1.det_count, f2.det_count)


def test_frame_size_in_paper_band():
    f = synth_frame("fair1m-2021")
    assert 30 <= f.size_mb <= 150          # paper: 50-100MB typical


def test_db_latency_vs_cache_latency_ratio():
    clock = SimClock()
    store = GeoDataStore(clock)
    t0 = clock.now()
    store.load("dota-2019")
    db = clock.now() - t0
    cr = store.cache_read_latency("dota-2019")
    assert 5.0 <= db / cr <= 10.0          # paper: cache 5-10x faster


def test_tools_pipeline():
    f = synth_frame("xview1-2022")
    roi = geotools.filter_bbox(f, "houston")
    assert 0 < len(roi) < len(f)
    det = geotools.detect_objects(roi, "ship")
    assert det["detections"] >= 0
    covers = geotools.dominant_land_covers(roi, 2)
    assert len(covers) == 2
    ans = geotools.vqa_answer(roi, "what is here?")
    assert "images" in ans


def test_workload_reuse_rate_controls_locality():
    """reuse_rate = probability the next key is in the recent working set."""
    def ws_hit_frac(rr, window=5):
        s = WorkloadSampler(reuse_rate=rr, seed=0)
        tasks = s.sample(200)
        keys = [k for t in tasks for k in t.required_keys]
        recent, hits = [], 0
        for k in keys:
            hits += k in recent
            recent = ([k] + [x for x in recent if x != k])[:window]
        return hits / len(keys)
    lo, hi = ws_hit_frac(0.0), ws_hit_frac(0.8)
    assert hi > 0.5
    assert hi > lo + 0.3


def test_benchmark_gold_and_model_checker():
    clock = SimClock()
    store = GeoDataStore(clock)
    tasks = make_benchmark(25, reuse_rate=0.8, seed=3, store=store)
    assert all(s.gold is not None for t in tasks for s in t.steps)
    assert model_check(tasks, store) == []
    calls = np.mean([t.n_tool_calls for t in tasks])
    assert 8 <= calls <= 30                # multi-step, ~50k calls / 1k tasks


def test_rouge_l():
    assert rouge_l("the cat sat", "the cat sat") == 1.0
    assert rouge_l("", "gold") == 0.0
    assert 0 < rouge_l("the dog sat", "the cat sat") < 1.0

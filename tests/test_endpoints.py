"""LLM decision-plane resilience (ISSUE 9): property locks.

* **fault plans** — generator/ordering units: events sort canonically,
  start/end pairing is validated fail-fast, a plan that leaves the whole
  pool permanently dead is rejected at router construction;
* **determinism** — plan_call/decision_call sequences are bit-identical
  under the same seed and differ across seeds (the router draws from a
  private RNG stream, so episode streams never shift);
* **circuit breaker** — closed -> open -> half-open -> closed/re-open
  transitions, exactly at the threshold and cooldown;
* **never-stall-forever** — a matrix of outage/straggler/blackout/
  malform regimes x mitigation tiers: every episode completes every
  session (``incomplete == 0``) with a finite makespan;
* **degeneracy contract** — an EMPTY :class:`EndpointFaultPlan` (router
  live on every planning round and cache-op decision) replays the
  router-free engine bit-identically across randomized configs, and
  re-locks the PR-4 concurrency, PR-6 resilience, and PR-8 coherence
  table digests;
* **satellites** — typed ``LLMParseError`` from SimLLM prompt parsing,
  unified programmatic-twin fallback (unavailable + parse) on the
  policy wrappers, and the stride-based scan-resistant admission gate.
"""
import hashlib
import math
import random

import pytest

from benchmarks import tables
from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.core.admission import ScanTinyLFU, TinyLFU, make_admission
from repro.core.coherence import MutationPlan
from repro.core.endpoints import (
    CLOSED,
    HALF_OPEN,
    LIMIT,
    MALFORM,
    OPEN,
    OUTAGE,
    RESTORE,
    SLOW,
    EndpointFaultEvent,
    EndpointFaultPlan,
    EndpointRouter,
    LLMUnavailableError,
    RoutedLLM,
)
from repro.core.prompts import LLMParseError

# the PR-4 / PR-6 references the degeneracy replays must keep matching
# (same values tests/test_locality.py and tests/test_coherence.py hold)
PR4_CONCURRENCY_DIGEST = "8ec8ff89cfb17741"
PR6_RESILIENCE_DIGEST_12 = "9ed9f62ca396989d"

EPS = ["ep0", "ep1", "ep2", "ep3"]


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


# ---------------------------------------------------------------------------
# Fault-plan generators, ordering, validation
# ---------------------------------------------------------------------------

def test_events_sort_canonically():
    plan = EndpointFaultPlan([
        EndpointFaultEvent(5.0, RESTORE, "ep1"),
        EndpointFaultEvent(2.0, OUTAGE, "ep1"),
        EndpointFaultEvent(2.0, OUTAGE, "ep0"),
    ])
    assert [(e.at, e.action, e.endpoint) for e in plan] == [
        (2.0, OUTAGE, "ep0"), (2.0, OUTAGE, "ep1"), (5.0, RESTORE, "ep1")]
    # construction order does not matter
    plan2 = EndpointFaultPlan(list(plan)[::-1])
    assert repr(plan2) == repr(plan)


def test_generators_build_expected_windows():
    p = EndpointFaultPlan.single("ep0", 3.0, 8.0)
    assert p.windows[OUTAGE]["ep0"] == [(3.0, 8.0, 0.0)]
    p = EndpointFaultPlan.single("ep1", 2.0, kind=SLOW, value=4.0)
    assert p.windows[SLOW]["ep1"] == [(2.0, math.inf, 4.0)]
    p = EndpointFaultPlan.correlated(EPS, 10.0, downtime_s=5.0)
    assert all(p.windows[OUTAGE][e] == [(10.0, 15.0, 0.0)] for e in EPS)
    p = EndpointFaultPlan.periodic(EPS[:2], period_s=10.0, downtime_s=3.0,
                                   start_s=5.0, horizon_s=30.0)
    assert p.windows[OUTAGE]["ep0"] == [(5.0, 8.0, 0.0), (25.0, 28.0, 0.0)]
    assert p.windows[OUTAGE]["ep1"] == [(15.0, 18.0, 0.0)]
    p = EndpointFaultPlan.outage_straggler(EPS, horizon_s=100.0)
    assert p.windows[SLOW]["ep3"] == [(5.0, 100.0, 8.0)]
    assert len(p.windows[OUTAGE]) == 3          # staggered over ep0..ep2
    # seeded random plans: reproducible, no same-endpoint overlap
    p1 = EndpointFaultPlan.random_plan(EPS, 12, 100.0, 6.0, seed=7)
    p2 = EndpointFaultPlan.random_plan(EPS, 12, 100.0, 6.0, seed=7)
    assert repr(p1) == repr(p2)
    assert repr(p1) != repr(
        EndpointFaultPlan.random_plan(EPS, 12, 100.0, 6.0, seed=8))
    for wins in p1.windows[OUTAGE].values():
        for (s1, e1, _), (s2, e2, _) in zip(wins, wins[1:]):
            assert e1 <= s2


def test_plan_validation_fails_fast():
    with pytest.raises(ValueError, match="unknown endpoint action"):
        EndpointFaultEvent(0.0, "explode", "ep0")
    with pytest.raises(ValueError, match="retry_after"):
        EndpointFaultEvent(0.0, LIMIT, "ep0", 0.0)
    with pytest.raises(ValueError, match="multiplier"):
        EndpointFaultEvent(0.0, SLOW, "ep0", 0.5)
    with pytest.raises(ValueError, match="malform needs p"):
        EndpointFaultEvent(0.0, MALFORM, "ep0", 1.5)
    with pytest.raises(ValueError, match="takes no value"):
        EndpointFaultEvent(0.0, OUTAGE, "ep0", 1.0)
    with pytest.raises(ValueError, match="overlapping"):
        EndpointFaultPlan([EndpointFaultEvent(1.0, OUTAGE, "ep0"),
                           EndpointFaultEvent(2.0, OUTAGE, "ep0")])
    with pytest.raises(ValueError, match="without an open"):
        EndpointFaultPlan([EndpointFaultEvent(2.0, RESTORE, "ep0")])
    with pytest.raises(ValueError, match="empty"):
        EndpointFaultPlan([EndpointFaultEvent(2.0, OUTAGE, "ep0"),
                           EndpointFaultEvent(2.0, RESTORE, "ep0")])


def test_router_rejects_permanently_dead_pool():
    dead = EndpointFaultPlan([EndpointFaultEvent(0.0, OUTAGE, e)
                              for e in EPS])
    with pytest.raises(ValueError, match="permanently dead"):
        EndpointRouter(4, dead)
    # one survivor is enough
    alive = EndpointFaultPlan([EndpointFaultEvent(0.0, OUTAGE, e)
                               for e in EPS[:3]])
    r = EndpointRouter(4, alive)
    assert r.next_available(5.0) == 5.0
    with pytest.raises(ValueError, match="outside the pool"):
        EndpointRouter(2, EndpointFaultPlan.single("ep3", 1.0, 2.0))


# ---------------------------------------------------------------------------
# Routing determinism: same seed bit-identical, different seed differs
# ---------------------------------------------------------------------------

def _drive(seed: int, hedge=True, breaker=True):
    plan = EndpointFaultPlan.outage_straggler(EPS, horizon_s=150.0) \
        + EndpointFaultPlan.single("ep1", 100.0, 130.0, kind=MALFORM,
                                   value=0.5)
    r = EndpointRouter(4, plan, seed=seed, hedge=hedge, breaker=breaker)
    out = []
    t = 0.0
    for i in range(60):
        out.append(r.plan_call(t, 2.0, 500))
        r.now = t
        try:
            out.append(r.decision_call(400))
        except LLMUnavailableError:
            out.append("degraded")
        t += 2.5
    out.append((r.retries, r.hedges, r.hedge_wins, r.malformed,
                r.retry_tokens, r.breaker_opens, r.breaker_closes))
    return out


def test_routing_deterministic_per_seed():
    assert _drive(3) == _drive(3)
    assert _drive(3) != _drive(4)


def test_plan_call_zero_extra_without_faults():
    r = EndpointRouter(4, EndpointFaultPlan(), seed=1, hedge=True,
                       breaker=True)
    for i in range(20):
        extra, retries, hedges, wins, wait = r.plan_call(i * 2.0, 1.7, 300)
        assert extra == 0.0 and wait == 0.0   # exactly, not approximately
        assert retries == hedges == wins == 0
    assert r.retry_tokens == 0 and r.retries == 0


def test_rate_limit_waits_then_succeeds():
    plan = EndpointFaultPlan([
        EndpointFaultEvent(0.0, LIMIT, e, 5.0) for e in EPS] + [
        EndpointFaultEvent(50.0, "limit_end", e) for e in EPS])
    r = EndpointRouter(4, plan, seed=0)
    extra, retries, _h, _w, wait = r.plan_call(10.0, 2.0, 100)
    assert extra == 5.0 and wait == 5.0 and retries == 1
    assert r.rate_limited == 1
    # latency-free decisions cannot wait a 429 out: budget burns, degrade
    r.now = 10.0
    with pytest.raises(LLMUnavailableError):
        r.decision_call(100)
    assert r.degraded == 1


def test_blackout_plan_call_waits_to_next_available():
    plan = EndpointFaultPlan.correlated(EPS, 10.0, downtime_s=12.0)
    r = EndpointRouter(4, plan, seed=2)
    assert r.next_available(15.0) == 22.0
    extra, retries, _h, _w, wait = r.plan_call(10.0, 2.0, 100)
    # every retry lands inside the blackout until the analytic jump past
    # t=22; the call always terminates with bounded extra latency
    assert retries >= 1 and extra >= 12.0 - 2.0 and extra < 40.0
    assert wait == extra
    assert r.retry_tokens == 100 * retries


# ---------------------------------------------------------------------------
# Circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_transitions():
    r = EndpointRouter(4, EndpointFaultPlan(), seed=0, breaker=True,
                       breaker_threshold=3, breaker_cooldown_s=20.0)
    ep = "ep0"
    assert r.breaker_state(ep, 0.0) == CLOSED
    r._note_fail(ep, 1.0)
    r._note_fail(ep, 2.0)
    assert r.breaker_state(ep, 2.0) == CLOSED    # below threshold
    r._note_fail(ep, 3.0)
    assert r.breaker_state(ep, 3.0) == OPEN      # tripped at 3
    assert r.breaker_opens == 1
    assert r.breaker_state(ep, 22.9) == OPEN     # still cooling down
    assert r.breaker_state(ep, 23.0) == HALF_OPEN
    r._note_fail(ep, 23.0)                       # probe fails: re-open
    assert r.breaker_state(ep, 24.0) == OPEN
    assert r.breaker_opens == 2
    assert r.breaker_state(ep, 43.0) == HALF_OPEN
    r._note_ok(ep, 43.0)                         # probe succeeds: close
    assert r.breaker_state(ep, 43.0) == CLOSED
    assert r.breaker_closes == 1
    # an ok resets the consecutive-failure count entirely
    r._note_fail(ep, 44.0)
    r._note_fail(ep, 45.0)
    r._note_ok(ep, 46.0)
    r._note_fail(ep, 47.0)
    assert r.breaker_state(ep, 47.0) == CLOSED


def test_open_breakers_exclude_endpoint_from_selection():
    r = EndpointRouter(4, EndpointFaultPlan(), seed=0, breaker=True,
                       breaker_threshold=1)
    r._note_fail("ep2", 0.0)
    assert r._candidates(1.0) == ["ep0", "ep1", "ep3"]
    # all open: decisions fail fast, planning probes the full pool
    for ep in ("ep0", "ep1", "ep3"):
        r._note_fail(ep, 1.0)
    assert r._candidates(2.0) == []
    r.now = 2.0
    with pytest.raises(LLMUnavailableError):
        r.decision_call(100)
    extra, *_ = r.plan_call(2.0, 2.0, 100)
    assert extra == 0.0   # pool is healthy, only the breakers were shy


# ---------------------------------------------------------------------------
# Never-stall-forever: the fault matrix always completes
# ---------------------------------------------------------------------------

REGIMES = {
    "mixed": EndpointFaultPlan.outage_straggler(EPS, horizon_s=150.0),
    "blackout": EndpointFaultPlan.correlated(EPS, 8.0, downtime_s=10.0),
    "malform": (EndpointFaultPlan.single("ep0", 5.0, kind=MALFORM, value=0.4)
                + EndpointFaultPlan.single("ep1", 5.0, kind=MALFORM,
                                           value=0.4)),
    "limit": EndpointFaultPlan([
        EndpointFaultEvent(5.0, LIMIT, e, 4.0) for e in EPS] + [
        EndpointFaultEvent(60.0, "limit_end", e) for e in EPS]),
    "open_ended_outage": EndpointFaultPlan.single("ep0", 5.0)
        + EndpointFaultPlan.single("ep1", 5.0),
}

TIERS = {"naive": {"hedge": False, "breaker": False},
         "hedge": {"hedge": True, "breaker": False},
         "breaker": {"hedge": True, "breaker": True}}


@pytest.mark.parametrize("regime", sorted(REGIMES))
@pytest.mark.parametrize("tier", sorted(TIERS))
def test_never_stalls_forever(regime, tier):
    res = run_episode(6, 4, n_pods=4, reuse_rate=0.3, seed=2, prefetch=True,
                      capacity_per_pod=8, admission="tinylfu",
                      admission_impl="llm",
                      endpoint_fault_plan=REGIMES[regime],
                      endpoint_kw=TIERS[tier])
    m = res.metrics
    assert m.resilience_incomplete_sessions == 0
    assert math.isfinite(m.makespan_s) and m.makespan_s > 0.0
    assert all(len(s.traces) == len(s.tasks) for s in res.sessions)


def test_degraded_decisions_fall_back_to_programmatic_twin():
    # a long blackout with constant admission pressure: decisions degrade
    # (programmatic twin, ungraded) instead of stalling or crashing
    plan = EndpointFaultPlan.correlated(EPS, 5.0, downtime_s=60.0)
    m = run_episode(8, 8, n_pods=4, reuse_rate=0.3, seed=1, prefetch=True,
                    capacity_per_pod=5, admission="tinylfu",
                    admission_impl="llm", endpoint_fault_plan=plan).metrics
    assert m.resilience_incomplete_sessions == 0
    assert m.llm_degraded_decisions > 0
    assert m.llm_fallback_share > 0.0
    # degraded decisions are ungraded: agreement stays at the backend's
    # simulated decision quality instead of collapsing toward 0
    assert m.admission_agreement >= 0.9


# ---------------------------------------------------------------------------
# Degeneracy: empty plan == the router-free engine, bit-identical
# ---------------------------------------------------------------------------

RANDOM_CONFIGS = [
    dict(n=4, tps=6, seed=11, kw=dict(prefetch=True)),
    dict(n=6, tps=5, seed=23, kw=dict(prefetch=True, admission="tinylfu",
                                      admission_impl="llm",
                                      capacity_per_pod=8)),
    dict(n=5, tps=5, seed=37, kw=dict(prefetch=True, replication=True,
                                      replication_impl="llm")),
    dict(n=4, tps=6, seed=41, kw=dict(
        prefetch=True, scenario="zipf",
        scenario_kw={"zipf_a": 1.1, "zipf_global": True},
        capacity_per_pod=8)),
    dict(n=4, tps=5, seed=53, kw=dict(
        prefetch=True,
        mutations=MutationPlan.periodic(["xview1-2015"], 5.0,
                                        horizon_s=40.0),
        coherence="serve-stale", coherence_impl="llm")),
]


@pytest.mark.parametrize("cfg", RANDOM_CONFIGS,
                         ids=[f"seed{c['seed']}" for c in RANDOM_CONFIGS])
def test_empty_plan_bit_identical_to_no_router(cfg):
    base = run_episode(cfg["n"], cfg["tps"], n_pods=4, reuse_rate=0.3,
                       seed=cfg["seed"], **cfg["kw"])
    live = run_episode(cfg["n"], cfg["tps"], n_pods=4, reuse_rate=0.3,
                       seed=cfg["seed"],
                       endpoint_fault_plan=EndpointFaultPlan(), **cfg["kw"])
    assert _traces(base) == _traces(live)
    b, l = base.metrics.row(), live.metrics.row()
    # llm_calls counts the routed rounds (router live vs absent); every
    # OTHER field — times, tokens, hits, stalls — must match exactly
    for d in (b, l):
        for k in [k for k in d if k.startswith("llm_")]:
            d.pop(k)
    assert b == l
    m = live.metrics
    assert m.llm_calls > 0
    assert m.llm_retries == m.llm_hedges == m.llm_degraded_decisions == 0
    assert m.llm_retry_tokens == 0 and m.llm_retry_wait_s == 0.0


def test_empty_plan_requires_plan_for_endpoint_kw():
    with pytest.raises(ValueError, match="endpoint_kw"):
        run_episode(2, 2, seed=0, endpoint_kw={"hedge": True})
    with pytest.raises(ValueError, match="EndpointFaultPlan"):
        run_episode(2, 2, seed=0, endpoint_fault_plan=[("ep0", 1.0)])


def test_degeneracy_replays_pr4_concurrency_digest():
    """Digest lock: the full default concurrency table with the router
    live on every planning round (empty plan) is bit-identical to the
    PR-4 reference tests/test_locality.py locks on the router-free
    engine."""
    rows = tables.table_concurrency(
        tasks_per_session=25,
        engine_kw={"endpoint_fault_plan": EndpointFaultPlan()})
    assert _digest(rows) == PR4_CONCURRENCY_DIGEST


def test_degeneracy_replays_pr6_resilience_digest():
    """Digest lock at the fault-matrix level: the decision-plane router
    composes with pod failover/retry/autoscale without moving a cell."""
    rows = tables.table_resilience(
        tasks_per_session=12,
        engine_kw={"endpoint_fault_plan": EndpointFaultPlan()})
    assert _digest(rows) == PR6_RESILIENCE_DIGEST_12


def test_degeneracy_replays_pr8_coherence_table():
    """The PR-8 coherence table (reduced stream) is bit-identical with
    the router live on every cell — mutation ordering, staleness clamps,
    and the GPT cache_update stream all survive the routing layer."""
    base = tables.table_coherence(tasks_per_session=4, parallel=True)
    live = tables.table_coherence(
        tasks_per_session=4, parallel=True,
        engine_kw={"endpoint_fault_plan": EndpointFaultPlan()})
    assert _digest(live) == _digest(base)


# ---------------------------------------------------------------------------
# Satellite: typed parse errors + unified programmatic fallback
# ---------------------------------------------------------------------------

def test_simllm_raises_typed_parse_error():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), 0)
    # decision prompts missing their payload lines raise the TYPED error,
    # never a raw AttributeError / IndexError from the regex parser
    for marker in ("ADMIT the candidate", "REPLICATION controller",
                   "RECOVERY controller", "COHERENCE controller",
                   "Respond with a JSON object mapping each key",
                   "return the NEW cache state"):
        with pytest.raises(LLMParseError):
            llm.complete(f"{marker}: but the evidence lines are missing")
    assert isinstance(LLMParseError("x"), ValueError)
    assert not isinstance(LLMUnavailableError("x"), ValueError)


def test_routed_llm_truncates_on_malform():
    class Canned:
        def complete(self, prompt):
            return 'Thought: ok.\nAnswer: {"admit": true}'
    plan = EndpointFaultPlan.single("ep0", 0.0, kind=MALFORM, value=1.0)
    r = EndpointRouter(1, plan, seed=0)
    wrapped = RoutedLLM(Canned(), r)
    out = wrapped.complete("Should the key be admitted? " * 4)
    assert len(out) < len('Thought: ok.\nAnswer: {"admit": true}')
    assert r.malformed == 1


def test_wrappers_fall_back_on_unavailable_and_parse_errors():
    from repro.core.admission import LLMAdmission

    class Unavailable:
        def complete(self, prompt):
            raise LLMUnavailableError("pool down")

    class Garbled:
        def complete(self, prompt):
            return "Thought: hmm.\nAnswer: not json"

    base = TinyLFU()
    pol = LLMAdmission(base, Unavailable())
    assert pol.admit("k", "v", None, {}) == base.admit("k", "v", None, {})
    assert pol.degraded == 1 and pol.llm_total == 0
    pol = LLMAdmission(TinyLFU(), Garbled())
    pol.admit("k", "v", None, {})
    assert pol.parse_fallbacks == 1 and pol.llm_total == 0
    assert pol.agreement == 1.0   # fallbacks are not graded


# ---------------------------------------------------------------------------
# Satellite: stride-based scan-resistant admission
# ---------------------------------------------------------------------------

def test_scan_tinylfu_registered():
    pol = make_admission("scan-tinylfu")
    assert isinstance(pol, ScanTinyLFU) and isinstance(pol, TinyLFU)
    assert pol.name == "scan-tinylfu"


def test_scan_gate_opens_on_sweep_and_stays_shut_on_skew():
    pol = ScanTinyLFU()
    keys = [f"k{i}" for i in range(40)]
    for sweep in range(3):
        for k in keys:
            pol.admit(k, "victim", None, {})
    assert pol.gate_open and pol.gate_opens == 1
    # a skewed candidate stream (popularity-random, uncorrelated with
    # first-seen order) closes the gate again
    rng = random.Random(0)
    for _ in range(200):
        pol.admit(f"k{rng.randrange(40)}", "victim", None, {})
    assert not pol.gate_open and pol.gate_closes >= 1


def test_scan_scenario_hit_gap_closes():
    """The carried PR-3/PR-4 follow-up: install-all beats TinyLFU by ~8pp
    local hits on the scan scenario; the stride-gated variant recovers
    nearly all of it while keeping TinyLFU's win on zipf."""
    common = dict(n_pods=4, reuse_rate=0.3, seed=0, scenario="scan")
    all_in = run_episode(16, 12, admission=None, **common).metrics
    tiny = run_episode(16, 12, admission="tinylfu", **common).metrics
    scan = run_episode(16, 12, admission="scan-tinylfu", **common).metrics
    assert tiny.local_hit_rate < all_in.local_hit_rate   # the known gap
    # the gated variant recovers at least half of the gap
    gap = all_in.local_hit_rate - tiny.local_hit_rate
    assert scan.local_hit_rate >= tiny.local_hit_rate + 0.5 * gap

from repro.core.distributed_cache import PodLocalCacheRouter


def mk(n=4):
    return PodLocalCacheRouter([f"pod{i}" for i in range(n)],
                               capacity_per_pod=3)


LOADER = lambda k: f"data:{k}"
SIZE = lambda v: len(v)


def test_owner_is_deterministic():
    r1, r2 = mk(), mk()
    keys = [f"ds{i}-202{i % 4}" for i in range(20)]
    assert [r1.owner(k) for k in keys] == [r2.owner(k) for k in keys]


def test_keys_spread_across_pods():
    r = mk(4)
    owners = {r.owner(f"ds{i}-2020") for i in range(40)}
    assert len(owners) >= 3


def test_locality_second_fetch_hits():
    r = mk()
    _, pod1, hit1 = r.fetch("xview1-2022", LOADER, SIZE)
    _, pod2, hit2 = r.fetch("xview1-2022", LOADER, SIZE)
    assert pod1 == pod2
    assert (hit1, hit2) == (False, True)
    assert r.stats.local_hits == 1


def test_failover_reroutes_minimally():
    r = mk(4)
    keys = [f"ds{i}-2021" for i in range(24)]
    before = {k: r.owner(k) for k in keys}
    dead = r.owner(keys[0])
    r.fail_pod(dead)
    after = {k: r.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # rendezvous hashing: ONLY keys owned by the dead pod move
    assert all(before[k] == dead for k in moved)
    assert all(after[k] != dead for k in keys)
    # recovery: owner map returns exactly to the original
    r.restore_pod(dead)
    assert {k: r.owner(k) for k in keys} == before


def test_failed_pod_cache_is_cold():
    r = mk(2)
    r.fetch("a-2020", LOADER, SIZE)
    dead = r.owner("a-2020")
    r.fail_pod(dead)
    r.restore_pod(dead)
    _, _, hit = r.fetch("a-2020", LOADER, SIZE)
    assert hit is False                      # contents were lost


def test_failed_pod_rebuild_keeps_router_clock():
    """Regression: fail_pod used to rebuild the pod's DataCache without the
    router's clock, detaching the restored pod from simulated time."""
    t = {"now": 100.0}
    r = PodLocalCacheRouter(["pod0", "pod1"], capacity_per_pod=3,
                            clock=lambda: t["now"])
    r.fetch("a-2020", LOADER, SIZE)
    dead = r.owner("a-2020")
    r.fail_pod(dead)
    r.restore_pod(dead)
    t["now"] = 500.0
    r.fetch("a-2020", LOADER, SIZE)
    e = r.pods[r.owner("a-2020")].entries()["a-2020"]
    assert e.created_at >= 500.0       # rebuilt cache still sees sim time


def test_summary_shape():
    r = mk(2)
    r.fetch("a-2020", LOADER, SIZE)
    s = r.summary()
    assert set(s) >= {"pods", "routed", "local_hit_rate", "failovers"}


# ---------------------------------------------------------------------------
# ISSUE 6: failover purge semantics + idempotency + elastic membership
# ---------------------------------------------------------------------------

def test_fail_pod_idempotent_and_reports():
    r = mk(3)
    r.fetch("a-2020", LOADER, SIZE)
    dead = r.owner("a-2020")
    report = r.fail_pod(dead)
    assert report is not None and report.pod == dead
    assert report.lost_keys == ["a-2020"]
    assert r.fail_pod(dead) is None          # already down: no-op
    assert r.stats.failovers == 1            # not double-counted
    assert r.restore_pod(dead) is True
    assert r.restore_pod(dead) is False      # already live: no-op


def test_fail_pod_purges_in_flight_and_demand_feed():
    """Regression: a dying pod's in-flight loads must abort (a dangling
    record would block the key's next demand load forever) and their
    demand-feed contribution must be un-counted (the load never
    completed; the replicator must not promote on it)."""
    r = mk(3)
    r.spill = object()                       # arm the demand feed
    key = "ds0-2020"
    pod = r.owner(key)
    rec = r.start_load(key, "v", 1, issued_at=0.0, completes_at=5.0)
    assert r.demand_counts == {key: 1}
    other = next(f"x{i}-2020" for i in range(99)
                 if r.owner(f"x{i}-2020") != pod)
    r.start_load(other, "v", 1, issued_at=0.0, completes_at=5.0)
    report = r.fail_pod(pod)
    assert rec.aborted and [a.key for a in report.aborted] == [key]
    assert key not in r.in_flight            # purged
    assert other in r.in_flight              # survivor untouched
    assert key not in r.demand_counts        # un-counted
    assert r.stats.aborted_loads == 1


def test_fail_pod_purges_replicas_and_read_feed():
    r = mk(4)
    key = "ds1-2021"
    hosts = [p for p in r.pods if p != r.owner(key)][:2]
    for h in hosts:
        r.pods[h].put(key, "v", 1)
    r.replicas[key] = list(hosts)
    r.replica_reads[key] = 3
    report = r.fail_pod(hosts[0])
    assert report.lost_replicas == [key]
    assert r.replicas[key] == [hosts[1]]     # surviving copy kept
    assert key in r.replica_reads            # still has a copy: feed kept
    r.fail_pod(hosts[1])
    assert key not in r.replicas             # last copy gone
    assert key not in r.replica_reads        # demotion feed purged with it


def test_scale_out_and_in():
    r = mk(2)
    r.scale_out("pod9")
    assert "pod9" in r.live_pods() and r.stats.scale_outs == 1
    keys = [f"k{i}-2020" for i in range(30)]
    gained = [k for k in keys if r.owner(k) == "pod9"]
    assert gained                            # rendezvous: pod9 wins some
    report = r.scale_in("pod9")
    assert report is not None and r.stats.scale_ins == 1
    assert "pod9" not in r.pods
    assert all(r.owner(k) != "pod9" for k in keys)
    assert r.scale_in("pod9") is None        # unknown pod: no-op

from repro.core.distributed_cache import PodLocalCacheRouter


def mk(n=4):
    return PodLocalCacheRouter([f"pod{i}" for i in range(n)],
                               capacity_per_pod=3)


LOADER = lambda k: f"data:{k}"
SIZE = lambda v: len(v)


def test_owner_is_deterministic():
    r1, r2 = mk(), mk()
    keys = [f"ds{i}-202{i % 4}" for i in range(20)]
    assert [r1.owner(k) for k in keys] == [r2.owner(k) for k in keys]


def test_keys_spread_across_pods():
    r = mk(4)
    owners = {r.owner(f"ds{i}-2020") for i in range(40)}
    assert len(owners) >= 3


def test_locality_second_fetch_hits():
    r = mk()
    _, pod1, hit1 = r.fetch("xview1-2022", LOADER, SIZE)
    _, pod2, hit2 = r.fetch("xview1-2022", LOADER, SIZE)
    assert pod1 == pod2
    assert (hit1, hit2) == (False, True)
    assert r.stats.local_hits == 1


def test_failover_reroutes_minimally():
    r = mk(4)
    keys = [f"ds{i}-2021" for i in range(24)]
    before = {k: r.owner(k) for k in keys}
    dead = r.owner(keys[0])
    r.fail_pod(dead)
    after = {k: r.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # rendezvous hashing: ONLY keys owned by the dead pod move
    assert all(before[k] == dead for k in moved)
    assert all(after[k] != dead for k in keys)
    # recovery: owner map returns exactly to the original
    r.restore_pod(dead)
    assert {k: r.owner(k) for k in keys} == before


def test_failed_pod_cache_is_cold():
    r = mk(2)
    r.fetch("a-2020", LOADER, SIZE)
    dead = r.owner("a-2020")
    r.fail_pod(dead)
    r.restore_pod(dead)
    _, _, hit = r.fetch("a-2020", LOADER, SIZE)
    assert hit is False                      # contents were lost


def test_failed_pod_rebuild_keeps_router_clock():
    """Regression: fail_pod used to rebuild the pod's DataCache without the
    router's clock, detaching the restored pod from simulated time."""
    t = {"now": 100.0}
    r = PodLocalCacheRouter(["pod0", "pod1"], capacity_per_pod=3,
                            clock=lambda: t["now"])
    r.fetch("a-2020", LOADER, SIZE)
    dead = r.owner("a-2020")
    r.fail_pod(dead)
    r.restore_pod(dead)
    t["now"] = 500.0
    r.fetch("a-2020", LOADER, SIZE)
    e = r.pods[r.owner("a-2020")].entries()["a-2020"]
    assert e.created_at >= 500.0       # rebuilt cache still sees sim time


def test_summary_shape():
    r = mk(2)
    r.fetch("a-2020", LOADER, SIZE)
    s = r.summary()
    assert set(s) >= {"pods", "routed", "local_hit_rate", "failovers"}

"""Dry-run machinery smoke test on the local (1-device) mesh.

The production 512-device sweep runs via ``python -m repro.launch.dryrun``
(XLA_FLAGS must be set before jax init); here we exercise the same
lower+compile plumbing — input specs, logical-axis shardings (incl. the
cache pytree), train/prefill/decode paths — with reduced configs on a
(1,1) mesh, so pytest needs no special device flags.
"""
import dataclasses

import jax
import pytest

from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import single_pod_rules
from repro.launch.dryrun import (
    RULE_VARIANTS,
    _lower_cell,
    analytic_hbm_bytes,
    collective_bytes,
)

# heavy lower+compile smokes: CI's full-suite lane runs these (pytest.ini)
pytestmark = pytest.mark.slow

SMALL_SHAPES = {
    "train": ShapeSpec("train_small", 64, 4, "train"),
    "prefill": ShapeSpec("prefill_small", 64, 2, "prefill"),
    "decode": ShapeSpec("decode_small", 64, 2, "decode"),
}


def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["granite-3-2b", "mixtral-8x22b",
                                  "rwkv6-7b", "hymba-1.5b",
                                  "seamless-m4t-large-v2"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_cell(arch, kind):
    cfg = get_config(arch).reduced()
    compiled = _lower_cell(cfg, SMALL_SHAPES[kind], mesh11(),
                           single_pod_rules())
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0]
    assert float(cost.get("flops", 0)) > 0
    assert isinstance(collective_bytes(compiled.as_text()), dict)


def test_variants_lower():
    cfg = get_config("mixtral-8x22b").reduced()
    for name, (rfn, cfn) in RULE_VARIANTS.items():
        compiled = _lower_cell(cfn(cfg), SMALL_SHAPES["decode"], mesh11(),
                               rfn(single_pod_rules()))
        assert compiled is not None


def test_analytic_hbm_monotone_in_seq():
    cfg = get_config("qwen1.5-32b")
    b1 = analytic_hbm_bytes(cfg, SHAPES["decode_32k"], 256)
    small = dataclasses.replace(SHAPES["decode_32k"])
    b2 = analytic_hbm_bytes(cfg, ShapeSpec("d", 8192, 128, "decode"), 256)
    assert b1 > b2 > 0
    # int8 KV cuts decode bytes
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    assert analytic_hbm_bytes(cfgq, SHAPES["decode_32k"], 256) < b1

"""Session->pod affinity + cross-pod read penalty (ISSUE 5).

The differential harness that locks the whole PR-1..5 stack down:

* **degeneracy contract** — any engine config with affinity enabled and
  ``remote_read_penalty=1.0`` replays the affinity-free engine
  bit-identically (times, tokens, answers, every non-locality metric),
  across randomized seeds, scenarios, session/pod counts, and all four
  affinity policies (property-based replay);
* **invariants** — local+remote reads partition the routed logical
  accesses; penalty monotonicity (p50/p95/mean/makespan nondecreasing in
  the penalty wherever the fleet is not queue-saturated — at saturation
  hops decongest pods and the tail can move either way, which is the
  documented closed-loop effect); replication strictly reduces the
  remote-read count on ``affinity_zipf``;
* **PR-4 digest locks** — the full default `table_concurrency` /
  `table_prefetch` / `table_admission` / `table_replication` /
  `belady_bound` tables are bit-identical to the PR-4 tree (affinity off
  is the default, and the ISSUE-5 refactor must not move a single cell);
* **acceptance** — penalty 2x at 16 sessions / 4 pods on ``affinity_zipf``:
  replication beats install-everything by >1.07x p95 across 3 seeds, with
  the remote-read share (not queueing relief) carrying the win;
* **GPT-driven paths** — LLMAdmission / LLMReplication agreement >= 90%
  under the locality-aware prompts, with fixed-seed SimLLM transcripts
  committed as golden files (tests/golden/) so prompt drift fails loudly;
* **prefetch_adaptive default-on** — the confirming workload matrix
  (zipf, scan, hotspot, zipf_global, affinity_zipf): adaptive >= the
  fixed guard's p95 speedup at every matrix cell, and >= lazy.
"""
import hashlib
import json
import pathlib
import random

import pytest

from benchmarks import tables
from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import ConcurrentEpisodeEngine, run_episode
from repro.agent.geollm.simclock import LatencyModel
from repro.agent.geollm.workload import WorkloadSampler
from repro.core.admission import FrequencySketch, LLMAdmission, TinyLFU
from repro.core.cache import CacheEntry
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.locality import (
    AFFINITIES,
    LocalityModel,
    MigratingAffinity,
    make_affinity,
)
from repro.core.replication import LLMReplication, ThresholdReplication

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


def _core_row(res):
    """Metrics row minus the locality_* classification fields (those are
    observability, allowed to differ between affinity on/off at 1x)."""
    return {k: v for k, v in res.metrics.row().items()
            if not k.startswith("locality_")}


# ---------------------------------------------------------------------------
# Affinity policies
# ---------------------------------------------------------------------------

def test_affinity_policies_deterministic_and_in_range():
    for name in AFFINITIES:
        pol = make_affinity(name, n_pods=4)
        homes = [pol.home(sid, 0) for sid in range(32)]
        assert all(0 <= h < 4 for h in homes)
        pol2 = make_affinity(name, n_pods=4)
        assert homes == [pol2.home(sid, 0) for sid in range(32)]


def test_round_robin_and_load_balanced_spread_evenly():
    rr = make_affinity("round_robin", n_pods=4)
    assert [rr.home(s, 0) for s in range(8)] == [0, 1, 2, 3, 0, 1, 2, 3]
    lb = make_affinity("load_balanced", n_pods=3)
    homes = [lb.home(s, 0) for s in range(9)]
    assert sorted(homes.count(p) for p in range(3)) == [3, 3, 3]
    # assignment is sticky per session
    assert [lb.home(s, 5) for s in range(9)] == homes


def test_migrating_affinity_drifts_every_period():
    pol = MigratingAffinity(n_pods=4, period=3)
    assert [pol.home(1, t) for t in range(9)] == [1, 1, 1, 2, 2, 2, 3, 3, 3]
    sticky = make_affinity("sticky", n_pods=4)
    assert sticky.home(7, 0) == sticky.home(7, 100)   # never moves


# ---------------------------------------------------------------------------
# LocalityModel
# ---------------------------------------------------------------------------

def test_charge_classifies_and_prices_reads():
    lat = LatencyModel()
    m = LocalityModel(lat, penalty=3.0)
    assert m.charge("k-2020", "pod0", "pod0", 80.0, 0.0) == 0.0   # local
    extra = m.charge("k-2020", "pod1", "pod0", 80.0, 0.0)
    assert extra == pytest.approx(2.0 * lat.cache_read(80.0))
    assert m.stats.local_reads == 1 and m.stats.remote_reads == 1
    assert m.remote_demand == {"k-2020": {"pod0": 1}}


def test_penalty_one_charges_exactly_zero_even_with_link_queue():
    m = LocalityModel(LatencyModel(), penalty=1.0, link_queue=True)
    for i in range(50):
        assert m.charge(f"k{i}-2020", "pod1", "pod0", 120.0, float(i)) == 0.0
    assert m.stats.remote_reads == 50          # still classified
    assert m.stats.remote_hop_s == 0.0
    assert m.stats.link_stall_s == 0.0
    assert m._link_busy == {}                  # the link never busies


def test_link_queue_serializes_hops_fcfs():
    lat = LatencyModel()
    m = LocalityModel(lat, penalty=2.0, link_queue=True)
    hop = lat.cache_read(50.0)
    first = m.charge("a-2020", "pod1", "pod0", 50.0, 0.0)
    assert first == pytest.approx(hop)
    # a second remote read arriving mid-transfer waits for the link
    second = m.charge("b-2020", "pod2", "pod0", 50.0, hop / 2)
    assert second == pytest.approx(hop / 2 + hop)
    assert m.stats.link_stall_s == pytest.approx(hop / 2)
    # a different HOME pod's link is independent
    assert m.charge("c-2020", "pod0", "pod3", 50.0, 0.0) == pytest.approx(hop)


def test_locate_prefers_home_copy_only_under_penalty():
    def build(penalty):
        sketch = FrequencySketch(width=256)
        r = PodLocalCacheRouter(["p0", "p1", "p2"], capacity_per_pod=2,
                                sketch=sketch)
        r.locality = LocalityModel(LatencyModel(), penalty=penalty)
        key = next(k for k in (f"k{i}-2020" for i in range(50))
                   if r.owner(k) == "p0")
        r.install("p0", key, "V", 1)
        sketch.touch_many([key] * 9)
        r.replicate(key, "V", 1, fanout=None)   # copies on p1 AND p2
        return r, key
    r, key = build(penalty=2.0)
    assert r.locate(key) == "p0"                      # no consumer: owner
    assert r.locate(key, home="p1") == "p1"           # cheapest: home copy
    assert r.locate(key, home="p0") == "p0"
    r1, k1 = build(penalty=1.0)
    # at 1x every placement costs the same: owner-first (PR-4 order)
    assert r1.locate(k1, home="p1") == "p0"


def test_replicate_targets_demanding_consumer_pod():
    sketch = FrequencySketch(width=256)
    r = PodLocalCacheRouter(["p0", "p1", "p2", "p3"], capacity_per_pod=1,
                            sketch=sketch)
    loc = LocalityModel(LatencyModel(), penalty=2.0)
    r.locality = loc
    key = next(k for k in (f"k{i}-2020" for i in range(50))
               if r.owner(k) == "p0")
    sketch.touch_many([key] * 10)
    # sessions homed on p2 keep paying hops for the key
    for _ in range(5):
        loc.charge(key, "p0", "p2", 50.0, 0.0)
    assert r.replicate(key, "V", 1, fanout=1) == 1
    assert r.replicas[key] == ["p2"]          # the demanding pod, not p1


# ---------------------------------------------------------------------------
# Differential replay: penalty 1x is bit-identical to the affinity-free
# engine across randomized configs and every affinity policy
# ---------------------------------------------------------------------------

def _random_configs(n):
    rng = random.Random(0xD1FF)
    scenarios = [("working", {}),
                 ("zipf", {"zipf_a": 1.2}),
                 ("zipf", {"zipf_a": 1.1, "zipf_global": True}),
                 ("scan", {}),
                 ("hotspot", {}),
                 ("affinity_zipf", {"zipf_a": 1.4})]
    out = []
    for i in range(n):
        scen, skw = rng.choice(scenarios)
        affinity = rng.choice(sorted(AFFINITIES))
        if scen == "affinity_zipf":
            # the group a session samples is derived from its home pod;
            # the affinity-free baseline falls back to sid % n_pods, so
            # the workloads only coincide under round_robin homes (other
            # policies change the WORKLOAD binding, not the cost model)
            affinity = "round_robin"
        out.append(dict(
            n_sessions=rng.randint(2, 8),
            tasks=rng.randint(4, 8),
            n_pods=rng.randint(2, 4),
            reuse=rng.choice([0.3, 0.8]),
            seed=rng.randint(0, 10_000),
            scenario=scen, scenario_kw=skw,
            prefetch=rng.random() < 0.5,
            admission=rng.choice([None, "tinylfu"]),
            replication=rng.random() < 0.5,
            affinity=affinity,
            link_queue=rng.random() < 0.5,
        ))
    return out


@pytest.mark.parametrize("cfg", _random_configs(8),
                         ids=lambda c: (f"{c['scenario']}-{c['affinity']}-"
                                        f"s{c['seed']}"))
def test_penalty_one_replays_affinity_free_engine_bit_identically(cfg):
    """THE degeneracy contract: home pods assigned, reads classified, but
    with a 1x penalty not a single clock, token, answer, or shared-state
    decision may move — whatever the workload, affinity policy, data-plane
    feature mix, or link-queue setting."""
    common = dict(n_pods=cfg["n_pods"], reuse_rate=cfg["reuse"],
                  seed=cfg["seed"], scenario=cfg["scenario"],
                  scenario_kw=cfg["scenario_kw"], prefetch=cfg["prefetch"],
                  admission=cfg["admission"])
    if cfg["replication"]:
        common.update(replication=True,
                      replication_kw={"epoch_s": 15.0, "promote_min": 3,
                                      "miss_min": 1})
    base = run_episode(cfg["n_sessions"], cfg["tasks"], **common)
    aff = run_episode(cfg["n_sessions"], cfg["tasks"], **common,
                      affinity=cfg["affinity"], remote_read_penalty=1.0,
                      link_queue=cfg["link_queue"])
    assert _traces(base) == _traces(aff)
    assert _core_row(base) == _core_row(aff)
    # and the locality split still partitions the routed accesses
    m = aff.metrics
    assert (m.locality_local_reads + m.locality_remote_reads
            == aff.router.stats.routed)


def test_penalty_one_llm_paths_replay_bit_identically():
    """The GPT-driven admission/replication prompt paths gain locality
    evidence ONLY under a penalty: at 1x the prompts are byte-identical,
    so the seeded SimLLM replays the same completions/agreement."""
    common = dict(n_pods=3, reuse_rate=0.3, seed=4, admission="tinylfu",
                  admission_impl="llm", replication=True,
                  replication_impl="llm",
                  replication_kw={"epoch_s": 15.0, "promote_min": 3,
                                  "miss_min": 1},
                  scenario="zipf", scenario_kw={"zipf_a": 1.2})
    base = run_episode(6, 6, **common)
    aff = run_episode(6, 6, **common, affinity="round_robin",
                      remote_read_penalty=1.0)
    assert _traces(base) == _traces(aff)
    assert _core_row(base) == _core_row(aff)


def test_locality_kwargs_rejected_without_affinity():
    """A penalty, link queue, or affinity_kw without an affinity policy
    is a misconfiguration, not a silent no-op."""
    for kw in (dict(remote_read_penalty=2.0), dict(link_queue=True),
               dict(affinity_kw={"period": 3})):
        with pytest.raises(AssertionError):
            ConcurrentEpisodeEngine(2, n_pods=2, **kw)


def test_locality_engine_deterministic_at_fixed_seed():
    kw = dict(n_pods=4, reuse_rate=0.3, seed=3, affinity="sticky",
              remote_read_penalty=2.0, link_queue=True, prefetch=True,
              admission="tinylfu", replication=True,
              scenario="affinity_zipf", scenario_kw={"zipf_a": 1.4})
    a = run_episode(8, 8, **kw)
    b = run_episode(8, 8, **kw)
    assert a.metrics.row() == b.metrics.row()
    assert _traces(a) == _traces(b)
    assert a.metrics.locality_remote_hop_s > 0.0


# ---------------------------------------------------------------------------
# Invariants: partition, penalty monotonicity, replication cuts remote reads
# ---------------------------------------------------------------------------

AFFZ = {"scenario": "affinity_zipf",
        "scenario_kw": {"zipf_a": 1.8, "spill_p": 0.1}}
# the table_locality operating point (benchmarks/tables.py)
RKW = {"epoch_s": 10.0, "max_replicated": 12, "promote_min": 3,
       "miss_min": 1, "gain_ratio": 1.2, "top_k": 12}


def test_remote_and_local_reads_partition_total_reads():
    """Under any penalty and feature mix, every routed logical access is
    classified exactly once: local XOR remote."""
    for kw in (dict(),
               dict(prefetch=True),
               dict(admission="tinylfu", replication=True,
                    replication_kw=RKW)):
        res = run_episode(8, 10, n_pods=4, reuse_rate=0.3, seed=1,
                          affinity="sticky", remote_read_penalty=2.0,
                          **AFFZ, **kw)
        m = res.metrics
        assert m.locality_local_reads + m.locality_remote_reads \
            == res.router.stats.routed
        assert m.locality_remote_reads \
            == sum(s.stats.remote_reads for s in res.sessions)
        # session-level hop seconds include any ingress-link wait; the
        # fleet stats split the two
        assert sum(s.stats.remote_hop_s for s in res.sessions) \
            == pytest.approx(m.locality_remote_hop_s
                             + m.locality_link_stall_s)
        assert m.locality_remote_hop_s > 0.0


def test_p95_nondecreasing_in_penalty_below_saturation():
    """Monotonicity holds where the model predicts it: at <= 1:1
    sessions-to-pods the fleet is not queue-saturated, so every extra hop
    is pure added latency (at 4:1 saturation hops decongest the pod queues
    of the closed-loop fleet and the tail can move either way — the
    documented caveat, surfaced in benchmarks/README.md)."""
    ms = [run_episode(8, 10, n_pods=8, reuse_rate=0.3, seed=0,
                      affinity="sticky", remote_read_penalty=pen,
                      **AFFZ).metrics
          for pen in (1.0, 2.0, 4.0)]
    for lo, hi in zip(ms, ms[1:]):
        assert hi.p95_task_latency_s >= lo.p95_task_latency_s
        assert hi.p50_task_latency_s >= lo.p50_task_latency_s
        assert hi.mean_task_latency_s >= lo.mean_task_latency_s
        assert hi.makespan_s >= lo.makespan_s


def test_solo_task_times_pointwise_nondecreasing_in_penalty():
    """With one session there is no queueing at all: every task's time is
    pointwise nondecreasing in the penalty (strict somewhere)."""
    runs = [run_episode(1, 10, n_pods=4, reuse_rate=0.3, seed=0,
                        affinity="sticky", remote_read_penalty=pen, **AFFZ)
            for pen in (1.0, 2.0, 4.0)]
    times = [[t.time_s for s in r.sessions for t in s.traces] for r in runs]
    for lo, hi in zip(times, times[1:]):
        assert all(h >= l for h, l in zip(hi, lo))
        assert sum(hi) > sum(lo)
    # answers are invariant: the penalty moves time, never results
    answers = [[t.answers for s in r.sessions for t in s.traces]
               for r in runs]
    assert answers[0] == answers[1] == answers[2]


def test_replication_strictly_reduces_remote_reads_on_affinity_zipf():
    for seed in (0, 1):
        base = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=seed,
                           affinity="sticky", remote_read_penalty=2.0,
                           **AFFZ).metrics
        rep = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=seed,
                          affinity="sticky", remote_read_penalty=2.0,
                          replication=True, replication_kw=RKW,
                          **AFFZ).metrics
        assert rep.locality_remote_reads < base.locality_remote_reads
        assert rep.locality_remote_read_share \
            < base.locality_remote_read_share - 0.15   # share conversion
        assert rep.replica_hits > 0


# ---------------------------------------------------------------------------
# Acceptance: the table_locality headline cell, seed-robust
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", (0, 1, 2))
def test_locality_headline_repl_beats_install_everything(seed):
    """Penalty 2x, 16 sessions / 4 pods, affinity_zipf (the table_locality
    acceptance cell, double-length stream): replication improves p95 by
    >1.07x over install-everything — past the PR-4 locality-free headline
    — and the win is carried by remote-read-share conversion."""
    base = run_episode(16, 50, n_pods=4, reuse_rate=0.3, seed=seed,
                       affinity="sticky", remote_read_penalty=2.0,
                       **AFFZ).metrics
    rep = run_episode(16, 50, n_pods=4, reuse_rate=0.3, seed=seed,
                      affinity="sticky", remote_read_penalty=2.0,
                      replication=True, replication_kw=RKW, **AFFZ).metrics
    assert base.p95_task_latency_s / rep.p95_task_latency_s > 1.07
    assert rep.locality_remote_read_share < 0.65 < \
        base.locality_remote_read_share


# ---------------------------------------------------------------------------
# PR-4 digest locks: affinity off (the default) moves NOTHING
# ---------------------------------------------------------------------------

PR4_CONCURRENCY_DIGEST = "8ec8ff89cfb17741"
PR4_PREFETCH_DIGEST = "13335d76f3b853b8"
PR4_ADMISSION_DIGEST = "0ab4ceee8be81cc2"
PR4_REPLICATION_DIGEST = "4b8558d2647170c5"
PR4_BELADY_DIGEST = "0f372094aa0edaf3"


def test_table_concurrency_bit_identical_to_pr4():
    assert _digest(tables.table_concurrency(tasks_per_session=25)) \
        == PR4_CONCURRENCY_DIGEST


def test_table_prefetch_bit_identical_to_pr4_under_new_default():
    """prefetch_adaptive now defaults ON; the table pins the fixed-guard
    mode explicitly, so every row (lazy, fixed, adaptive) replays PR-4
    bit-identically — this is the re-lock under the new default."""
    assert _digest(tables.table_prefetch(tasks_per_session=25)) \
        == PR4_PREFETCH_DIGEST


def test_table_admission_bit_identical_to_pr4():
    assert _digest(tables.table_admission(tasks_per_session=25)) \
        == PR4_ADMISSION_DIGEST


def test_table_replication_bit_identical_to_pr4():
    assert _digest(tables.table_replication(tasks_per_session=25)) \
        == PR4_REPLICATION_DIGEST


def test_belady_bit_identical_to_pr4():
    assert _digest(tables.belady_bound(n=200)) == PR4_BELADY_DIGEST


# ---------------------------------------------------------------------------
# prefetch_adaptive default-on: the confirming workload matrix
# ---------------------------------------------------------------------------

# each scenario is paired with the contention regime where the depth guard
# is load-bearing: the mid-range (8/8) for the skewed per-session streams,
# saturation (16/4) for the shared-order scan/hotspot/zipf_global streams.
# (At the other regime the two guards are within tail noise of each other;
# the adaptive controller's constants are PR-4 digest-locked, so the matrix
# confirms the default flip rather than retuning the guard.)
ADAPTIVE_MATRIX = [
    ("zipf", {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.2}}, 8, 8),
    ("scan", {"scenario": "scan"}, 16, 4),
    ("hotspot", {"scenario": "hotspot"}, 16, 4),
    ("zipf_global", {"scenario": "zipf",
                     "scenario_kw": {"zipf_a": 1.1, "zipf_global": True}},
     16, 4),
    ("affinity_zipf", {"scenario": "affinity_zipf",
                       "scenario_kw": {"zipf_a": 1.3}}, 8, 8),
]


@pytest.mark.parametrize("name,kw,ns,npod", ADAPTIVE_MATRIX,
                         ids=[c[0] for c in ADAPTIVE_MATRIX])
def test_adaptive_guard_beats_fixed_guard_across_workloads(name, kw, ns,
                                                           npod):
    lazy = run_episode(ns, 25, n_pods=npod, reuse_rate=0.3, seed=0,
                       **kw).metrics
    fixed = run_episode(ns, 25, n_pods=npod, reuse_rate=0.3, seed=0,
                        prefetch=True, prefetch_adaptive=False,
                        **kw).metrics
    adaptive = run_episode(ns, 25, n_pods=npod, reuse_rate=0.3, seed=0,
                           prefetch=True, **kw).metrics   # the new default
    sp_fixed = lazy.p95_task_latency_s / fixed.p95_task_latency_s
    sp_adaptive = lazy.p95_task_latency_s / adaptive.p95_task_latency_s
    assert sp_adaptive >= sp_fixed, (name, sp_adaptive, sp_fixed)
    assert sp_adaptive >= 1.0, (name, sp_adaptive)   # never loses to lazy


def test_prefetch_adaptive_is_the_default():
    eng = ConcurrentEpisodeEngine(2, n_pods=2)
    assert eng.prefetch_adaptive is True
    a = run_episode(6, 8, n_pods=4, seed=0, prefetch=True).metrics.row()
    b = run_episode(6, 8, n_pods=4, seed=0, prefetch=True,
                    prefetch_adaptive=True).metrics.row()
    assert a == b


# ---------------------------------------------------------------------------
# affinity_zipf sampler
# ---------------------------------------------------------------------------

def test_affinity_zipf_groups_partition_keys_and_spill():
    s0 = WorkloadSampler(0.3, seed=1, scenario="affinity_zipf", n_groups=4,
                         group=0, zipf_a=1.8, spill_p=0.0)
    own = set(s0._aff_groups[0])
    draws = [s0._sample_key() for _ in range(300)]
    assert set(draws) <= own                      # no spill: stays in-group
    groups = s0._aff_groups
    assert sorted(k for g in groups for k in g) == sorted(s0.keys)
    s1 = WorkloadSampler(0.3, seed=99, scenario="affinity_zipf", n_groups=4,
                         group=1, zipf_a=1.8, spill_p=0.0)
    assert s1._aff_groups == groups               # seed-independent split
    sp = WorkloadSampler(0.3, seed=1, scenario="affinity_zipf", n_groups=4,
                         group=0, zipf_a=1.8, spill_p=0.5)
    spills = sum(k not in own for k in (sp._sample_key()
                                        for _ in range(400)))
    assert 100 < spills < 300                     # ~50% cross-group


def test_affinity_zipf_group_bound_to_home_pod():
    res = run_episode(8, 4, n_pods=4, reuse_rate=0.3, seed=0,
                      affinity="round_robin", remote_read_penalty=2.0,
                      **AFFZ)
    sampler = WorkloadSampler(0.3, scenario="affinity_zipf", n_groups=4,
                              group=0, zipf_a=1.8, spill_p=0.1)
    groups = sampler._aff_groups
    for s in res.sessions:
        gi = int(s.home_pod.replace("pod", ""))   # round_robin: sid % 4
        assert gi == s.sid % 4
        own = set(groups[gi])
        keys = [k for t in s.tasks for k in t.required_keys]
        # the large majority of a session's keys come from its home group
        assert sum(k in own for k in keys) >= 0.6 * len(keys)


# ---------------------------------------------------------------------------
# GPT-driven paths under locality-aware prompts: graded + golden transcripts
# ---------------------------------------------------------------------------

def _build_admission_transcript():
    """Fixed-seed LLMAdmission transcript under locality evidence: the
    decisions, prompts (hashed; first one verbatim) and completions are
    deterministic, so any prompt/SimLLM drift diffs against the committed
    golden file."""
    sketch = FrequencySketch(width=256, age_period_s=0)
    loc = LocalityModel(LatencyModel(), penalty=2.0)
    adm = LLMAdmission(TinyLFU(),
                       SimLLM(Profile("gpt-4-turbo", "cot", True), seed=11))
    adm.locality = loc
    rng = random.Random(5)
    keys = [f"k{i}-2020" for i in range(12)]
    for k in keys:
        sketch.touch_many([k] * rng.randint(0, 9))
        for _ in range(rng.randint(0, 4)):
            loc.charge(k, "pod0", f"pod{rng.randint(1, 3)}", 60.0, 0.0)
    records = []
    example = None
    for i in range(40):
        key, victim = rng.sample(keys, 2)
        entries = {victim: CacheEntry(key=victim, value=None, size_bytes=0,
                                      created_at=0.0, last_access=float(i),
                                      access_count=1, insert_order=i)}
        from repro.core.prompts import admission_decision_prompt
        from repro.core.admission import entries_json
        prompt = admission_decision_prompt(
            adm.base.describe(), key, victim,
            *sketch.estimate_many((key, victim)),
            entries_json(entries), True,
            home_demand_json=adm._home_demand_json(key))
        if example is None:
            example = prompt
        got = adm.admit(key, victim, sketch, entries)
        expected = adm.base.admit(key, victim, sketch, entries)
        records.append({
            "key": key, "victim": victim,
            "key_freq": sketch.estimate(key),
            "victim_freq": sketch.estimate(victim),
            "prompt_sha": hashlib.sha256(prompt.encode()).hexdigest()[:16],
            "expected": "admit" if expected else "bypass",
            "decision": "admit" if got else "bypass",
        })
    return {
        "kind": "admission", "policy": adm.name, "seed": 11,
        "model": "gpt-4-turbo", "penalty": 2.0,
        "agreement": round(adm.agreement, 4),
        "example_prompt": example,
        "decisions": records,
    }


def _build_replication_transcript():
    pol = LLMReplication(ThresholdReplication(promote_min=8,
                                              demote_frac=0.5),
                         SimLLM(Profile("gpt-4-turbo", "cot", True),
                                seed=13))
    pol.set_evidence([("hot-2021", 12), ("warm-2020", 7), ("cool-2019", 3)])
    pol.set_home_demand({
        "hot-2021": {"pod1": 9, "pod3": 4},
        "warm-2020": {"pod2": 2},
    })
    rng = random.Random(7)
    keys = ["hot-2021", "warm-2020", "cool-2019", "cold-2018"]
    freqs = {"hot-2021": 12, "warm-2020": 7, "cool-2019": 3, "cold-2018": 1}
    records = []
    example = None
    for i in range(40):
        key = rng.choice(keys)
        replicated = rng.random() < 0.5
        from repro.core.prompts import replication_decision_prompt
        hd = pol._home_demand.get(key)
        prompt = replication_decision_prompt(
            pol.base.describe(), key, freqs[key], replicated,
            pol.base.promote_min, pol.base.demote_min, pol._top_json, True,
            home_demand_json=(json.dumps(hd, sort_keys=True) if hd
                              else None))
        if example is None:
            example = prompt
        got = pol.decide(key, freqs[key], replicated)
        expected = pol.base.decide(key, freqs[key], replicated)
        records.append({
            "key": key, "freq": freqs[key], "replicated": replicated,
            "prompt_sha": hashlib.sha256(prompt.encode()).hexdigest()[:16],
            "expected": expected, "decision": got,
        })
    return {
        "kind": "replication", "policy": pol.name, "seed": 13,
        "model": "gpt-4-turbo", "penalty": 2.0,
        "agreement": round(pol.agreement, 4),
        "example_prompt": example,
        "decisions": records,
    }


@pytest.mark.parametrize("name,builder", [
    ("admission_locality", _build_admission_transcript),
    ("replication_locality", _build_replication_transcript),
])
def test_llm_transcripts_match_golden_and_agree(name, builder):
    """Locality-aware prompt drift fails loudly: the regenerated
    fixed-seed transcript must equal the committed golden file exactly
    (regenerate with tests/golden/regen.py after an INTENTIONAL prompt
    change), and graded agreement stays >= 90%."""
    got = builder()
    assert got["agreement"] >= 0.90, got["agreement"]
    path = GOLDEN_DIR / f"{name}.json"
    golden = json.loads(path.read_text())
    assert got == golden, (
        f"{name} transcript drifted from {path} — if the prompt change is "
        f"intentional, regenerate via: PYTHONPATH=src:. python "
        f"tests/golden/regen.py")


def test_llm_agreement_in_locality_engine_run():
    """End-to-end: the GPT-driven admission+replication paths keep >= 90%
    agreement inside a penalty-2x engine episode (the prompts now carry
    the home-demand evidence lines)."""
    m = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                    affinity="sticky", remote_read_penalty=2.0,
                    admission="tinylfu", admission_impl="llm",
                    replication=True, replication_impl="llm",
                    replication_kw=RKW, **AFFZ).metrics
    assert m.admitted + m.bypassed > 0
    assert m.replication_promotes > 0
    assert m.admission_agreement >= 0.90
    assert m.replication_agreement >= 0.90


def test_remote_demand_windowed_without_replicator():
    """Consumer-demand evidence stays a recent-demand signal: the
    replicator drains it per epoch when wired; otherwise the engine arms
    the model's sim-time window and the map self-drains."""
    m = LocalityModel(LatencyModel(), penalty=2.0)
    m.demand_window_s = 10.0
    m.charge("a-2020", "pod1", "pod0", 50.0, 1.0)
    m.charge("b-2020", "pod1", "pod0", 50.0, 5.0)
    assert set(m.remote_demand) == {"a-2020", "b-2020"}
    m.charge("c-2020", "pod1", "pod0", 50.0, 12.0)   # crosses the window
    assert set(m.remote_demand) == {"c-2020"}
    eng = ConcurrentEpisodeEngine(2, n_pods=2, affinity="sticky",
                                  remote_read_penalty=2.0)
    assert eng.locality.demand_window_s == 60.0
    eng2 = ConcurrentEpisodeEngine(2, n_pods=2, affinity="sticky",
                                   remote_read_penalty=2.0,
                                   replication=True)
    assert eng2.locality.demand_window_s == 0.0      # epoch-drained
    # penalty 1x records no demand at all (placement evidence is unused)
    m1 = LocalityModel(LatencyModel(), penalty=1.0)
    m1.charge("a-2020", "pod1", "pod0", 50.0, 1.0)
    assert m1.remote_demand == {}


def test_cache_admit_tool_exposes_remote_demand_in_engine():
    res = run_episode(6, 8, n_pods=3, reuse_rate=0.3, seed=1,
                      affinity="sticky", remote_read_penalty=2.0,
                      admission="tinylfu", **AFFZ)
    reg = res.sessions[0].runner.registry
    assert "cache_admit" in reg
    loc = res.router.locality
    assert loc.remote_demand            # hops were paid this window
    key = next(iter(loc.remote_demand))
    out = reg.call("cache_admit", key=key).value
    assert out["remote_demand"] == loc.remote_demand[key]
    # without affinity the tool reports no locality field
    plain = run_episode(4, 4, n_pods=2, reuse_rate=0.3, seed=1,
                        admission="tinylfu")
    out2 = plain.sessions[0].runner.registry.call(
        "cache_admit", key="xview1-2020").value
    assert "remote_demand" not in out2


def test_locality_prompt_lines_only_render_with_evidence():
    from repro.core.prompts import (admission_decision_prompt,
                                    replication_decision_prompt)
    bare = admission_decision_prompt("p", "k-1", "v-1", 3, 1, "{}", True)
    assert "Remote consumer demand" not in bare
    rich = admission_decision_prompt("p", "k-1", "v-1", 3, 1, "{}", True,
                                     home_demand_json='{"pod1": 4}')
    assert 'Remote consumer demand' in rich and '{"pod1": 4}' in rich
    bare_r = replication_decision_prompt("p", "k-1", 9, False, 8, 4, "[]",
                                         True)
    assert "Remote consumer demand" not in bare_r
    rich_r = replication_decision_prompt("p", "k-1", 9, False, 8, 4, "[]",
                                         True, home_demand_json='{"pod2": 7}')
    assert 'Remote consumer demand' in rich_r and '{"pod2": 7}' in rich_r

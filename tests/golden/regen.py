"""Regenerate the golden LLM transcripts for the locality-aware prompts.

    PYTHONPATH=src:. python tests/golden/regen.py

Only run this after an INTENTIONAL prompt or SimLLM change — the golden
files exist so that unintentional drift fails tests/test_locality.py
loudly. The transcripts are fully deterministic (fixed-seed SimLLM), so a
regeneration on an unchanged tree is a no-op.
"""
import importlib.util
import json
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, HERE.parent / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    return mod


loc = _load("test_locality")
flt = _load("test_faults")
coh = _load("test_coherence")

for name, builder in (
        ("admission_locality", loc._build_admission_transcript),
        ("replication_locality", loc._build_replication_transcript),
        ("recovery", flt._build_recovery_transcript),
        ("cache_update", coh._build_coherence_transcript)):
    path = HERE / f"{name}.json"
    transcript = builder()
    path.write_text(json.dumps(transcript, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path} (agreement {transcript['agreement']:.2%}, "
          f"{len(transcript['decisions'])} decisions)")

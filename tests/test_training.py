import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Init, init_model, unbox
from repro.training import (
    AdamWConfig,
    TokenStream,
    adamw_update,
    init_opt_state,
    make_train_step,
    schedule,
)
from repro.training.grad_compress import (
    compress,
    compress_with_feedback,
    decompress,
)


def small_cfg():
    return get_config("dcache-agent-150m").reduced()


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=0.05)
    assert lrs[4] == pytest.approx(1e-4, rel=0.1)       # min_lr_frac


def test_adamw_moves_params_against_gradient():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                      total_steps=10)
    p2, opt2, m = adamw_update(cfg, params, grads, opt)
    assert (np.asarray(p2["w"]) < 1.0).all()
    assert int(opt2["step"]) == 1
    assert m["grad_norm"] > 0


@pytest.mark.slow
@pytest.mark.slow
def test_loss_decreases_over_training():
    cfg = small_cfg()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=40)))
    opt = init_opt_state(params)
    stream = TokenStream(cfg, batch=8, seq=32, seed=0)
    losses = []
    for _ in range(25):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


@pytest.mark.slow
@pytest.mark.slow
def test_grad_accum_matches_full_batch():
    cfg = dataclasses.replace(small_cfg(), dtype="float32")
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=jnp.float32), cfg))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          grad_clip=1e9)
    stream = TokenStream(cfg, batch=8, seq=16, seed=3)
    batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    opt = init_opt_state(params)
    p1, _, _ = make_train_step(cfg, opt_cfg, accum_steps=1)(params, opt, batch)
    p2, _, _ = make_train_step(cfg, opt_cfg, accum_steps=2)(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compress_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.1, (1000,)), jnp.float32)
    codes, scale = compress(g)
    assert codes.dtype == jnp.int8
    approx = decompress(codes, scale, g.shape)
    err = np.abs(np.asarray(approx - g))
    assert err.max() <= float(np.abs(np.asarray(g)).max()) / 127 + 1e-6


def test_error_feedback_accumulates_lost_mass():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 0.1, (512,)), jnp.float32)
    res = jnp.zeros_like(g)
    total_applied = jnp.zeros_like(g)
    for _ in range(30):
        codes, scale, res = compress_with_feedback(g, res)
        total_applied = total_applied + decompress(codes, scale, g.shape)
    # after N steps, mean applied update ~= true gradient (unbiased)
    np.testing.assert_allclose(np.asarray(total_applied / 30),
                               np.asarray(g), atol=2e-3)


def test_compressed_psum_single_device():
    from repro.training.grad_compress import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    g = jnp.linspace(-1, 1, 256)
    f = shard_map(lambda x: compressed_psum(x, "data"), mesh=mesh,
                  in_specs=P(), out_specs=P(), check_rep=False)
    out = f(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-2)

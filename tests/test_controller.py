"""Controller tests: the four Table III configurations + error handling."""
import pytest

from repro.agent.backends import Profile, SimLLM
from repro.core.cache import DataCache
from repro.core.controller import (
    LLMController,
    ProgrammaticController,
    make_controller,
)
from repro.core.policies import make_policy


def mk(read_impl="llm", update_impl="llm", eps_model="gpt-4-turbo"):
    cache = DataCache(capacity=3)
    llm = SimLLM(Profile(eps_model, "cot", True), seed=0)
    ctrl = make_controller(cache, make_policy("lru"), llm=llm,
                           read_impl=read_impl, update_impl=update_impl)
    return cache, ctrl


LOADER = staticmethod(lambda k: f"data:{k}")
SIZE = staticmethod(lambda v: len(v))


def test_programmatic_read_plan_exact():
    cache, ctrl = mk("python", "python")
    assert isinstance(ctrl, ProgrammaticController)
    cache.put("x-2020", 1, 1)
    plan = ctrl.plan_reads("q", ["x-2020", "y-2021"])
    assert plan.choices == {"x-2020": "read_cache", "y-2021": "load_db"}


def test_programmatic_update_applies_lru():
    cache, ctrl = mk("python", "python")
    for k in ("a", "b", "c"):
        cache.put(k, k, 1)
    cache.get("a"); cache.get("c")           # b least recent
    ctrl.update(["d"], lambda k: k, lambda v: 1)
    assert "b" not in cache and "d" in cache


def test_llm_controller_read_grading():
    cache, ctrl = mk("llm", "llm")
    assert isinstance(ctrl, LLMController)
    cache.put("x-2020", 1, 1)
    for _ in range(30):
        ctrl.plan_reads("show x-2020 and y-2021", ["x-2020", "y-2021"])
    st = cache.stats
    assert st.llm_total_decisions == 60
    # gpt-4 eps=3.4%: overwhelming majority correct
    assert st.llm_correct_decisions / st.llm_total_decisions > 0.85


def test_llm_update_matches_programmatic_mostly():
    cache, ctrl = mk("llm", "llm")
    keys = [f"d{i}-2020" for i in range(12)]
    for k in keys:
        ctrl.update([k], lambda k: k, lambda v: 1)
        assert len(cache) <= cache.capacity
    st = cache.stats
    assert st.gpt_hit_rate > 0.7


def test_mixed_table3_grid_runs():
    for r in ("python", "llm"):
        for u in ("python", "llm"):
            cache, ctrl = mk(r, u)
            ctrl.plan_reads("q", ["a-2020"])
            ctrl.update(["a-2020"], lambda k: k, lambda v: 1)
            assert "a-2020" in cache


class BrokenLLM:
    def complete(self, prompt):
        return "I cannot help with that."


def test_malformed_completion_falls_back_safe():
    cache = DataCache(capacity=2)
    ctrl = LLMController(cache, make_policy("lru"), BrokenLLM())
    plan = ctrl.plan_reads("q", ["a-2020"])
    assert plan.choices["a-2020"] == "load_db"   # safe slow path
    ctrl.update(["a-2020"], lambda k: k, lambda v: 1)
    assert "a-2020" in cache                      # programmatic fallback

"""Open-loop traffic engine + SLO/capacity harness (ISSUE 7).

The test archetype of this PR: every new behavior ships with a property
or statistical lock —

* **generators** — seeded statistical tests: Poisson inter-arrival
  mean/variance (CV^2 ~ 1), MMPP regime occupancy vs the stationary
  dwell ratio plus burstiness (CV^2 > 1), diurnal rate integral ~
  realized session count; determinism (same seed -> identical schedule);
* **closed-loop degeneracy** — the degenerate arrival schedule
  (everything at t=0, unbounded lifetimes) replays the closed-loop
  engine bit-identically: property-tested over randomized configs
  (extending the tests/test_locality.py harness pattern) and
  digest-locked against the PR-4 `table_concurrency` and PR-6
  `table_resilience` tables;
* **queueing locks** — flow balance (spawned == completed + in_system,
  in_system == 0 at episode end) on every capacity cell, Little's law
  |L - lambda*W| at float precision, SLO attainment monotone
  non-increasing in offered load, and a finite knee for >= 3 configs;
* **fail-fast validation** — negative/zero rates, horizons, lifetime
  bounds, SLO targets, penalties and probabilities raise ValueError at
  construction (regression: they used to be silent NaN/stall bait);
* **warm-up-aware autoscaler** (the PR-6 follow-up) — unit-level gate
  semantics plus the end-to-end MMPP-surge comparison: the gate defers
  scale_outs under short surges, cutting membership churn without
  giving up the tail.
"""
import hashlib
import random
import statistics

import pytest

from benchmarks import tables
from repro.agent.concurrency import ConcurrentEpisodeEngine, run_episode
from repro.agent.geollm.workload import WorkloadSampler
from repro.core.faults import SCALE_OUT, BacklogAutoscaler, FaultPlan
from repro.core.traffic import (
    ClosedLoopTraffic,
    DiurnalTraffic,
    MMPPTraffic,
    PoissonTraffic,
    SessionArrival,
    TrafficStats,
    find_knee,
    make_traffic,
    slo_attainment,
)

# the PR-4 lock test_locality.py already holds on the default table, and
# the PR-6 fault-matrix reference at the 12-task stream this file replays
PR4_CONCURRENCY_DIGEST = "8ec8ff89cfb17741"
PR6_RESILIENCE_DIGEST_12 = "9ed9f62ca396989d"

ZIPFG = {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.1,
                                             "zipf_global": True}}


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


def _core_row(res):
    """Metrics row minus the traffic_* ledger (observability fields the
    open-loop run fills and the closed-loop baseline leaves zero)."""
    return {k: v for k, v in res.metrics.row().items()
            if not k.startswith("traffic_")}


def _gaps(schedule):
    ts = [a.at for a in schedule]
    return [b - a for a, b in zip([0.0] + ts[:-1], ts)]


# ---------------------------------------------------------------------------
# Arrival generators: seeded statistical locks + determinism
# ---------------------------------------------------------------------------

def test_poisson_interarrival_mean_and_variance():
    """Exponential inter-arrivals at rate 0.5/s: mean ~ 2s, variance ~
    4s^2, CV^2 ~ 1 (the memoryless signature) at a fixed seed."""
    sched = PoissonTraffic(0.5, 4000.0, seed=7).schedule()
    gaps = _gaps(sched)
    mu = statistics.mean(gaps)
    var = statistics.variance(gaps)
    assert len(sched) > 1500
    assert mu == pytest.approx(2.0, rel=0.10)
    assert var == pytest.approx(4.0, rel=0.25)
    assert var / mu ** 2 == pytest.approx(1.0, rel=0.15)
    assert all(a.at < 4000.0 for a in sched)
    # arrival times strictly increase (exponential gaps are never 0)
    assert all(b.at > a.at for a, b in zip(sched, sched[1:]))


def test_poisson_expected_count():
    p = PoissonTraffic(0.5, 4000.0, seed=7)
    assert len(p) == pytest.approx(p.expected_sessions(), rel=0.10)


def test_same_seed_identical_schedule_different_seed_not():
    for build in (lambda s: PoissonTraffic(0.3, 500.0, seed=s,
                                           lifetime_tasks=(2, 9)),
                  lambda s: DiurnalTraffic(0.3, 500.0, seed=s),
                  lambda s: MMPPTraffic(0.1, 0.8, 500.0, seed=s)):
        a, b = build(3).schedule(), build(3).schedule()
        assert a == b                      # dataclass equality: at+lifetime
        assert build(3).schedule() != build(4).schedule()


def test_schedule_is_memoized_and_pure():
    p = PoissonTraffic(0.2, 300.0, seed=1)
    assert p.schedule() is p.schedule()


def test_lifetime_sampling_bounded_and_seeded():
    sched = PoissonTraffic(0.5, 1000.0, seed=9,
                           lifetime_tasks=(3, 7)).schedule()
    assert all(3 <= a.lifetime_tasks <= 7 for a in sched)
    assert len({a.lifetime_tasks for a in sched}) > 1   # actually sampled
    fixed = PoissonTraffic(0.5, 200.0, seed=9, lifetime_tasks=5).schedule()
    assert all(a.lifetime_tasks == 5 for a in fixed)


def test_mmpp_regime_occupancy_and_burstiness():
    """Realized high-regime occupancy ~ dwell_high/(dwell_low+dwell_high)
    and inter-arrival CV^2 >> 1 (the burstiness MMPP exists to model)."""
    mm = MMPPTraffic(0.1, 1.0, 4000.0, dwell_low_s=60.0, dwell_high_s=20.0,
                     seed=5)
    sched = mm.schedule()
    occ = mm.high_time_s / (mm.high_time_s + mm.low_time_s)
    assert occ == pytest.approx(mm.stationary_high, abs=0.05)
    assert mm.switches > 50
    assert mm.high_time_s + mm.low_time_s == pytest.approx(4000.0)
    gaps = _gaps(sched)
    cv2 = statistics.variance(gaps) / statistics.mean(gaps) ** 2
    assert cv2 > 1.5                       # a plain Poisson sits at ~1.0
    # realized rate ~ dwell-weighted offered rate
    assert len(sched) / 4000.0 == pytest.approx(mm.offered_rate, rel=0.10)


def test_diurnal_integral_matches_count_and_profile_shows():
    d = DiurnalTraffic(0.4, 2400.0, amplitude=0.8, period_s=240.0, seed=11)
    sched = d.schedule()
    assert len(sched) == pytest.approx(d.expected_sessions(), rel=0.10)
    # the mid-period (peak) half must carry well over half the arrivals
    peak = sum(1 for a in sched
               if 0.25 <= (a.at % d.period_s) / d.period_s < 0.75)
    trough = len(sched) - peak
    assert peak / max(trough, 1) > 2.0
    # rate_at spans [base*(1-amp), base*(1+amp)]
    assert d.rate_at(0.0) == pytest.approx(0.4 * 0.2)
    assert d.rate_at(120.0) == pytest.approx(0.4 * 1.8)


def test_closed_loop_schedule_is_degenerate():
    c = ClosedLoopTraffic(5)
    assert c.schedule() == [SessionArrival(0.0, None)] * 5
    assert make_traffic("closed", 3).schedule() == \
        [SessionArrival(0.0, None)] * 3
    p = PoissonTraffic(0.5, 100.0, seed=0)
    assert make_traffic(p, 99) is p        # pass-through, count ignored


# ---------------------------------------------------------------------------
# Fail-fast validation (regression: silent NaN/stall bait)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("build", [
    lambda: PoissonTraffic(0.0, 100.0),
    lambda: PoissonTraffic(-1.0, 100.0),
    lambda: PoissonTraffic(0.5, 0.0),
    lambda: PoissonTraffic(0.5, -5.0),
    lambda: PoissonTraffic(0.5, 100.0, lifetime_tasks=0),
    lambda: PoissonTraffic(0.5, 100.0, lifetime_tasks=(0, 4)),
    lambda: PoissonTraffic(0.5, 100.0, lifetime_tasks=(5, 2)),
    lambda: PoissonTraffic(0.5, 100.0, max_arrivals=0),
    lambda: DiurnalTraffic(0.0, 100.0),
    lambda: DiurnalTraffic(0.4, 100.0, amplitude=-0.1),
    lambda: DiurnalTraffic(0.4, 100.0, amplitude=1.5),
    lambda: DiurnalTraffic(0.4, 100.0, period_s=0.0),
    lambda: MMPPTraffic(0.0, 1.0, 100.0),
    lambda: MMPPTraffic(1.0, 0.5, 100.0),     # high < low
    lambda: MMPPTraffic(0.1, 1.0, 100.0, dwell_low_s=0.0),
    lambda: MMPPTraffic(0.1, 1.0, 100.0, dwell_high_s=-2.0),
    lambda: ClosedLoopTraffic(0),
    lambda: make_traffic("open-sesame", 4),
    lambda: slo_attainment([1.0], 0.0),
    lambda: find_knee([(0.1, 5.0)], -1.0),
], ids=lambda b: "case")
def test_traffic_params_fail_fast(build):
    with pytest.raises(ValueError):
        build()


def test_empty_schedule_fails_fast():
    # rate*horizon << 1 at this seed produces zero arrivals: the engine
    # must refuse to build, not run an empty fleet into NaN metrics
    with pytest.raises(ValueError, match="empty"):
        PoissonTraffic(1e-6, 1.0, seed=0).schedule()


def test_max_arrivals_guard_fails_fast():
    with pytest.raises(ValueError, match="max_arrivals"):
        PoissonTraffic(10.0, 100.0, seed=0, max_arrivals=50).schedule()


def test_engine_params_fail_fast():
    with pytest.raises(ValueError, match="remote_read_penalty"):
        ConcurrentEpisodeEngine(2, n_pods=2, affinity="sticky",
                                remote_read_penalty=0.5)
    with pytest.raises(ValueError, match="capacity_per_pod"):
        ConcurrentEpisodeEngine(2, n_pods=2, capacity_per_pod=0)
    with pytest.raises(ValueError, match="tasks_per_session"):
        ConcurrentEpisodeEngine(2, n_pods=2).run(tasks_per_session=0)
    with pytest.raises(ValueError, match="reuse_rate"):
        ConcurrentEpisodeEngine(2, n_pods=2).run(5, reuse_rate=1.5)
    with pytest.raises(ValueError, match="traffic"):
        ConcurrentEpisodeEngine(2, n_pods=2, traffic="bogus")


def test_workload_sampler_params_fail_fast():
    for kw in (dict(reuse_rate=-0.1), dict(reuse_rate=1.1),
               dict(scenario="nope"), dict(zipf_a=0.0),
               dict(scenario="hotspot", hot_p=1.5),
               dict(scenario="hotspot", hot_k=0),
               dict(scenario="hotspot", phase_len=0),
               dict(scenario="affinity_zipf", spill_p=-0.2)):
        with pytest.raises(ValueError):
            WorkloadSampler(**kw)


def test_capacity_table_rejects_bad_slo():
    with pytest.raises(ValueError, match="slo_p99_s"):
        tables.table_capacity(slo_p99_s=0.0)


# ---------------------------------------------------------------------------
# Closed-loop degeneracy: property replay + digest locks
# ---------------------------------------------------------------------------

def _random_configs(n):
    rng = random.Random(0x7AFF1C)
    scenarios = [("working", {}),
                 ("zipf", {"zipf_a": 1.2}),
                 ("zipf", {"zipf_a": 1.1, "zipf_global": True}),
                 ("scan", {}),
                 ("hotspot", {})]
    out = []
    for _ in range(n):
        scen, skw = rng.choice(scenarios)
        out.append(dict(
            n_sessions=rng.randint(2, 8),
            tasks=rng.randint(4, 8),
            n_pods=rng.randint(2, 4),
            reuse=rng.choice([0.3, 0.8]),
            seed=rng.randint(0, 10_000),
            scenario=scen, scenario_kw=skw,
            prefetch=rng.random() < 0.5,
            admission=rng.choice([None, "tinylfu"]),
            replication=rng.random() < 0.5,
            faults=rng.random() < 0.5,
        ))
    return out


@pytest.mark.parametrize("cfg", _random_configs(8),
                         ids=lambda c: (f"{c['scenario']}-s{c['seed']}"
                                        + ("-f" if c["faults"] else "")))
def test_closed_loop_traffic_replays_engine_bit_identically(cfg):
    """THE degeneracy contract: spawning every session at t=0 with an
    unbounded lifetime through the spawn/retire event path replays the
    closed-loop engine bit-identically — times, tokens, answers, every
    non-traffic metric — whatever the workload, data-plane feature mix,
    or fault schedule."""
    common = dict(n_pods=cfg["n_pods"], reuse_rate=cfg["reuse"],
                  seed=cfg["seed"], scenario=cfg["scenario"],
                  scenario_kw=cfg["scenario_kw"], prefetch=cfg["prefetch"],
                  admission=cfg["admission"])
    if cfg["replication"]:
        common.update(replication=True,
                      replication_kw={"epoch_s": 15.0, "promote_min": 3,
                                      "miss_min": 1})
    if cfg["faults"]:
        common.update(fault_plan=FaultPlan.single(
            "pod1", 30.0, restore_at=45.0))
    base = run_episode(cfg["n_sessions"], cfg["tasks"], **common)
    closed = run_episode(cfg["n_sessions"], cfg["tasks"], **common,
                         traffic="closed")
    assert _traces(base) == _traces(closed)
    assert _core_row(base) == _core_row(closed)
    # and the open-loop ledger still balanced: everyone spawned at t=0,
    # everyone retired by the end
    m = closed.metrics
    assert m.traffic_spawned == cfg["n_sessions"]
    assert m.traffic_completed == m.traffic_spawned
    assert m.traffic_in_system == 0
    assert m.traffic_little_residual < 1e-9


def test_closed_loop_replays_pr4_concurrency_digest():
    """Digest lock: the full default concurrency table routed through the
    spawn/retire event path is bit-identical to the PR-4 reference that
    tests/test_locality.py already locks on the traffic-free engine."""
    rows = tables.table_concurrency(tasks_per_session=25,
                                    engine_kw={"traffic": "closed"})
    assert _digest(rows) == PR4_CONCURRENCY_DIGEST


def test_closed_loop_replays_pr6_resilience_digest():
    """Digest lock at the fault-matrix level: the PR-6 resilience table
    (fail/restore/churn/elastic/autoscale x replication) replays
    bit-identically under closed-loop traffic — spawn/retire events
    compose with PRI_FAULT membership events without moving a cell."""
    base = tables.table_resilience(tasks_per_session=12)
    closed = tables.table_resilience(tasks_per_session=12,
                                     engine_kw={"traffic": "closed"})
    assert _digest(base) == PR6_RESILIENCE_DIGEST_12
    assert _digest(closed) == PR6_RESILIENCE_DIGEST_12


# ---------------------------------------------------------------------------
# Queueing locks: flow balance, Little's law, SLO monotonicity, the knee
# ---------------------------------------------------------------------------

def _open_loop(rate, seed=1, **kw):
    p = PoissonTraffic(rate, 150.0, seed=seed, lifetime_tasks=6)
    return run_episode(1, 25, n_pods=4, reuse_rate=0.3, seed=1,
                       prefetch=True, capacity_per_pod=8, traffic=p,
                       **dict(ZIPFG, **kw))


def test_flow_balance_and_littles_law_on_open_loop_episode():
    res = _open_loop(0.4)
    m = res.metrics
    assert m.n_sessions == m.traffic_spawned == len(res.sessions)
    # flow balance: nothing leaks — and at episode end nothing is left
    assert m.traffic_spawned == m.traffic_completed + m.traffic_in_system
    assert m.traffic_in_system == 0
    assert m.resilience_incomplete_sessions == 0
    # Little's law: L and W are computed by INDEPENDENT code paths
    # (event-sweep integral vs sojourn sums); the residual must sit at
    # float precision, and the measured rate near the offered rate
    assert m.traffic_little_residual < 1e-9
    assert m.traffic_offered_rate == pytest.approx(0.4)
    assert m.traffic_measured_rate == pytest.approx(0.4, rel=0.25)
    assert m.traffic_mean_sojourn_s > 0.0
    assert m.traffic_mean_in_system > 0.0
    # every bounded session ran exactly its lifetime
    assert all(len(s.traces) == len(s.tasks) == 6 for s in res.sessions)


def test_traffic_stats_ledger_unit():
    ts = TrafficStats(offered_rate=0.5)
    ts.note_spawn(0.0, 0)
    ts.note_spawn(2.0, 1)
    ts.note_retire(4.0, 0)
    ts.note_retire(8.0, 1)
    assert (ts.spawned, ts.completed, ts.in_system) == (2, 2, 0)
    assert ts.mean_sojourn_s() == pytest.approx(5.0)
    # N(t): 1 on [0,2), 2 on [2,4), 1 on [4,8) -> integral 10 over T=10
    assert ts.mean_in_system(10.0) == pytest.approx(1.0)
    assert ts.measured_rate(10.0) == pytest.approx(0.2)
    assert ts.little_residual(10.0) == pytest.approx(0.0)


def test_slo_attainment_monotone_non_increasing_in_offered_load():
    """The capacity sweep's core property on stable cells: pushing more
    offered load through the same fleet can only hold or hurt the SLO."""
    fracs = []
    for rate in (0.1, 0.2, 0.4, 0.8):
        res = _open_loop(rate)
        lats = [t.time_s for s in res.sessions for t in s.traces]
        fracs.append(slo_attainment(lats, 10.0))
        m = res.metrics
        assert m.traffic_spawned == m.traffic_completed   # stable cell
    assert all(a >= b - 1e-12 for a, b in zip(fracs, fracs[1:])), fracs
    assert fracs[0] == 1.0          # unloaded fleet meets the SLO outright


def test_capacity_table_reports_finite_knees_and_balanced_cells():
    """table_capacity acceptance: a finite knee for >= 3 configs, flow
    balance + zero incomplete in every cell, SLO attainment monotone
    per config (a reduced sweep keeps the tier-1 budget)."""
    rows = tables.table_capacity(rates=(0.2, 0.4, 0.8), horizon_s=100.0)
    cells = [r.split(",") for r in rows if r.startswith("capacity,")]
    knees = {c[2]: c[3] for c in [r.split(",") for r in rows]
             if c[0] == "capacity_knee"}
    assert len(cells) == 12                       # 4 configs x 3 rates
    finite = [k for k, v in knees.items() if v != ""]
    assert len(finite) >= 3, knees
    by_cfg = {}
    for c in cells:
        spawned, completed, in_sys = int(c[5]), int(c[6]), int(c[7])
        assert spawned == completed + in_sys      # flow balance
        assert in_sys == 0
        assert int(c[17]) == 0                    # incomplete
        assert float(c[15]) < 1e-9                # Little residual
        by_cfg.setdefault(c[2], []).append(float(c[12]))
    for cfg, fr in by_cfg.items():
        assert all(a >= b - 1e-12 for a, b in zip(fr, fr[1:])), (cfg, fr)


def test_open_loop_composes_with_faults():
    """A pod failure mid-horizon under Poisson arrivals: failover counted,
    fleet recovers, ledger still balances, nothing stalls forever."""
    p = PoissonTraffic(0.3, 120.0, seed=2, lifetime_tasks=5)
    res = run_episode(1, 25, n_pods=4, reuse_rate=0.3, seed=1,
                      prefetch=True, capacity_per_pod=8, traffic=p,
                      fault_plan=FaultPlan.single("pod3", 40.0,
                                                  restore_at=55.0),
                      **ZIPFG)
    m = res.metrics
    assert m.resilience_failovers == 1
    assert m.resilience_restores == 1
    assert m.traffic_spawned == m.traffic_completed
    assert m.traffic_in_system == 0
    assert m.resilience_incomplete_sessions == 0
    assert m.traffic_little_residual < 1e-9


# ---------------------------------------------------------------------------
# Warm-up-aware autoscaler (the PR-6 follow-up, measurable end-to-end)
# ---------------------------------------------------------------------------

def test_warmup_gate_defers_until_surge_outlives_rewarm_cost():
    sc = BacklogAutoscaler(check_every_s=10.0, high_backlog_s=1.0,
                           low_backlog_s=0.1, cooldown_s=0.0,
                           warmup_aware=True)
    high = {"p0": 5.0}
    # surge onset at t=10: age 0 < rewarm 15 -> deferred
    assert sc.decide(10.0, high, rewarm_cost_s=15.0) is None
    assert sc.deferred == 1
    # persisted to t=20: age 10 < 15 -> still deferred
    assert sc.decide(20.0, high, rewarm_cost_s=15.0) is None
    assert sc.deferred == 2
    # t=30: age 20 >= 15 -> the surge outlived the predicted warm-up
    assert sc.decide(30.0, high, rewarm_cost_s=15.0) == SCALE_OUT
    # a dip resets the surge clock
    assert sc.decide(40.0, {"p0": 0.5}, rewarm_cost_s=15.0) is None
    assert sc.decide(50.0, high, rewarm_cost_s=15.0) is None
    assert sc.surge_since == 50.0
    # zero predicted cost (cold caches): gate passes immediately
    sc2 = BacklogAutoscaler(cooldown_s=0.0, warmup_aware=True)
    assert sc2.decide(20.0, {"p0": 5.0}, rewarm_cost_s=0.0) == SCALE_OUT


def test_warmup_defaults_off_and_naive_decide_unchanged():
    """The PR-6 digest-locked behavior: warmup_aware defaults False and
    the naive policy ignores rewarm_cost_s entirely."""
    sc = BacklogAutoscaler(check_every_s=10.0, high_backlog_s=1.0,
                           low_backlog_s=0.1, cooldown_s=0.0)
    assert not sc.warmup_aware
    assert sc.decide(10.0, {"p0": 5.0}, rewarm_cost_s=1e9) == SCALE_OUT
    assert sc.deferred == 0


def _surge_episode(warmup_aware, seed):
    mm = MMPPTraffic(0.05, 1.2, 240.0, dwell_low_s=70.0, dwell_high_s=15.0,
                     seed=seed, lifetime_tasks=5)
    kw = {"check_every_s": 10.0, "high_backlog_s": 0.5,
          "low_backlog_s": 0.05, "max_extra": 2, "cooldown_s": 20.0}
    if warmup_aware:
        kw["warmup_aware"] = True
    return run_episode(1, 25, n_pods=4, reuse_rate=0.3, seed=1,
                       prefetch=True, capacity_per_pod=8, traffic=mm,
                       autoscale=True, autoscale_kw=kw, **ZIPFG).metrics


@pytest.mark.parametrize("seed", (1, 3))
def test_warmup_aware_autoscaler_cuts_churn_under_short_surges(seed):
    """End-to-end (the ROADMAP follow-up): under short MMPP surges the
    naive autoscaler pays the rendezvous reshuffle on surges that end
    before the new pod warms; the warm-up-aware gate defers those
    scale_outs — strictly less membership churn, a tail no worse than
    5%, and the zero-stall-forever gate intact."""
    naive = _surge_episode(False, seed)
    warm = _surge_episode(True, seed)
    assert naive.resilience_scale_outs >= 1       # the surge bites
    assert warm.autoscale_deferred >= 1           # the gate engaged
    assert warm.resilience_scale_outs < naive.resilience_scale_outs
    assert warm.resilience_scale_ins <= naive.resilience_scale_ins
    assert warm.p99_task_latency_s <= naive.p99_task_latency_s * 1.05
    for m in (naive, warm):
        assert m.resilience_incomplete_sessions == 0
        assert m.traffic_in_system == 0

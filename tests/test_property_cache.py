"""Property-based tests (hypothesis) for the dCache invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.cache import DataCache
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.policies import make_policy

KEYS = st.sampled_from([f"ds{i}-20{y}" for i in range(6) for y in range(18, 24)])
OPS = st.lists(
    st.tuples(st.sampled_from(["put", "get"]), KEYS), min_size=1, max_size=60)


@given(ops=OPS, cap=st.integers(min_value=1, max_value=5))
@settings(max_examples=60, deadline=None)
def test_capacity_never_exceeded_and_stats_consistent(ops, cap):
    c = DataCache(capacity=cap)
    pol = make_policy("lru")
    gets = hits = 0
    for op, k in ops:
        if op == "put":
            victim = None
            if k not in c and len(c) >= cap:
                victim = pol.victim(c.entries())
            c.put(k, k, 1, victim=victim)
        else:
            gets += 1
            try:
                c.get(k)
                hits += 1
            except KeyError:
                pass
        assert len(c) <= cap
    assert c.stats.hits == hits
    assert c.stats.hits + c.stats.misses == gets


@given(ops=OPS)
@settings(max_examples=40, deadline=None)
def test_lru_victim_is_least_recent(ops):
    c = DataCache(capacity=3)
    pol = make_policy("lru")
    for op, k in ops:
        if op == "put":
            victim = None
            if k not in c and len(c) >= 3:
                ents = c.entries()
                victim = pol.victim(ents)
                assert ents[victim].last_access == min(
                    e.last_access for e in ents.values())
            c.put(k, k, 1, victim=victim)
        elif k in c:
            c.get(k)


@given(keys=st.lists(KEYS, min_size=1, max_size=10, unique=True))
@settings(max_examples=40, deadline=None)
def test_apply_state_is_idempotent(keys):
    c = DataCache(capacity=5)
    loader = lambda k: k
    size = lambda v: 1
    c.apply_state(keys, loader, size)
    first = sorted(c.keys())
    ev_before = c.stats.evictions
    c.apply_state(first, loader, size)
    assert sorted(c.keys()) == first
    assert c.stats.evictions == ev_before


@given(keys=st.lists(KEYS, min_size=5, max_size=30, unique=True),
       kill=st.integers(min_value=0, max_value=3))
@settings(max_examples=40, deadline=None)
def test_rendezvous_minimal_disruption(keys, kill):
    r = PodLocalCacheRouter([f"p{i}" for i in range(4)])
    before = {k: r.owner(k) for k in keys}
    dead = f"p{kill}"
    r.fail_pod(dead)
    for k in keys:
        after = r.owner(k)
        if before[k] != dead:
            assert after == before[k]       # survivors keep their keys
        else:
            assert after != dead

import pytest

from repro.core.cache import DataCache
from repro.core.policies import make_policy


def test_put_get_roundtrip():
    c = DataCache(capacity=3)
    c.put("a-2020", {"x": 1}, 100)
    assert "a-2020" in c
    assert c.get("a-2020") == {"x": 1}
    assert c.stats.hits == 1


def test_miss_raises_and_counts():
    c = DataCache(capacity=2)
    with pytest.raises(KeyError):
        c.get("nope-2020")
    assert c.stats.misses == 1


def test_put_full_requires_victim():
    c = DataCache(capacity=2)
    c.put("a", 1, 1)
    c.put("b", 2, 1)
    with pytest.raises(ValueError):
        c.put("c", 3, 1)                    # no victim given
    evicted = c.put("c", 3, 1, victim="a")
    assert evicted == "a"
    assert sorted(c.keys()) == ["b", "c"]
    assert c.stats.evictions == 1


def test_reput_existing_key_no_eviction():
    c = DataCache(capacity=2)
    c.put("a", 1, 1)
    c.put("b", 2, 1)
    c.put("a", 10, 1)                       # overwrite, cache full but no evict
    assert c.get("a") == 10
    assert c.stats.evictions == 0


def test_recency_and_frequency_metadata():
    c = DataCache(capacity=3)
    c.put("a", 1, 1)
    c.put("b", 2, 1)
    c.get("a")
    c.get("a")
    c.get("b")
    ents = c.entries()
    assert ents["a"].access_count == 2
    assert ents["b"].access_count == 1
    assert ents["b"].last_access > ents["a"].last_access


def test_apply_state_reconciles():
    c = DataCache(capacity=3)
    loader = lambda k: f"value:{k}"
    size_of = lambda v: len(v)
    c.put("a", "va", 2)
    c.put("b", "vb", 2)
    c.apply_state(["b", "c"], loader, size_of)
    assert sorted(c.keys()) == ["b", "c"]
    assert c.peek("c") == "value:c"
    assert c.stats.evictions == 1           # "a" dropped


def test_apply_state_respects_capacity():
    c = DataCache(capacity=2)
    c.apply_state(["a", "b", "c", "d"], lambda k: k, lambda v: 1)
    assert len(c) <= 2


def test_lru_end_to_end():
    c = DataCache(capacity=2)
    pol = make_policy("lru")
    c.put("a", 1, 1)
    c.put("b", 2, 1)
    c.get("a")                               # b is now LRU
    victim = pol.victim(c.entries())
    assert victim == "b"


def test_contents_json_fields():
    import json
    c = DataCache(capacity=2)
    c.put("xview1-2022", 1, 55_000_000)
    d = json.loads(c.contents_json())
    e = d["xview1-2022"]
    assert set(e) >= {"last_access", "access_count", "insert_order", "size_mb"}
    assert e["size_mb"] == 55.0

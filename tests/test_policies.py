from repro.core.cache import CacheEntry
from repro.core.policies import POLICIES, make_policy


def ents(meta):
    """meta: {key: (last_access, access_count, insert_order)}"""
    return {k: CacheEntry(key=k, value=None, size_bytes=0, created_at=0,
                          last_access=m[0], access_count=m[1],
                          insert_order=m[2])
            for k, m in meta.items()}


BASE = {"a": (5.0, 3, 1), "b": (1.0, 9, 2), "c": (9.0, 1, 3)}


def test_lru_picks_oldest_access():
    assert make_policy("lru").victim(ents(BASE)) == "b"


def test_lfu_picks_least_frequent():
    assert make_policy("lfu").victim(ents(BASE)) == "c"


def test_fifo_picks_first_inserted():
    assert make_policy("fifo").victim(ents(BASE)) == "a"


def test_rr_deterministic_given_seed():
    p1 = make_policy("rr", seed=42)
    p2 = make_policy("rr", seed=42)
    assert [p1.victim(ents(BASE)) for _ in range(5)] == \
           [p2.victim(ents(BASE)) for _ in range(5)]


def test_belady_picks_farthest_future_use():
    p = make_policy("belady", future=["a", "c", "a", "b"])
    # "b" used last -> but farthest means max index of next use; b at 3,
    # a at 0, c at 1 -> evict b? No: farthest-in-future = b (index 3)
    assert p.victim(ents(BASE)) == "b"
    p2 = make_policy("belady", future=["a", "c"])   # b never used again
    assert p2.victim(ents(BASE)) == "b"


def test_all_policies_have_descriptions():
    for name in POLICIES:
        p = make_policy(name)
        text = p.describe()
        assert len(text) > 40
        assert "evict" in text.lower()

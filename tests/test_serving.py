import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Init, init_model, unbox
from repro.serving import ByteTokenizer, ServingEngine, sample


def engine(max_batch=3, max_len=96, family_arch="dcache-agent-150m"):
    cfg = dataclasses.replace(get_config(family_arch).reduced(),
                              vocab_size=512)
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    return ServingEngine(cfg, params, max_batch=max_batch, max_len=max_len)


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    ids = tok.encode("hello, dCache!")
    assert ids[0] == tok.bos_id
    assert tok.decode(ids[1:]) == "hello, dCache!"


def test_sampler_greedy_and_topk():
    logits = jnp.asarray([[0.1, 5.0, -1.0], [2.0, 0.0, 3.0]], jnp.float32)
    out = sample(logits, jax.random.PRNGKey(0))
    assert out.tolist() == [1, 2]
    out2 = sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=1)
    assert out2.tolist() == [1, 2]             # top-1 == greedy


@pytest.mark.slow
def test_batched_requests_complete():
    eng = engine()
    reqs = [eng.submit(p, max_new_tokens=6) for p in
            ("alpha", "a much longer prompt about satellites", "geo")]
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out_ids) <= 6 for r in reqs)
    s = eng.stats()
    assert s["finished"] == 3 and s["throughput_tok_s"] > 0


@pytest.mark.slow
def test_more_requests_than_slots():
    eng = engine(max_batch=2)
    reqs = [eng.submit(f"req {i}", max_new_tokens=4) for i in range(5)]
    eng.run_until_done()
    assert all(r.done for r in reqs)


@pytest.mark.slow
def test_greedy_determinism_across_batching():
    """A request must decode the same tokens alone or batched (slots are
    independent: ring caches + per-row pos)."""
    eng1 = engine(max_batch=1)
    r_alone = eng1.submit("determinism test prompt", max_new_tokens=5)
    eng1.run_until_done()

    eng2 = engine(max_batch=3)
    r_b = eng2.submit("determinism test prompt", max_new_tokens=5)
    eng2.submit("other request one", max_new_tokens=5)
    eng2.submit("yet another", max_new_tokens=5)
    eng2.run_until_done()
    assert r_alone.out_ids == r_b.out_ids


@pytest.mark.slow
def test_padding_invariance():
    """Bucket padding must not change the decoded tokens (mask proof)."""
    eng = engine(max_batch=1)
    # 9 chars -> bucket 16 (padded); compare vs exact-length bucket
    r1 = eng.submit("abcdefgh", max_new_tokens=5)   # 9 ids with BOS
    eng.run_until_done()

    eng2 = engine(max_batch=1)
    # force exact bucketing by monkeypatching _bucket
    import repro.serving.engine as E
    orig = E._bucket
    E._bucket = lambda n, cap: n
    try:
        r2 = eng2.submit("abcdefgh", max_new_tokens=5)
        eng2.run_until_done()
    finally:
        E._bucket = orig
    assert r1.out_ids == r2.out_ids


@pytest.mark.slow
def test_max_len_cap_terminates():
    eng = engine(max_batch=1, max_len=24)
    r = eng.submit("x" * 10, max_new_tokens=500)
    eng.run_until_done()
    assert r.done
    assert len(r.out_ids) < 30

"""Plan-cache tier (ISSUE 10): replay-correctness + accounting locks.

* **unit layer** — template ids, policy validation/admission, TTL expiry,
  exact-LRU eviction, the residency/version-sensitive context digest, the
  by-key invalidation index, and the serve-time staleness guard;
* **LLM policy** — graded agreement, PR-9's degraded-mode contract
  (unavailable -> programmatic twin ungraded, garbled -> parse fallback),
  free-slot installs never prompting, the SimLLM PLAN-CACHE handler;
* **replay correctness** — over randomized configs, every episode run
  with the plan cache ON produces the same per-task answers and the same
  gold grade as the forced-miss ``plan_cache=None`` replay (a hit is the
  plan the LLM *would* have produced, never a semantic shortcut);
* **degeneracy** — ``plan_cache=None`` replays the committed PR-4
  concurrency / PR-6 resilience digests and the PR-8 coherence table
  bit-identically: the tier is invisible until switched on;
* **coherence coupling** — a ``MutationPlan`` write to a covered key
  invalidates the plan under ``write-invalidate``; ``stale_served`` is
  asserted zero (measured, not trusted) under every exercised policy;
* **satellites** — the ``model_check`` exception-narrowing regression
  (poisoned ``execute_plan`` must propagate) and the per-episode
  token-conservation invariant (trace + decision buckets == fleet total;
  hits charge exactly zero plan tokens).
"""
import hashlib

import pytest

from benchmarks import tables
from repro.agent.agent import (
    PLAN_COMPLETION_TOKENS,
    PLAN_PROMPT_TOKENS_FS,
    STEP_SUMMARY_TOKENS,
)
from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.agent.geollm import workload
from repro.agent.geollm.datastore import GeoDataStore
from repro.agent.geollm.simclock import SimClock
from repro.agent.geollm.workload import (
    Step,
    Task,
    WorkloadSampler,
    answers_equal,
    model_check,
)
from repro.core.coherence import MutationPlan
from repro.core.controller import ReadPlan
from repro.core.endpoints import EndpointFaultPlan, LLMUnavailableError
from repro.core.plan_cache import (
    LLMPlanCache,
    PlanCache,
    PlanCachePolicy,
    make_plan_cache,
    task_template_id,
)
from repro.core.prompts import plan_cache_decision_prompt

# the PR-4 / PR-6 references the plan_cache=None replays must keep
# matching (same values tests/test_locality.py and tests/test_endpoints.py
# hold on the router-free / empty-plan engines)
PR4_CONCURRENCY_DIGEST = "8ec8ff89cfb17741"
PR6_RESILIENCE_DIGEST_12 = "9ed9f62ca396989d"


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


def _task(kinds, keys, tid=0):
    return Task(tid=tid, query="q",
                steps=[Step(kind=k, key=keys[0], prompt="p", plan=[])
                       for k in kinds],
                required_keys=list(keys))


def _grades(res):
    """(answers, gold-grade) per task across the episode, in stream
    order. The gold grade is computed here (the engine does not grade at
    run time): per step, does the produced answer match the gold?"""
    out = []
    for s in res.sessions:
        for task, tr in zip(s.tasks, s.traces):
            grade = tuple(
                st.gold is None or (i in tr.answers and
                                    answers_equal(tr.answers[i], st.gold))
                for i, st in enumerate(task.steps))
            out.append((repr(tr.answers), grade))
    return out


# ---------------------------------------------------------------------------
# Unit layer: keys, policy, TTL, LRU, digest, invalidation
# ---------------------------------------------------------------------------

def test_template_id_is_shape_pure():
    t = _task(["detect", "plot"], ["xview1-2015", "fmow-2016"])
    assert task_template_id(t) == "detect>plot#2"
    # the id ignores tid/query/keys — only the shape matters
    u = _task(["detect", "plot"], ["spacenet-2017", "fmow-2016"], tid=99)
    assert task_template_id(u) == task_template_id(t)
    assert task_template_id(_task(["detect"], ["fmow-2016"])) == "detect#1"


def test_policy_validation_and_admit_table():
    with pytest.raises(ValueError, match="ttl_s"):
        PlanCachePolicy(ttl_s=0.0)
    with pytest.raises(ValueError, match="min_freq"):
        PlanCachePolicy(min_freq=0)
    pol = PlanCachePolicy(ttl_s=60.0, min_freq=2)
    assert not pol.admit(1, None)          # below the frequency floor
    assert pol.admit(2, None)              # free slot: floor only
    assert pol.admit(2, 2) and pol.admit(5, 3)
    assert not pol.admit(2, 3)             # colder than the LRU victim
    assert "60" in pol.describe() and "2" in pol.describe()


def test_capacity_validation_and_factory():
    with pytest.raises(ValueError, match="capacity"):
        PlanCache(capacity=0)
    assert isinstance(make_plan_cache("python").policy, PlanCachePolicy)
    assert isinstance(make_plan_cache("programmatic").policy, PlanCachePolicy)
    pc = make_plan_cache("llm", llm=object(), ttl_s=9.0, min_freq=3)
    assert isinstance(pc.policy, LLMPlanCache)
    assert pc.policy.ttl_s == 9.0 and pc.policy.min_freq == 3
    with pytest.raises(ValueError, match="unknown plan-cache impl"):
        make_plan_cache("perfect")


def test_lookup_install_hit_and_ttl_expiry():
    pc = PlanCache(capacity=4, policy=PlanCachePolicy(ttl_s=10.0))
    t = _task(["detect"], ["xview1-2015"])
    tpl = task_template_id(t)
    plan = ReadPlan({"xview1-2015": "load_db"})
    assert pc.lookup(tpl, t.required_keys, 0.0) is None     # cold miss
    assert pc.install(tpl, t.required_keys, plan, 0.0)
    got = pc.lookup(tpl, t.required_keys, 5.0)
    assert got is plan and pc.stats.hits == 1
    # racing second install is a no-op (first install wins)
    assert not pc.install(tpl, t.required_keys, ReadPlan({}), 5.0)
    assert pc.stats.installs == 1
    # past the TTL the entry is dropped and counted
    assert pc.lookup(tpl, t.required_keys, 10.1) is None
    assert pc.stats.expired == 1 and not pc.entries
    assert pc.stats.lookups == 3 and pc.stats.misses == 2
    assert pc.stats.hit_rate == pytest.approx(1 / 3)


def test_exact_lru_eviction_and_frequency_gate():
    pc = PlanCache(capacity=2)
    plans = {}
    for i, kinds in enumerate((["detect"], ["plot"], ["vqa"])):
        t = _task(kinds, ["xview1-2015"])
        plans[i] = (task_template_id(t), t.required_keys)
    a, b, c = plans[0], plans[1], plans[2]
    # touch a twice (lookup + install path), b once -> a is hotter
    pc.lookup(*a, 0.0)
    assert pc.install(*a, ReadPlan({}), 0.0)
    pc.lookup(*b, 1.0)
    assert pc.install(*b, ReadPlan({}), 1.0)
    # a hit on a makes b the LRU victim
    assert pc.lookup(*a, 2.0) is not None
    # c (freq 1) cannot displace b (freq 1)? it can: >= victim frequency
    pc.lookup(*c, 3.0)
    assert pc.install(*c, ReadPlan({}), 3.0)
    assert pc.stats.evictions == 1
    assert pc.lookup(*b, 4.0) is None          # b was the victim
    assert pc.lookup(*a, 5.0) is not None      # a survived (recency)
    # a colder candidate than the victim is rejected
    d = (task_template_id(_task(["lcc"], ["xview1-2015"])), ["xview1-2015"])
    cold = PlanCache(capacity=1, policy=PlanCachePolicy(min_freq=3))
    cold.lookup(*a, 0.0)
    assert not cold.install(*a, ReadPlan({}), 0.0)   # freq 1 < floor 3
    assert cold.stats.rejected == 1 and not cold.entries
    del d


def test_context_digest_tracks_versions_and_residency():
    versions = {"xview1-2015": 0}
    resident = {"xview1-2015": False}
    pc = PlanCache(version_of=lambda k: versions.get(k, 0))
    pc.resident_of = lambda k: resident.get(k, False)
    keys = ["xview1-2015", "fmow-2016"]
    d0 = pc.context_digest(keys)
    assert d0 == pc.context_digest(list(reversed(keys)))   # order-free
    versions["xview1-2015"] = 1
    d1 = pc.context_digest(keys)
    assert d1 != d0                    # a write moves every covering digest
    resident["xview1-2015"] = True
    assert pc.context_digest(keys) != d1   # residency IS request context
    assert pc.context_versions(keys) == (
        ("fmow-2016", 0, False), ("xview1-2015", 1, True))


def test_version_bump_makes_stored_plan_unreachable():
    versions = {"xview1-2015": 0}
    pc = PlanCache(version_of=lambda k: versions["xview1-2015"])
    tpl, keys = "detect#1", ["xview1-2015"]
    pc.lookup(tpl, keys, 0.0)
    assert pc.install(tpl, keys, ReadPlan({}), 0.0)
    assert pc.lookup(tpl, keys, 1.0) is not None
    versions["xview1-2015"] = 1        # a write lands: digest moves
    assert pc.lookup(tpl, keys, 2.0) is None
    assert pc.stats.stale_served == 0  # unreachable, not served-then-caught
    # the dead entry still occupies capacity until note_write invalidates
    assert len(pc.entries) == 1
    assert pc.note_write("xview1-2015", invalidate=True) == 1
    assert pc.stats.invalidations == 1 and not pc.entries
    assert not pc.by_key               # reverse index fully cleaned
    # non-invalidating policies leave the (unreachable) entry in place
    assert pc.note_write("xview1-2015", invalidate=False) == 0


def test_serve_time_guard_counts_tampered_entry():
    # structurally unreachable through the public API (the digest embeds
    # the versions) — tamper the stored snapshot to prove the serve-time
    # guard measures staleness instead of trusting the construction
    pc = PlanCache()
    pc.lookup("detect#1", ["xview1-2015"], 0.0)
    pc.install("detect#1", ["xview1-2015"], ReadPlan({}), 0.0)
    entry = next(iter(pc.entries.values()))
    entry.versions = (("xview1-2015", 99, False),)
    assert pc.lookup("detect#1", ["xview1-2015"], 1.0) is None
    assert pc.stats.stale_served == 1 and not pc.entries


# ---------------------------------------------------------------------------
# LLM policy: grading, degraded-mode contract, free-slot short-circuit
# ---------------------------------------------------------------------------

class _Unavailable:
    def complete(self, prompt):
        raise LLMUnavailableError("pool down")


class _Garbled:
    def complete(self, prompt):
        return "Thought: hmm.\nAnswer: not json"


class _Canned:
    def __init__(self, decision):
        self.decision = decision
        self.calls = 0

    def complete(self, prompt):
        self.calls += 1
        return f'Thought: ok.\nAnswer: {{"decision": "{self.decision}"}}'


class _Explodes:
    def complete(self, prompt):  # pragma: no cover - must never run
        raise AssertionError("free-slot install consulted the LLM")


def test_llm_policy_degraded_and_parse_fallbacks():
    base = PlanCachePolicy(min_freq=1)
    pol = LLMPlanCache(base, _Unavailable())
    assert pol.admit(3, 1, "a", "b") == base.admit(3, 1)
    assert pol.degraded == 1 and pol.llm_total == 0
    assert pol.prompt_tokens == 0      # the prompt never reached a pod
    pol = LLMPlanCache(base, _Garbled())
    assert pol.admit(3, 1, "a", "b") == base.admit(3, 1)
    assert pol.parse_fallbacks == 1 and pol.llm_total == 0
    assert pol.prompt_tokens > 0 and pol.completion_tokens > 0
    assert pol.agreement == 1.0        # fallbacks are not graded
    # parsed-but-foreign decision: fallback, ungraded
    pol = LLMPlanCache(base, _Canned("maybe"))
    assert pol.admit(3, 1, "a", "b") == base.admit(3, 1)
    assert pol.parse_fallbacks == 1 and pol.llm_total == 0


def test_llm_policy_grades_against_programmatic_twin():
    base = PlanCachePolicy(min_freq=1)
    pol = LLMPlanCache(base, _Canned("cache"))
    assert pol.admit(5, 2, "a", "b") is True    # agrees with the twin
    assert (pol.llm_total, pol.llm_correct) == (1, 1)
    assert pol.admit(1, 7, "a", "b") is True    # disagrees (twin: bypass)
    assert (pol.llm_total, pol.llm_correct) == (2, 1)
    assert pol.agreement == 0.5
    assert pol.ttl_s == base.ttl_s and pol.min_freq == base.min_freq


def test_free_slot_install_skips_the_prompt():
    pol = LLMPlanCache(PlanCachePolicy(min_freq=1), _Explodes())
    assert pol.admit(1, None, "a", "") is True
    pc = PlanCache(capacity=8, policy=pol)
    pc.lookup("detect#1", ["xview1-2015"], 0.0)
    assert pc.install("detect#1", ["xview1-2015"], ReadPlan({}), 0.0)
    assert pol.llm_total == 0 and pc.tokens == 0


def test_simllm_answers_plan_cache_prompt():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=0)
    pol = PlanCachePolicy(ttl_s=45.0, min_freq=2)
    for freq, vf, want in ((5, 1, True), (1, 7, False), (2, 2, True)):
        prompt = plan_cache_decision_prompt(
            pol.describe(), "detect>plot#2", "vqa#1", freq, vf,
            pol.ttl_s, few_shot=True)
        wrapped = LLMPlanCache(pol, llm)
        got = wrapped.admit(freq, vf, "detect>plot#2", "vqa#1")
        assert isinstance(got, bool)
        assert wrapped.llm_total + wrapped.parse_fallbacks == 1
        del prompt, want   # eps noise may flip the simulated decision
    # the simulated backend tracks the programmatic twin closely
    agree = LLMPlanCache(pol, SimLLM(Profile("gpt-4-turbo", "cot", True), 1))
    for i in range(200):
        agree.admit(1 + i % 5, 1 + (i * 7) % 5, "detect#1", "plot#1")
    assert agree.agreement >= 0.9


# ---------------------------------------------------------------------------
# Replay correctness: hit == forced-miss, answer-for-answer
# ---------------------------------------------------------------------------

# non-fault, non-mutation configs (timing shifts under faults/mutations
# legitimately change availability/staleness verdicts, asserted separately)
REPLAY_CONFIGS = [
    dict(n=6, tps=8, seed=11, kw=dict(prefetch=True, capacity_per_pod=8,
                                      scenario="zipf",
                                      scenario_kw={"zipf_a": 1.1,
                                                   "zipf_global": True,
                                                   "repeat_p": 0.6})),
    dict(n=4, tps=10, seed=23, kw=dict(prefetch=True, admission="tinylfu",
                                       admission_impl="llm",
                                       capacity_per_pod=8,
                                       scenario_kw={"repeat_p": 0.7})),
    dict(n=5, tps=8, seed=37, kw=dict(prefetch=True, replication=True,
                                      scenario_kw={"repeat_p": 0.5})),
    dict(n=4, tps=8, seed=41, kw=dict(scenario_kw={"repeat_p": 0.8},
                                      capacity_per_pod=6)),
    dict(n=6, tps=6, seed=53, kw=dict(prefetch=True, few_shot=False,
                                      scenario_kw={"repeat_p": 0.6})),
    dict(n=4, tps=8, seed=67, kw=dict(prefetch=True,
                                      scenario_kw={"repeat_p": 0.9},
                                      plan_cache_kw={"capacity": 4,
                                                     "ttl_s": 60.0})),
]


@pytest.mark.parametrize("cfg", REPLAY_CONFIGS,
                         ids=[f"seed{c['seed']}" for c in REPLAY_CONFIGS])
def test_hits_replay_forced_miss_answers_and_grades(cfg):
    kw = dict(cfg["kw"])
    kw.setdefault("plan_cache", "python")
    on = run_episode(cfg["n"], cfg["tps"], n_pods=4, reuse_rate=0.3,
                     seed=cfg["seed"], **kw)
    kw["plan_cache"] = None
    kw.pop("plan_cache_kw", None)
    off = run_episode(cfg["n"], cfg["tps"], n_pods=4, reuse_rate=0.3,
                      seed=cfg["seed"], **kw)
    m = on.metrics
    assert m.plancache_hits > 0, "config must exercise the hit path"
    assert m.plancache_stale_served == 0
    # answers and gold grades are bit-identical task-for-task; only
    # time/tokens may move (the skipped planning rounds)
    assert _grades(on) == _grades(off)
    assert sum(t.tokens for s in on.sessions for t in s.traces) < \
        sum(t.tokens for s in off.sessions for t in s.traces)


def test_plan_cache_disabled_is_bit_identical():
    base = run_episode(6, 6, n_pods=4, reuse_rate=0.3, seed=7, prefetch=True,
                       scenario_kw={"repeat_p": 0.6})
    off = run_episode(6, 6, n_pods=4, reuse_rate=0.3, seed=7, prefetch=True,
                      scenario_kw={"repeat_p": 0.6}, plan_cache=None)
    assert _traces(base) == _traces(off)
    assert base.metrics.row() == off.metrics.row()


def test_react_profiles_bypass_the_tier():
    # ReAct has no discrete planning round to skip: the tier would be
    # pure lookup cost, so ReAct sessions never consult it — and the
    # run stays bit-identical to the cache-off engine
    on = run_episode(4, 6, n_pods=4, reuse_rate=0.3, seed=17,
                     prompting="react", scenario_kw={"repeat_p": 0.8},
                     plan_cache="python")
    off = run_episode(4, 6, n_pods=4, reuse_rate=0.3, seed=17,
                      prompting="react", scenario_kw={"repeat_p": 0.8})
    assert on.metrics.plancache_lookups == 0
    assert _traces(on) == _traces(off)


def test_plan_cache_kw_requires_plan_cache():
    with pytest.raises(ValueError, match="plan_cache_kw requires"):
        run_episode(2, 2, seed=0, plan_cache_kw={"capacity": 4})


def test_workload_repeat_validation_and_default_stream():
    with pytest.raises(ValueError, match="repeat_p"):
        WorkloadSampler(0.5, 0, repeat_p=1.5)
    with pytest.raises(ValueError, match="repeat_pool"):
        WorkloadSampler(0.5, 0, repeat_p=0.5, repeat_pool=0)
    # repeat_p=0 never draws the gate: the stream is the PR-1 stream
    a = WorkloadSampler(0.5, 3).sample(20)
    b = WorkloadSampler(0.5, 3, repeat_p=0.0).sample(20)
    assert repr(a) == repr(b)
    # the library is seed-independent: two samplers on different seeds
    # draw repeats from the same template set
    s1 = WorkloadSampler(0.5, 1, repeat_p=1.0)
    s2 = WorkloadSampler(0.5, 2, repeat_p=1.0)
    lib = {task_template_id(t) for t in s1._library}
    assert lib == {task_template_id(t) for t in s2._library}
    assert all(task_template_id(t) in lib for t in s1.sample(10))
    # repeated tasks are fresh copies: mutating one never corrupts the pool
    t = s1.sample_task(0)
    t.steps[0].kind = "mutated"
    assert all(s.kind != "mutated" for lt in s1._library for s in lt.steps)


# ---------------------------------------------------------------------------
# Degeneracy: plan_cache=None re-locks the PR-4 / PR-6 / PR-8 digests
# ---------------------------------------------------------------------------

def test_plan_cache_none_replays_pr4_concurrency_digest():
    rows = tables.table_concurrency(tasks_per_session=25,
                                    engine_kw={"plan_cache": None})
    assert _digest(rows) == PR4_CONCURRENCY_DIGEST


def test_plan_cache_none_replays_pr6_resilience_digest():
    rows = tables.table_resilience(tasks_per_session=12,
                                   engine_kw={"plan_cache": None})
    assert _digest(rows) == PR6_RESILIENCE_DIGEST_12


def test_plan_cache_none_replays_pr8_coherence_table():
    base = tables.table_coherence(tasks_per_session=4, parallel=True)
    live = tables.table_coherence(tasks_per_session=4, parallel=True,
                                  engine_kw={"plan_cache": None})
    assert _digest(live) == _digest(base)


def test_table_plancache_headline_and_locks():
    """The benchmark acceptance gate: on the mixed outage+straggler
    regime at the retry-only tier, plan-cache hits strictly reduce p95
    vs the cache-off cell (repeated templates never touch the
    straggler); the non-repeating stream cannot hit; no cell ever
    serves a stale plan; parallel and serial sweeps are bit-identical."""
    rows = tables.table_plancache(parallel=True)
    assert rows == tables.table_plancache(parallel=False)
    cells = {tuple(c[4:7]): c for c in (r.split(",") for r in rows[1:])}
    assert len(cells) == 8
    # zero-hit lock: a non-repeating stream has nothing to replay
    assert int(cells[("none", "0", "python")][8]) == 0
    # repeat-heavy clean regime: hits cut trace tokens at ~p95 parity
    on, off = cells[("none", "60", "python")], cells[("none", "60", "off")]
    assert int(on[8]) > 0
    assert int(on[18]) < int(off[18])              # trace tokens strictly cut
    assert float(on[23]) < 1.1                     # p95 parity band
    # the faulted headline: strictly below the cache-off p95
    assert float(cells[("mixed", "60", "python")][23]) < 1.0
    assert float(cells[("mixed", "60", "llm")][23]) < 1.0
    # the GPT path really prompted (capacity 16 forces evictions)
    assert int(cells[("none", "60", "llm")][17]) > 0
    # zero stale served, zero incomplete sessions, everywhere
    assert all(int(c[15]) == 0 and int(c[24]) == 0 for c in cells.values())


# ---------------------------------------------------------------------------
# Coherence coupling: no stale plan under invalidate, ever
# ---------------------------------------------------------------------------

MUTATE_KEYS = ["xview1-2015", "fmow-2016", "spacenet-2017"]


@pytest.mark.parametrize("impl", ["python", "llm"])
def test_covered_key_write_invalidates_and_zero_stale(impl):
    muts = MutationPlan.periodic(MUTATE_KEYS, 4.0, horizon_s=60.0)
    res = run_episode(8, 8, n_pods=4, reuse_rate=0.3, seed=3, prefetch=True,
                      capacity_per_pod=8,
                      scenario_kw={"repeat_p": 0.7},
                      mutations=muts, coherence="write-invalidate",
                      coherence_impl="python",
                      plan_cache=impl,
                      plan_cache_kw={"capacity": 4} if impl == "llm" else None)
    m = res.metrics
    assert m.plancache_lookups > 0 and m.plancache_installs > 0
    assert m.plancache_stale_served == 0          # measured, not trusted
    if impl == "llm":
        # capacity 4 forces evictions -> the GPT path actually prompts
        # (LRU churn may beat the writes to the covered entries, so the
        # invalidation count is asserted on the full-capacity run only)
        assert m.plancache_tokens > 0
        assert m.plancache_agreement >= 0.9
    else:
        assert m.plancache_invalidations > 0      # writes evicted plans


def test_serve_stale_policy_never_serves_version_lagged_plan():
    # even NON-invalidating coherence never serves a version-lagged plan:
    # the digest moved, the old entry is unreachable (only uncollected)
    muts = MutationPlan.periodic(MUTATE_KEYS[:2], 5.0, horizon_s=50.0)
    m = run_episode(6, 8, n_pods=4, reuse_rate=0.3, seed=5, prefetch=True,
                    scenario_kw={"repeat_p": 0.7}, mutations=muts,
                    coherence="serve-stale", plan_cache="python").metrics
    assert m.plancache_stale_served == 0
    assert m.plancache_invalidations == 0         # nothing eagerly dropped


# ---------------------------------------------------------------------------
# Satellite: model_check exception narrowing (decision-path accounting)
# ---------------------------------------------------------------------------

def _checked_tasks(n=6):
    clock = SimClock()
    store = GeoDataStore(clock)
    tasks = WorkloadSampler(0.8, 0).sample(n)
    workload.compute_gold(tasks, store)
    return tasks, store


def test_model_check_passes_clean_tasks_and_flags_bad_keys():
    tasks, store = _checked_tasks()
    assert model_check(tasks, store) == []
    broken = Task(tid=999, query="q",
                  steps=[Step(kind="detect", key="no-such-key", prompt="p",
                              plan=[])],
                  required_keys=["no-such-key"])
    assert model_check(tasks + [broken], store) == [999]   # KeyError -> bad


def test_model_check_propagates_checker_bugs(monkeypatch):
    """The regression: a TypeError out of a poisoned execute_plan is a
    bug in the checker's dependencies, not evidence the task is broken —
    it must propagate instead of being laundered into the bad list."""
    tasks, store = _checked_tasks(n=2)

    def poisoned(step, env):
        raise TypeError("buggy tool signature")

    monkeypatch.setattr(workload, "execute_plan", poisoned)
    with pytest.raises(TypeError, match="buggy tool signature"):
        model_check(tasks, store)

    def value_poisoned(step, env):
        raise ValueError("tool rejected arguments")

    monkeypatch.setattr(workload, "execute_plan", value_poisoned)
    assert model_check(tasks, store) == [t.tid for t in tasks]


# ---------------------------------------------------------------------------
# Satellite: per-episode token conservation
# ---------------------------------------------------------------------------

CONSERVATION_CONFIGS = [
    dict(seed=2, kw=dict(prefetch=True)),
    dict(seed=3, kw=dict(prefetch=True, admission="tinylfu",
                         admission_impl="llm", capacity_per_pod=6)),
    dict(seed=5, kw=dict(prefetch=True, replication=True,
                         replication_impl="llm")),
    dict(seed=7, kw=dict(prefetch=True,
                         endpoint_fault_plan=EndpointFaultPlan.
                         outage_straggler(["ep0", "ep1", "ep2", "ep3"],
                                          horizon_s=120.0),
                         endpoint_kw={"hedge": True, "breaker": True})),
    dict(seed=11, kw=dict(prefetch=True, plan_cache="llm",
                          plan_cache_kw={"capacity": 3},
                          scenario_kw={"repeat_p": 0.7})),
]


@pytest.mark.parametrize("cfg", CONSERVATION_CONFIGS,
                         ids=[f"seed{c['seed']}"
                              for c in CONSERVATION_CONFIGS])
def test_fleet_token_total_conserves(cfg):
    res = run_episode(6, 6, n_pods=4, reuse_rate=0.3, seed=cfg["seed"],
                      **cfg["kw"])
    m = res.metrics
    trace = sum(t.tokens for s in res.sessions for t in s.traces)
    assert m.tokens_trace_total == trace
    decision = (m.admission_tokens + m.replication_tokens
                + m.recovery_tokens + m.coherence_tokens
                + m.plancache_tokens + m.llm_retry_tokens)
    assert m.tokens_decision_total == decision
    assert m.tokens_fleet_total == trace + decision


def test_hits_charge_exactly_zero_plan_tokens():
    """Noise-free single-session run: the paired token delta per task is
    EXACTLY one planning round for every hit and zero otherwise — a hit
    charges no plan tokens, no summaries, no completion, nothing."""
    kw = dict(n_pods=1, reuse_rate=0.3, seed=13, llm_decisions=False,
              capacity_per_pod=64, scenario_kw={"repeat_p": 0.9})
    on = run_episode(1, 30, plan_cache="python", **kw)
    off = run_episode(1, 30, **kw)
    hits = 0
    for t_on, t_off, task in zip(on.sessions[0].traces,
                                 off.sessions[0].traces,
                                 on.sessions[0].tasks):
        if t_on.plancache_hits:
            hits += 1
            round_tokens = (PLAN_PROMPT_TOKENS_FS["cot"]
                            + STEP_SUMMARY_TOKENS * len(task.steps)
                            + PLAN_COMPLETION_TOKENS["cot"])
            assert t_off.tokens - t_on.tokens == round_tokens
        else:
            assert t_on.tokens == t_off.tokens
    assert hits > 0 and hits == on.metrics.plancache_hits
    assert on.metrics.plancache_tokens == 0      # python policy: no GPT

from repro.agent import build_runtime, build_tasks
from repro.core.controller import LLMController


def test_agent_runs_tasks_and_traces():
    rt = build_runtime(model="gpt-4-turbo", prompting="cot", few_shot=True,
                       use_cache=True, seed=0)
    tasks = build_tasks(10, reuse_rate=0.8, seed=2, store=rt.store)
    traces = rt.run(tasks)
    assert len(traces) == 10
    for tr in traces:
        assert tr.tokens > 5_000
        assert tr.tool_calls >= 5
        assert tr.time_s > 1.0


def test_cache_reduces_time_no_metric_damage():
    reports = {}
    for use_cache in (False, True):
        rt = build_runtime(model="gpt-4-turbo", prompting="cot",
                           few_shot=True, use_cache=use_cache, seed=0)
        tasks = build_tasks(80, reuse_rate=0.8, seed=2, store=rt.store)
        reports[use_cache] = rt.run_and_evaluate(tasks)
    speedup = reports[False].avg_time_s / reports[True].avg_time_s
    assert speedup > 1.08                      # paper: 1.15-1.33x
    # no degradation beyond variance bounds (sampling noise at n=80)
    assert abs(reports[True].success_rate - reports[False].success_rate) < 0.15
    assert reports[True].gpt_hit_rate > 0.9


def test_cache_miss_replan_path():
    rt = build_runtime(model="gpt-3.5-turbo", prompting="cot", few_shot=False,
                       use_cache=True, seed=1)
    tasks = build_tasks(60, reuse_rate=0.8, seed=4, store=rt.store)
    traces = rt.run(tasks)
    # gpt-3.5 eps=5.5%: some read decisions are wrong -> miss -> replan
    assert sum(t.cache_miss_replans for t in traces) >= 1
    assert isinstance(rt.runner.controller, LLMController)


def test_react_uses_more_tokens_than_cot():
    toks = {}
    for prompting in ("cot", "react"):
        rt = build_runtime(model="gpt-4-turbo", prompting=prompting,
                           few_shot=True, use_cache=True, seed=0)
        tasks = build_tasks(20, reuse_rate=0.8, seed=2, store=rt.store)
        rep = rt.run_and_evaluate(tasks)
        toks[prompting] = rep.avg_tokens
    assert toks["react"] > toks["cot"]


def test_determinism_same_seed():
    def run():
        rt = build_runtime(model="gpt-4-turbo", prompting="cot",
                           few_shot=True, use_cache=True, seed=7)
        tasks = build_tasks(15, reuse_rate=0.8, seed=9, store=rt.store)
        rep = rt.run_and_evaluate(tasks)
        return (rep.avg_time_s, rep.avg_tokens, rep.success_rate)
    assert run() == run()

import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import (
    FailureInjector,
    HeartbeatMonitor,
    PreemptionGuard,
    WorkerFailure,
)
from repro.models import Init, init_model, unbox
from repro.training import AdamWConfig, TokenStream, TrainLoop


def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(straggler_sigma=3.0)
    for i in range(20):
        mon.record_step(i, 0.10 + 0.001 * (i % 3))
    assert not mon.stragglers
    mon.record_step(20, 1.5)                    # 15x slower step
    assert 20 in mon.stragglers
    assert mon.is_straggling(2.0)
    assert not mon.is_straggling(0.11)


def test_failure_injector_fires_once():
    inj = FailureInjector([3])
    inj(2)
    with pytest.raises(WorkerFailure):
        inj(3)
    inj(3)                                       # second pass: already fired


def test_preemption_guard_checkpoints_once():
    calls = []
    g = PreemptionGuard(lambda: calls.append(1))
    g.notify()
    g.notify()
    assert calls == [1]
    assert g.preempted


@pytest.mark.slow
@pytest.mark.slow
def test_train_loop_survives_failures_and_resumes(tmp_path):
    cfg = get_config("dcache-agent-150m").reduced()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    stream = TokenStream(cfg, batch=4, seq=24, seed=0)
    mon = HeartbeatMonitor()
    ck = Checkpointer(str(tmp_path), keep=2)
    loop = TrainLoop(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20),
                     params, iter(stream.next_batch, None),
                     checkpointer=ck, ckpt_every=4, monitor=mon,
                     failure_injector=FailureInjector([5, 9]))
    loop.run(12)
    assert len(mon.failures) == 2
    assert all(f["restored"] for f in mon.failures)
    assert loop.step_idx == 12

    # cold restart resumes from the last checkpoint
    loop2 = TrainLoop(cfg, AdamWConfig(), params,
                      iter(stream.next_batch, None), checkpointer=ck)
    assert loop2.restore_if_available()
    assert loop2.step_idx == 12


def test_train_loop_gives_up_after_max_retries(tmp_path):
    cfg = get_config("dcache-agent-150m").reduced()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    stream = TokenStream(cfg, batch=2, seq=16, seed=0)

    def always_fail(step):
        raise WorkerFailure("node is gone")

    loop = TrainLoop(cfg, AdamWConfig(), params,
                     iter(stream.next_batch, None),
                     failure_injector=always_fail)
    with pytest.raises(WorkerFailure):
        loop.run(2, max_retries=2)

"""Concurrent multi-session episode engine: determinism, contention
accounting, and the lazy-view GeoFrame regression (ISSUE 1)."""
import numpy as np

from repro.agent.concurrency import (
    ConcurrentEpisodeEngine,
    PodContention,
    run_episode,
    session_seed,
)
from repro.agent.geollm.datastore import REGIONS, synth_frame
from repro.agent.geollm import geotools


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_same_seed_identical_metrics():
    a = run_episode(4, 8, n_pods=3, seed=11).metrics.row()
    b = run_episode(4, 8, n_pods=3, seed=11).metrics.row()
    assert a == b


def test_solo_replay_matches_concurrent_session_answers():
    """Session ``sid`` of an N-session episode replays bit-identically as a
    1-session episode seeded with session_seed(seed, sid): same answers and
    success flags (time/tokens may shift — the shared cache state differs)."""
    episode = run_episode(6, 6, n_pods=4, seed=5)
    for sid in (0, 2, 5):
        solo = run_episode(1, 6, n_pods=4, seed=session_seed(5, sid))
        s_n = episode.sessions[sid]
        s_1 = solo.sessions[0]
        assert [t.answers for t in s_1.traces] == \
               [t.answers for t in s_n.traces]
        assert [t.success for t in s_1.traces] == \
               [t.success for t in s_n.traces]


def test_answer_quality_independent_of_concurrency():
    """Contention shifts time, never answers: the aggregate answer metrics
    of an N-session episode equal those of its N solo replays pooled."""
    from repro.agent.geollm.evaluator import evaluate

    n, per = 4, 6
    episode = run_episode(n, per, n_pods=4, seed=1)
    rep_n = episode.evaluate_answers()
    tasks, traces = [], []
    for sid in range(n):
        solo = run_episode(1, per, n_pods=4, seed=session_seed(1, sid))
        tasks += solo.sessions[0].tasks
        traces += solo.sessions[0].traces
    pooled = evaluate(tasks, traces)
    # answer-derived metrics are exactly N-independent; the correctness
    # *ratio* is call-based (good/total tool calls) and may shift by a few
    # cache-miss replans, which legitimately depend on shared-cache state
    for field in ("success_rate", "obj_det_f1", "lcc_recall", "vqa_rouge"):
        assert getattr(rep_n, field) == getattr(pooled, field), field
    assert abs(rep_n.correctness - pooled.correctness) < 0.02


# ---------------------------------------------------------------------------
# contention accounting
# ---------------------------------------------------------------------------

def test_single_session_never_stalls():
    m = run_episode(1, 10, n_pods=4, seed=0).metrics
    assert m.total_stall_s == 0.0
    assert m.stalled_loads == 0


def test_contention_appears_and_grows_with_sessions():
    m1 = run_episode(1, 10, n_pods=2, seed=0).metrics
    m8 = run_episode(8, 10, n_pods=2, seed=0).metrics
    assert m8.total_stall_s > m1.total_stall_s
    assert m8.stalled_loads > 0
    assert m8.p95_task_latency_s > m1.p95_task_latency_s


def test_stalls_attributed_consistently():
    res = run_episode(8, 8, n_pods=2, seed=3)
    per_session = sum(s.stats.stall_s for s in res.sessions)
    assert abs(per_session - res.contention.total_stall_s) < 1e-9
    assert sum(s.stats.stalled_loads for s in res.sessions) == \
        res.metrics.stalled_loads
    assert res.metrics.total_loads == res.router.stats.remote_loads


def test_pod_fcfs_queueing_math():
    c = PodContention(["p0"])
    assert c.acquire("p0", 0.0, 2.0) == 2.0           # idle: service only
    dwell = c.acquire("p0", 1.0, 2.0)                 # arrives mid-service
    assert dwell == (2.0 - 1.0) + 2.0                 # 1s stall + 2s service
    assert c.pods["p0"].stall_s == 1.0
    assert c.pods["p0"].stalled_loads == 1
    assert c.total_loads == 2


def test_shared_cache_cross_session_hits():
    """Later sessions hit frames loaded by earlier sessions: the episode's
    local hit rate should beat what capacity alone gives one session."""
    res = run_episode(8, 10, n_pods=4, seed=0)
    assert res.metrics.local_hit_rate > 0.0
    assert res.router.stats.local_hits > 0
    # routed counts successful acquisitions exactly once each, even when an
    # erroneous read decision misses and re-plans into load_db; with exact
    # event interleaving an acquisition can also *join* another session's
    # in-flight load of the same key (no duplicate DB service)
    s = res.router.stats
    assert s.routed == s.local_hits + s.remote_loads + s.joined_in_flight


def test_metrics_shape():
    m = run_episode(2, 4, seed=0).metrics.row()
    for k in ("p50_task_latency_s", "p95_task_latency_s", "makespan_s",
              "total_stall_s", "pod_load_imbalance", "local_hit_rate"):
        assert k in m
    assert m["n_tasks"] == 8


def test_engine_uses_shared_router_capacity():
    eng = ConcurrentEpisodeEngine(2, n_pods=3, capacity_per_pod=2, seed=0)
    eng.run(4)
    for p in eng.pod_ids:
        assert len(eng.router.pods[p]) <= 2


# ---------------------------------------------------------------------------
# lazy-view GeoFrame regression (identical to the copying implementation)
# ---------------------------------------------------------------------------

def _copy_columns(f, m):
    """The pre-optimization semantics: boolean-mask-copy every column."""
    return {c: getattr(f, c)[m]
            for c in ("filename", "lon", "lat", "timestamp", "class_id",
                      "det_count", "land_cover", "cloud_pct")}


def test_lazy_views_match_copying_filters():
    f = synth_frame("dota-2019")
    x0, y0, x1, y1 = REGIONS["miami"]
    m = (f.lon >= x0) & (f.lon <= x1) & (f.lat >= y0) & (f.lat <= y1)
    ref = _copy_columns(f, m)
    roi = f.filter_bbox(REGIONS["miami"])
    assert len(roi) == int(m.sum())
    for col, expect in ref.items():
        np.testing.assert_array_equal(getattr(roi, col), expect)
    # chained view over a view
    m2 = ref["cloud_pct"] <= 40.0
    sub = roi.filter_clouds(40.0)
    for col, expect in ref.items():
        np.testing.assert_array_equal(getattr(sub, col), expect[m2])
    # sort is a permutation view
    srt = geotools.sort_by_time(sub)
    order = np.argsort(ref["timestamp"][m2], kind="stable")
    np.testing.assert_array_equal(srt.filename, ref["filename"][m2][order])
    assert np.all(np.diff(srt.timestamp) >= 0)


def test_bbox_filter_memoized_per_region():
    f = synth_frame("naip-2020")
    a = f.filter_bbox(REGIONS["seattle"])
    b = f.filter_bbox(REGIONS["seattle"])
    assert a is b                      # served from the (key, region) memo
    c = f.filter_bbox(REGIONS["houston"])
    assert c is not a


def test_views_share_base_arrays_not_copies():
    from repro.agent.geollm.datastore import GeoFrame

    n = 100
    f = GeoFrame("t-2020", np.array([f"im_{i}" for i in range(n)]),
                 np.linspace(-120, -80, n).astype(np.float32),
                 np.linspace(25, 48, n).astype(np.float32),
                 np.arange(n, dtype=np.int64),
                 np.zeros(n, np.int8), np.ones(n, np.int16),
                 np.zeros(n, np.int8), np.full(n, 10.0, np.float32))
    roi = f.filter_bbox((-110.0, 30.0, -90.0, 45.0))
    assert roi._base is f._base        # zero column copies at filter time
    assert roi._index is not None
    assert 0 < len(roi) < n
    # untouched columns stay ungathered until read
    assert "land_cover" not in roi._cols
    roi.land_cover
    assert "land_cover" in roi._cols

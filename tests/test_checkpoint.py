import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import Checkpointer
from repro.distributed.elastic import mesh_transition_plan, reshard_tree
from repro.distributed.sharding import single_pod_rules


def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "meta": {"step": np.int64(7)}}


def test_roundtrip_including_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(7, t)
    r = ck.restore(7, like=t)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert np.asarray(r["params"]["b"]).dtype == np.dtype("bfloat16")
    assert int(r["meta"]["step"]) == 7


def test_restore_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree())
    steps = ck.available_steps()
    assert steps == [3, 4]                     # gc kept last 2
    assert ck.restore_latest(like=tree()) is not None


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    ck.save(2, tree())
    # corrupt the newest shard
    d = ck._step_dir(2)
    shard = [f for f in os.listdir(d) if f.endswith(".ckpt")][0]
    with open(os.path.join(d, shard), "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00garbage\x00")
    assert ck.available_steps() == [1]         # 2 is invalid now
    r = ck.restore_latest(like=tree())
    assert r is not None                       # fell back to step 1


def test_partial_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009"))  # no manifest
    assert ck.available_steps() == [1]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    th = ck.save_async(5, tree())
    ck.wait()
    assert not th.is_alive()
    assert ck.available_steps() == [5]


def test_elastic_reshard_local_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    rules = single_pod_rules()
    vals = {"w": np.arange(32, dtype=np.float32).reshape(8, 4)}
    axes = {"w": ("embed", "mlp")}
    placed = reshard_tree(vals, axes, mesh, rules)
    np.testing.assert_array_equal(np.asarray(placed["w"]), vals["w"])


def test_mesh_transition_plan():
    plan = mesh_transition_plan({"data": 16, "model": 16},
                                {"pod": 2, "data": 16, "model": 16})
    assert "grow" in plan["pod"]
    assert plan["data"] == "keep 16"

"""Fault/elasticity layer (ISSUE 6): schedules, failover semantics,
recovery measurement, and the degeneracy contract.

* **degeneracy contract** — an EMPTY :class:`FaultPlan` (and no
  autoscaler) replays the fault-free engine bit-identically: times,
  tokens, answers, and every metric, across randomized seeds, scenarios
  and session/pod counts (property-based replay). The PR-4/5 table
  digest locks in tests/test_locality.py run with this layer compiled in
  and keep matching;
* **failure semantics** — in-flight loads on a dying pod abort; waiters
  retry against the new rendezvous owner with bounded sim-time backoff;
  prefetches targeting a dying pod bypass gracefully; NO session ever
  stalls forever, in any fault-matrix cell (``incomplete == 0``);
* **acceptance** — after the worst-case single-pod failure (pod3 owns
  the globally hottest zipf_global keys), the hit-EWMA recovery time is
  measurably shorter with durability replication ON than OFF, per seed
  across seeds 1-3;
* **GPT-driven recovery** — LLMRecovery agreement >= 90% with a
  fixed-seed golden transcript committed (tests/golden/recovery.json);
* **seed idioms** — SimFailureInjector / SimStragglerDetector: the
  training loop's fault-tolerance patterns ported to sim time.
"""
import hashlib
import json
import pathlib
import random

import pytest

from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.faults import (
    FAIL,
    RESTORE,
    SCALE_IN,
    SCALE_OUT,
    BacklogAutoscaler,
    FaultEvent,
    FaultPlan,
    LLMRecovery,
    RetryPolicy,
    SimFailureInjector,
    SimStragglerDetector,
    ThresholdRecovery,
    make_recovery,
)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

# the benchmark operating point (benchmarks/tables.py::table_resilience):
# globally-aligned zipf so the hot ranking — and the worst pod to kill —
# is seed-independent, capacity 8 so a failure destroys real state
ZIPFG = {"scenario": "zipf", "scenario_kw": {"zipf_a": 1.1,
                                             "zipf_global": True}}
RKW = {"epoch_s": 20.0, "max_replicated": 8, "promote_min": 4,
       "miss_min": 2, "gain_ratio": 2.0, "durability": True, "fanout": 1}


def _episode(seed=1, fault_plan=None, **kw):
    kw.setdefault("capacity_per_pod", 8)
    kw.setdefault("prefetch", True)
    return run_episode(16, 20, n_pods=4, reuse_rate=0.3, seed=seed,
                       fault_plan=fault_plan, **dict(ZIPFG, **kw))


def _traces(res):
    return [(t.time_s, t.tokens, repr(t.answers))
            for s in res.sessions for t in s.traces]


# ---------------------------------------------------------------------------
# FaultPlan schedules
# ---------------------------------------------------------------------------

def test_plan_sorted_and_same_instant_order():
    """Construction order never matters; at one instant capacity arrives
    before capacity leaves (scale_out < restore < fail < scale_in)."""
    evs = [FaultEvent(5.0, SCALE_IN, "pod9"), FaultEvent(5.0, FAIL, "pod1"),
           FaultEvent(5.0, RESTORE, "pod0"), FaultEvent(5.0, SCALE_OUT, "p8"),
           FaultEvent(1.0, FAIL, "pod0")]
    plan = FaultPlan(evs)
    assert plan.events == FaultPlan(list(reversed(evs))).events
    assert [e.action for e in plan][1:] == [SCALE_OUT, RESTORE, FAIL,
                                            SCALE_IN]


def test_plan_generators():
    single = FaultPlan.single("pod1", 10.0, restore_at=20.0)
    assert [(e.at, e.action) for e in single] == [(10.0, FAIL),
                                                  (20.0, RESTORE)]
    per = FaultPlan.periodic(["a", "b"], period_s=30.0, downtime_s=10.0,
                             start_s=30.0, horizon_s=120.0)
    assert [(e.at, e.action, e.pod) for e in per] == [
        (30.0, FAIL, "a"), (40.0, RESTORE, "a"),
        (60.0, FAIL, "b"), (70.0, RESTORE, "b"),
        (90.0, FAIL, "a"), (100.0, RESTORE, "a")]
    corr = FaultPlan.correlated(["a", "b"], 50.0, downtime_s=5.0)
    assert sum(e.action == FAIL and e.at == 50.0 for e in corr) == 2
    assert sum(e.action == RESTORE and e.at == 55.0 for e in corr) == 2
    el = FaultPlan.elastic("pod4", 40.0, in_at=100.0)
    assert [(e.at, e.action) for e in el] == [(40.0, SCALE_OUT),
                                              (100.0, SCALE_IN)]
    rnd = FaultPlan.random_plan(["a", "b", "c"], n_faults=4, horizon_s=100.0,
                                downtime_s=5.0, seed=3)
    assert len(rnd) == 8
    assert rnd.events == FaultPlan.random_plan(
        ["a", "b", "c"], n_faults=4, horizon_s=100.0, downtime_s=5.0,
        seed=3).events                               # deterministic in seed
    assert not FaultPlan() and len(FaultPlan()) == 0


def test_retry_policy_bounded_backoff():
    r = RetryPolicy(base_s=0.25, factor=2.0, cap_s=8.0, max_retries=4)
    assert [r.delay(a) for a in (1, 2, 3, 4, 5, 6, 9)] == \
        [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 8.0]


# ---------------------------------------------------------------------------
# Seed fault-tolerance idioms in sim time
# ---------------------------------------------------------------------------

def test_sim_failure_injector_plan_and_due():
    inj = SimFailureInjector({10.0: "pod1", 30.0: "pod0"}, downtime_s=5.0)
    assert [(e.at, e.action, e.pod) for e in inj.plan()] == [
        (10.0, FAIL, "pod1"), (15.0, RESTORE, "pod1"),
        (30.0, FAIL, "pod0"), (35.0, RESTORE, "pod0")]
    assert inj.due(12.0) == [(10.0, "pod1")]
    assert inj.due(12.0) == []                       # fires once
    assert inj.due(99.0) == [(30.0, "pod0")]


def test_sim_straggler_detector():
    det = SimStragglerDetector(window=20, sigma=3.0, timeout_s=10.0)
    for i in range(10):
        assert det.record(float(i), 1.0 + 0.01 * (i % 2)) is False
    assert det.record(10.0, 50.0) is True            # clear outlier
    assert det.stragglers and det.stragglers[0][1] == 50.0
    assert det.healthy(15.0)                         # beat at t=10
    assert not det.healthy(25.0)                     # 15s silent > timeout


# ---------------------------------------------------------------------------
# Degeneracy contract: empty plan == no fault layer at all
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", range(4))
def test_empty_plan_replays_fault_free_engine(case):
    rng = random.Random(1000 + case)
    n = rng.choice([4, 8])
    pods = rng.choice([2, 4])
    kw = {"prefetch": rng.random() < 0.5,
          "capacity_per_pod": rng.choice([5, 8])}
    if rng.random() < 0.5:
        kw.update(ZIPFG)
    seed = rng.randrange(10_000)
    base = run_episode(n, 8, n_pods=pods, seed=seed, **kw)
    faulted = run_episode(n, 8, n_pods=pods, seed=seed,
                          fault_plan=FaultPlan(), **kw)
    assert _traces(base) == _traces(faulted)
    assert base.metrics.row() == faulted.metrics.row()


# ---------------------------------------------------------------------------
# Failure semantics in the engine
# ---------------------------------------------------------------------------

def test_single_failure_counts_and_completes():
    res = _episode(fault_plan=FaultPlan.single("pod3", 60.0,
                                               restore_at=75.0))
    m = res.metrics
    assert m.resilience_failovers == 1 and m.resilience_restores == 1
    assert m.resilience_lost_keys > 0
    assert m.resilience_incomplete_sessions == 0
    assert all(len(s.traces) == 20 for s in res.sessions)


def test_owner_death_mid_flight_aborts_and_retries():
    """A pod that dies while serving in-flight loads aborts them; every
    waiter retries against the new owner and still finishes its stream.
    The churn plan keeps a pod dying every 30s, so across seeds some
    failure lands mid-service."""
    plan = FaultPlan.periodic([f"pod{i}" for i in range(4)], period_s=30.0,
                              downtime_s=10.0, start_s=30.0, horizon_s=120.0)
    hits = 0
    for seed in (1, 2, 3):
        m = _episode(seed=seed, fault_plan=plan).metrics
        assert m.resilience_incomplete_sessions == 0
        if m.resilience_aborted_loads:
            hits += 1
            assert m.resilience_lost_work_s > 0.0
            assert (m.resilience_retried_loads > 0
                    or m.resilience_prefetch_aborted > 0)
    assert hits > 0         # at least one seed aborted a live load


def test_prefetch_abort_bypasses_gracefully():
    """A prefetch whose target pod dies mid-flight is dropped from the
    session's prefetched map — the consuming task falls back to the
    demand path instead of joining a dead load (never stall-forever)."""
    plan = FaultPlan.correlated(["pod1", "pod3"], 60.0, downtime_s=15.0)
    seen = 0
    for seed in (1, 2, 4):
        m = _episode(seed=seed, fault_plan=plan).metrics
        assert m.resilience_incomplete_sessions == 0
        seen += m.resilience_prefetch_aborted
    assert seen > 0


def test_scale_out_then_fail_new_pod():
    """An elastically added pod can die like any other; its keys re-route
    back and the episode completes."""
    plan = FaultPlan([FaultEvent(40.0, SCALE_OUT, "pod4"),
                      FaultEvent(80.0, FAIL, "pod4")])
    res = _episode(fault_plan=plan)
    m = res.metrics
    assert m.resilience_scale_outs == 1 and m.resilience_failovers == 1
    assert m.resilience_incomplete_sessions == 0
    assert "pod4" not in res.router.live_pods()


def test_locate_skips_dead_replica_pod():
    r = PodLocalCacheRouter([f"pod{i}" for i in range(3)],
                            capacity_per_pod=4)
    key = "xview1-2020"
    owner = r.owner(key)
    host = next(p for p in r.pods if p != owner)
    r.pods[host].put(key, "v", 1)
    r.replicas[key] = [host]
    assert r.locate(key) == host
    r.fail_pod(host)
    assert r.locate(key) is None         # dead copy is never served
    assert key not in r.replicas         # purged with the pod


# ---------------------------------------------------------------------------
# Acceptance: durability replication shortens recovery (seeds 1-3)
# ---------------------------------------------------------------------------

def test_replication_shortens_recovery_across_seeds():
    plan = FaultPlan.single("pod3", 60.0, restore_at=75.0)
    for seed in (1, 2, 3):
        off = _episode(seed=seed, fault_plan=plan).metrics
        on = _episode(seed=seed, fault_plan=plan, replication=True,
                      replication_kw=RKW).metrics
        assert off.resilience_unrecovered == 0
        assert on.resilience_unrecovered == 0
        assert on.replica_hits > 0
        # per-seed win, with real margin (measured ~37/44/33s vs ~9/3/2s)
        assert on.resilience_recovery_s < 0.5 * off.resilience_recovery_s, \
            (seed, off.resilience_recovery_s, on.resilience_recovery_s)


def test_durability_pass_replicates_owner_retained_hot_key():
    """The miss feed never promotes a key its owner retains (it never
    misses); the opt-in durability pass judges the sketch top-k so hot
    residents get copies that survive owner loss. Off by default —
    bit-identical to the PR-5 replicator (the digest locks depend on
    it)."""
    from repro.core.admission import FrequencySketch
    from repro.core.replication import HotKeyReplicator

    def mk(durability):
        r = PodLocalCacheRouter([f"pod{i}" for i in range(3)],
                                capacity_per_pod=4)
        sketch = FrequencySketch(width=256, age_period_s=0)
        key = "hot-2020"
        sketch.touch_many([key] * 10)
        r.pods[r.owner(key)].put(key, "v", 1)       # owner-resident: no miss
        rep = HotKeyReplicator(r, sketch, lambda k: "v",
                               max_replicated=4, epoch_s=10.0, fanout=1,
                               miss_min=2, durability=durability)
        rep.run_epoch(10.0)
        return key, r, rep

    key, r_off, rep_off = mk(False)
    assert key not in rep_off.replicated             # structural gap
    key, r_on, rep_on = mk(True)
    assert key in rep_on.replicated                  # durability closes it
    assert r_on.replicas[key] and r_on.replicas[key] != [r_on.owner(key)]


# ---------------------------------------------------------------------------
# Fault matrix: zero stall-forever in every cell
# ---------------------------------------------------------------------------

def test_fault_matrix_no_incomplete_sessions():
    from benchmarks import tables
    rows = tables.table_resilience(tasks_per_session=12)
    body = [r.split(",") for r in rows[1:]]
    assert len(body) >= 12                           # the full matrix ran
    assert {c[4] for c in body} >= {"none", "single", "double", "churn",
                                    "elastic", "autoscale"}
    assert all(c[-1] == "0" for c in body), \
        [(c[4], c[5], c[-1]) for c in body if c[-1] != "0"]


# ---------------------------------------------------------------------------
# Autoscaler
# ---------------------------------------------------------------------------

def test_autoscaler_policy_unit():
    sc = BacklogAutoscaler(check_every_s=10.0, high_backlog_s=1.0,
                           low_backlog_s=0.1, max_extra=2, cooldown_s=30.0)
    assert sc.decide(10.0, {"p0": 2.0, "p1": 2.0}) == SCALE_OUT
    sc.note_action(10.0, SCALE_OUT, "pod2")
    # cooldown: the post-reshuffle backlog echo must not trigger a flap
    assert sc.decide(20.0, {"p0": 5.0}) is None
    assert sc.decide(50.0, {"p0": 5.0}) == SCALE_OUT
    sc.note_action(50.0, SCALE_OUT, "pod3")
    assert sc.decide(90.0, {"p0": 9.0}) is None      # max_extra reached
    assert sc.decide(90.0, {"p0": 0.0}) == SCALE_IN
    sc.note_action(90.0, SCALE_IN, "pod3")           # LIFO retirement
    assert sc.added == ["pod2"]
    # never scales the initial fleet away
    sc.added.clear()
    assert sc.decide(130.0, {"p0": 0.0}) is None


def test_autoscaler_in_engine():
    res = _episode(autoscale=True,
                   autoscale_kw={"check_every_s": 15.0,
                                 "high_backlog_s": 0.5,
                                 "low_backlog_s": 0.05,
                                 "max_extra": 2, "cooldown_s": 30.0})
    m = res.metrics
    assert m.autoscale_actions > 0
    assert m.resilience_scale_outs > 0
    assert m.resilience_incomplete_sessions == 0
    assert m.resilience_failovers == 0       # scale events are not failures


# ---------------------------------------------------------------------------
# GPT-driven recovery: graded + golden transcript
# ---------------------------------------------------------------------------

def _build_recovery_transcript():
    """Fixed-seed LLMRecovery transcript: decisions, prompts (hashed;
    first one verbatim) and the graded agreement are deterministic, so
    any prompt/SimLLM drift diffs against the committed golden file."""
    from repro.core.prompts import recovery_decision_prompt
    pol = LLMRecovery(ThresholdRecovery(rewarm_min=4),
                      SimLLM(Profile("gpt-4-turbo", "cot", True), seed=17))
    pol.set_evidence([("fair1m-2017", 11), ("dota-2023", 7),
                      ("xview1-2017", 3)])
    rng = random.Random(9)
    keys = ["fair1m-2017", "dota-2023", "xview1-2017", "modis-2023"]
    records = []
    example = None
    for _ in range(40):
        key = rng.choice(keys)
        freq = rng.randint(0, 9)
        prompt = recovery_decision_prompt(
            pol.base.describe(), key, freq, pol.base.rewarm_min,
            pol._top_json, True)
        if example is None:
            example = prompt
        got = pol.decide(key, freq)
        records.append({
            "key": key, "freq": freq,
            "prompt_sha": hashlib.sha256(prompt.encode()).hexdigest()[:16],
            "expected": pol.base.decide(key, freq),
            "decision": got,
        })
    return {
        "kind": "recovery", "policy": pol.name, "seed": 17,
        "model": "gpt-4-turbo",
        "agreement": round(pol.agreement, 4),
        "example_prompt": example,
        "decisions": records,
    }


def test_recovery_transcript_matches_golden_and_agrees():
    got = _build_recovery_transcript()
    assert got["agreement"] >= 0.90, got["agreement"]
    path = GOLDEN_DIR / "recovery.json"
    golden = json.loads(path.read_text())
    assert got == golden, (
        f"recovery transcript drifted from {path} — if the prompt change "
        f"is intentional, regenerate via: PYTHONPATH=src:. python "
        f"tests/golden/regen.py")


def test_llm_recovery_in_engine():
    plan = FaultPlan.single("pod3", 60.0, restore_at=75.0)
    thr = _episode(fault_plan=plan, recovery_impl="python").metrics
    llm = _episode(fault_plan=plan, recovery_impl="llm").metrics
    assert thr.recovery_rewarms + thr.recovery_lazy > 0
    assert llm.recovery_agreement >= 0.90
    assert llm.recovery_tokens > 0 and thr.recovery_tokens == 0
    # the threshold rule itself costs no tokens and grades 1.0
    assert thr.recovery_agreement == 1.0


def test_make_recovery_factory():
    assert isinstance(make_recovery(impl="python"), ThresholdRecovery)
    pol = make_recovery(impl="llm",
                        llm=SimLLM(Profile("gpt-4-turbo", "cot", True), 1))
    assert isinstance(pol, LLMRecovery) and pol.name == "llm-threshold"
    with pytest.raises(AssertionError):
        make_recovery(impl="llm")                    # llm backend required


# ---------------------------------------------------------------------------
# Mutation x fault interplay (ISSUE 8): writes landing across failures
# ---------------------------------------------------------------------------

def _mutation_fault_episode(policy, plan, mutations, **kw):
    from repro.core.coherence import MutationPlan
    assert isinstance(mutations, MutationPlan)
    return _episode(fault_plan=plan, mutations=mutations, coherence=policy,
                    replication=True, replication_kw=RKW, **kw)


def _assert_no_version_lag(res):
    """No lost invalidations: at episode end every live cached copy —
    owner resident, replica, or durability copy — of a mutated key is at
    the datastore's current version."""
    coh = res.coherence
    mutated = {k for k, v in coh.versions.items() if v > 0}
    assert mutated
    for pod, cache in res.router.pods.items():
        for key, entry in cache.entries().items():
            if key in mutated:
                assert entry.version >= coh.versions[key], (
                    pod, key, entry.version, coh.versions[key])


def test_pod_fails_mid_invalidation_window():
    """A pod down while writes invalidate its keys cannot resurrect a
    stale copy on restore: the failure purged its cache and every
    post-restore fill is stamped with the current version. Mutations hit
    the globally hottest keys (the 0x5EED order zipf_global ranks), so
    the failed pod3 owns most of the written keys."""
    from repro.core.coherence import MutationPlan
    from repro.agent.geollm.workload import mutation_hot_keys
    plan = FaultPlan.single("pod3", 60.0, restore_at=75.0)
    muts = MutationPlan.periodic(mutation_hot_keys(4), 4.0, start_s=55.0,
                                 horizon_s=95.0)
    res = _mutation_fault_episode("write-invalidate", plan, muts)
    m = res.metrics
    assert m.resilience_failovers == 1 and m.resilience_restores == 1
    assert m.coherence_mutations == len(muts)
    assert m.coherence_stale_reads == 0       # WI safety survives failover
    assert m.resilience_incomplete_sessions == 0
    _assert_no_version_lag(res)


def test_mutation_during_failover_retry():
    """Writes landing while aborted loads are in retry backoff: the
    retried load re-issues against the new owner and its fill carries
    the post-write version (a version-lagged fill is never installed
    under write-through — ``superseded_fills`` counts those races)."""
    from repro.core.coherence import MutationPlan
    from repro.agent.geollm.workload import mutation_hot_keys
    plan = FaultPlan.correlated(["pod1", "pod3"], 60.0, downtime_s=15.0)
    muts = MutationPlan.random_plan(mutation_hot_keys(6), 0.4, 120.0,
                                    seed=7)
    res = _mutation_fault_episode("write-through", plan, muts)
    m = res.metrics
    assert m.resilience_aborted_loads > 0     # the fault actually raced
    assert m.coherence_writethroughs > 0
    assert m.coherence_stale_reads == 0
    assert m.resilience_incomplete_sessions == 0
    _assert_no_version_lag(res)


def test_durability_copies_restored_at_correct_version():
    """Durability replication under a write stream: the copies that
    survive (or are re-placed after) the failure are at the current
    version — a restored durability copy never serves pre-failure data.
    Bounded staleness still holds for every value actually consumed."""
    from repro.core.coherence import MutationPlan
    from repro.agent.geollm.workload import mutation_hot_keys
    plan = FaultPlan.single("pod3", 60.0, restore_at=75.0)
    muts = MutationPlan.random_plan(mutation_hot_keys(4), 0.3, 120.0,
                                    seed=11)
    res = _mutation_fault_episode("serve-stale", plan, muts,
                                  coherence_kw={"bound_s": 20.0})
    m = res.metrics
    assert m.replica_installs > 0             # durability copies were placed
    assert m.coherence_max_staleness_s <= 20.0 + 1e-9
    assert m.resilience_incomplete_sessions == 0
    coh = res.coherence
    # serve-stale copies may lag in cache (readers decide at consume) but
    # the ledger proves every consumed stale value was inside the bound
    assert all(s <= 20.0 + 1e-9 for (_t, _k, _v, _c, s, verdict)
               in coh.ledger if verdict == "serve_stale")


def test_stale_churn_feeds_replica_demotion_pressure():
    """ISSUE-8 satellite: a replica copy the write stream stales out
    registers demotion pressure — the replicator folds the router's
    ``replica_stale_counts`` into its decaying ``stale_pressure`` score,
    drops the key past its grace epoch even though the replica is USED
    (the no-flap invariant yields to coherence churn), vetoes
    re-promotion while pressured, and lifts the ban once the score
    decays."""
    from repro.core.admission import FrequencySketch
    from repro.core.replication import HotKeyReplicator

    r = PodLocalCacheRouter([f"pod{i}" for i in range(3)],
                            capacity_per_pod=4)
    sketch = FrequencySketch(width=256, age_period_s=0)
    key = "hot-2020"
    sketch.touch_many([key] * 10)
    r.demand_counts[key] = 5
    rep = HotKeyReplicator(r, sketch, lambda k: "v", max_replicated=4,
                           epoch_s=10.0, fanout=1, miss_min=2,
                           stale_demote_min=1)
    rep.run_epoch(10.0)
    assert key in rep.replicated and rep.stats.promotes == 1
    r.replica_reads[key] = 1
    rep.run_epoch(20.0)                # grace epoch: copy survives
    assert key in rep.replicated
    # a write invalidates the placed copy: churn lands in the router feed
    assert r.invalidate_copies(key) >= 1
    assert r.replica_stale_counts[key] == 1
    r.replica_reads[key] = 1           # used — only the churn rule drops it
    r.demand_counts[key] = 5
    rep.run_epoch(30.0)
    assert key not in rep.replicated and rep.stats.demotes == 1
    assert rep.stats.promotes == 1     # re-promotion vetoed under pressure
    assert not r.replica_stale_counts  # drained into the decaying score
    # pressure 1 decays to 0 after the epoch: the ban lifts
    r.demand_counts[key] = 5
    rep.run_epoch(40.0)
    assert key in rep.replicated and rep.stats.promotes == 2

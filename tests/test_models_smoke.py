"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step on CPU, output shapes + no NaNs; plus decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, get_config
from repro.models import Init, decode_step, init_model, loss_fn, prefill_step, unbox

RNG = np.random.default_rng(0)

# heavy JAX smokes: CI's full-suite lane runs these (see pytest.ini)
pytestmark = pytest.mark.slow


def make_batch(cfg, B=2, S=16, with_targets=True):
    batch = {}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, 8, cfg.d_model)), cfg.jnp_dtype)
        text_len = S
    else:
        text_len = S - (cfg.n_frontend_tokens
                        if cfg.frontend == "vision_patches" else 0)
        if cfg.frontend == "vision_patches":
            batch["patches"] = jnp.asarray(
                RNG.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
                cfg.jnp_dtype)
    batch["tokens"] = jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, text_len)), jnp.int32)
    if with_targets:
        batch["targets"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, text_len)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    batch = make_batch(cfg)
    loss, metrics = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    g = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x: float(jnp.sum(jnp.abs(x.astype(jnp.float32)))),
                     g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(1),
                                      dtype=cfg.jnp_dtype), cfg))
    batch = make_batch(cfg, with_targets=False)
    cache, logits = prefill_step(cfg, params, batch, max_len=24)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = decode_step(cfg, params, tok, cache)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-4b", "rwkv6-7b",
                                  "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Prefill(S) + decode(t) must equal forward over S+1 tokens.

    MoE archs are excluded: GShard capacity-based dispatch makes the drop
    pattern batch-shape dependent, so strict decode==forward equality is
    not an invariant of that family (decode itself is dropless, see
    ``moe_capacity``)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(2),
                                      dtype=jnp.float32), cfg))
    B, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    # reference: full forward logits at position S-1 predictions for token S
    from repro.models.model import forward, _unembed
    h, _, _ = forward(cfg, params, {"tokens": toks}, is_train=False)
    ref_logits = _unembed(cfg, params, h[:, S - 1:S, :])
    # prefill S tokens, logits for next
    cache, logits = prefill_step(cfg, params, {"tokens": toks[:, :S]},
                                 max_len=S + 2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)
    # decode token S: must match forward at position S
    ref_logits2 = _unembed(cfg, params, h[:, S:S + 1, :])
    logits2, _ = decode_step(cfg, params, toks[:, S:S + 1], cache)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(ref_logits2),
                               atol=2e-3, rtol=2e-3)


def test_vocab_padding_masked():
    cfg = get_config("granite-3-2b").reduced()   # vocab 257 -> padded 512
    assert cfg.padded_vocab == 512
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    batch = make_batch(cfg, with_targets=False)
    _, logits = prefill_step(cfg, params, batch)
    pad_logits = np.asarray(logits, np.float32)[..., cfg.vocab_size:]
    assert (pad_logits < -1e29).all()


def test_moe_aux_loss_positive():
    cfg = get_config("mixtral-8x22b").reduced()
    params, _ = unbox(init_model(Init(jax.random.PRNGKey(0),
                                      dtype=cfg.jnp_dtype), cfg))
    batch = make_batch(cfg)
    _, metrics = loss_fn(cfg, params, batch)
    assert float(metrics["aux_loss"]) > 0.5     # ~1.0 when balanced

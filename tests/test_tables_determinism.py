"""Determinism contract for the paper tables (docs/architecture.md).

Tables I-III are bit-stable across runs, machines, and refactors of the
scheduling machinery: the agent loop's generator conversion (ISSUE 2) kept
every RNG draw and every clock-advance in its original order, so the digests
below — captured from the PR-1 code — must keep matching. If a PR changes
them *intentionally* (a modeling change), update the digests and say so in
CHANGES.md; an accidental drift is a regression.
"""
import hashlib

from benchmarks import tables


def _digest(rows) -> str:
    return hashlib.sha256(repr(rows).encode()).hexdigest()[:16]


# captured from the PR-1 code at the reduced sizes below
TABLE1_N40_DIGEST = "4a16fa741c2ec0e3"
TABLE2_N30_DIGEST = "c843260e9b690452"
TABLE3_N30_DIGEST = "4932ee22ebf094a7"


def test_table1_bit_stable():
    assert _digest(tables.table1(n=40)) == TABLE1_N40_DIGEST


def test_table2_bit_stable():
    assert _digest(tables.table2(n=30)) == TABLE2_N30_DIGEST


def test_table3_bit_stable():
    assert _digest(tables.table3(n=30)) == TABLE3_N30_DIGEST

"""Paged KV cache: allocator, page tables, gather, prefix sharing, and
equivalence with contiguous attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_cache import (
    OutOfPages,
    PagedCacheConfig,
    PagedKVCache,
    paged_decode_attention,
)

RNG = np.random.default_rng(0)


def mk(n_pages=32, page_size=4, L=2, kvd=16):
    return PagedKVCache(PagedCacheConfig(
        n_layers=L, kv_dim=kvd, page_size=page_size, n_pages=n_pages,
        dtype="float32"))


def rand(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


def test_append_and_gather_roundtrip():
    c = mk()
    sid = c.new_seq()
    toks = [rand(2, 16) for _ in range(6)]
    for t in toks:
        c.append(sid, t, t * 2)
    k, v, lens = c.gather([sid])
    assert int(lens[0]) == 6
    for i, t in enumerate(toks):
        np.testing.assert_allclose(np.asarray(k[:, 0, i]), np.asarray(t))
        np.testing.assert_allclose(np.asarray(v[:, 0, i]), np.asarray(t) * 2)


def test_write_prompt_matches_appends():
    c1, c2 = mk(), mk()
    kseq, vseq = rand(2, 7, 16), rand(2, 7, 16)
    s1 = c1.new_seq()
    c1.write_prompt(s1, kseq, vseq)
    s2 = c2.new_seq()
    for i in range(7):
        c2.append(s2, kseq[:, i], vseq[:, i])
    k1, _, _ = c1.gather([s1])
    k2, _, _ = c2.gather([s2])
    np.testing.assert_allclose(np.asarray(k1[:, :, :7]),
                               np.asarray(k2[:, :, :7]))


def test_memory_scales_with_tokens_not_slots():
    c = mk(n_pages=32, page_size=4)
    sids = [c.new_seq() for _ in range(4)]
    for sid in sids:
        for _ in range(3):                       # 3 tokens -> 1 page each
            t = rand(2, 16)
            c.append(sid, t, t)
    assert c.alloc.n_free == 32 - 4              # no max-len reservation


def test_out_of_pages_raises():
    c = mk(n_pages=2, page_size=2)
    sid = c.new_seq()
    t = rand(2, 16)
    for _ in range(4):
        c.append(sid, t, t)
    with pytest.raises(OutOfPages):
        c.append(sid, t, t)


def test_free_seq_releases_pages():
    c = mk(n_pages=8, page_size=2)
    sid = c.new_seq()
    t = rand(2, 16)
    for _ in range(5):
        c.append(sid, t, t)
    assert c.alloc.n_free == 8 - 3
    c.free_seq(sid)
    assert c.alloc.n_free == 8


def test_prefix_sharing_fork():
    c = mk(n_pages=16, page_size=4)
    a = c.new_seq()
    toks = [rand(2, 16) for _ in range(10)]     # 2 full pages + partial
    for t in toks:
        c.append(a, t, t)
    used_before = 16 - c.alloc.n_free
    b = c.fork_seq(a)
    # shared full pages + 1 copied partial page
    assert (16 - c.alloc.n_free) == used_before + 1
    kb, _, lens = c.gather([b])
    assert int(lens[0]) == 10
    for i, t in enumerate(toks):
        np.testing.assert_allclose(np.asarray(kb[:, 0, i]), np.asarray(t))
    # divergence: appending to the fork must not disturb the parent
    c.append(b, rand(2, 16), rand(2, 16))
    ka, _, _ = c.gather([a])
    np.testing.assert_allclose(np.asarray(ka[:, 0, 9]), np.asarray(toks[9]))


def test_paged_attention_matches_contiguous():
    c = mk(n_pages=64, page_size=4, L=1, kvd=32)   # 2 kv heads x 16
    sids = []
    lens = [5, 9, 3]
    store = {}
    for n in lens:
        sid = c.new_seq()
        ks, vs = rand(1, n, 32), rand(1, n, 32)
        c.write_prompt(sid, ks, vs)
        store[sid] = (ks, vs)
        sids.append(sid)
    k, v, lengths = c.gather(sids)
    q = rand(3, 64)                                # 4 q heads x 16
    out = paged_decode_attention(q, k[0], v[0], lengths,
                                 n_kv_heads=2, head_dim=16)
    # contiguous reference per sequence
    for i, sid in enumerate(sids):
        ks, vs = store[sid]
        kc = ks[0].reshape(lens[i], 2, 16)
        vc = vs[0].reshape(lens[i], 2, 16)
        qh = q[i].reshape(2, 2, 16)
        s = jnp.einsum("kgh,tkh->kgt", qh, kc) * (16 ** -0.5)
        w = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("kgt,tkh->kgh", w, vc).reshape(-1)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

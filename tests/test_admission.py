"""Cross-session admission + queueing-aware prefetch (ISSUE 3).

Covers: frequency-sketch estimates under aging, admission determinism at a
fixed seed, bypass-on-miss semantics (rejected keys stream through without
evicting residents), GPT-driven vs programmatic admission agreement on
synthetic traces, the digest-lock proving default-off behavior is
bit-identical to PR 2, the Belady bisect refactor, the scenario-diverse
workload generator, and the headline acceptance properties (TinyLFU lifts
the 16-sessions/4-pods local hit rate and p95; queueing-aware prefetch is
no worse than lazy at 4:1 saturation).
"""
import hashlib
import random

from repro.agent.backends import Profile, SimLLM
from repro.agent.concurrency import run_episode
from repro.agent.geollm.workload import WorkloadSampler
from repro.core.admission import (
    AdmitAll,
    Doorkeeper,
    FrequencySketch,
    LLMAdmission,
    TinyLFU,
    make_admission,
)
from repro.core.cache import CacheEntry
from repro.core.distributed_cache import PodLocalCacheRouter
from repro.core.policies import make_policy


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _entries(keys):
    return {k: CacheEntry(key=k, value=None, size_bytes=0, created_at=0.0,
                          last_access=float(i), access_count=1,
                          insert_order=i)
            for i, k in enumerate(keys)}


# ---------------------------------------------------------------------------
# FrequencySketch
# ---------------------------------------------------------------------------

def test_sketch_counts_touches():
    s = FrequencySketch(width=256, depth=4)
    assert s.estimate("a-2020") == 0
    for _ in range(5):
        s.touch("a-2020")
    s.touch("b-2021")
    # count-min guarantee: estimates never undercount
    assert s.estimate("a-2020") >= 5
    assert s.estimate("b-2021") >= 1
    # conservative update keeps small distinct keys near-exact at this load
    assert s.estimate("b-2021") < 5


def test_sketch_ages_by_halving_on_sim_time():
    s = FrequencySketch(width=256, depth=4, age_period_s=10.0)
    for _ in range(8):
        s.touch("k-2020", now=0.0)
    assert s.estimate("k-2020") >= 8
    s.touch("k-2020", now=10.5)         # crosses one aging boundary
    assert s.ages == 1
    assert s.estimate("k-2020") <= 8 // 2 + 1
    s.touch("other-2020", now=35.0)     # crosses two more boundaries
    assert s.ages == 3


def test_sketch_deterministic_across_instances():
    a, b = FrequencySketch(width=128), FrequencySketch(width=128)
    keys = [f"k{i}-2020" for i in range(30)]
    for i, k in enumerate(keys):
        for _ in range(i % 5 + 1):
            a.touch(k)
            b.touch(k)
    assert all(a.estimate(k) == b.estimate(k) for k in keys)
    assert (a.table == b.table).all()


# ---------------------------------------------------------------------------
# Admission policies: programmatic rules + bypass semantics
# ---------------------------------------------------------------------------

def test_tinylfu_admits_only_strictly_hotter():
    s = FrequencySketch(width=256)
    for _ in range(3):
        s.touch("hot-2020")
    s.touch("cold-2020")
    ents = _entries(["hot-2020"])
    p = TinyLFU()
    assert not p.admit("cold-2020", "hot-2020", s, ents)
    assert p.admit("hot-2020", "cold-2020", s, ents)
    # ties protect the resident (both keys seen once)
    s.touch("cold2-2020")
    assert not p.admit("cold-2020", "cold2-2020", s, ents)


def test_doorkeeper_requires_second_touch():
    s = FrequencySketch(width=256)
    p = Doorkeeper()
    s.touch("k-2020")
    assert not p.admit("k-2020", "v-2020", s, {})
    s.touch("k-2020")
    assert p.admit("k-2020", "v-2020", s, {})


def test_admit_all_matches_pre_admission_behavior():
    assert AdmitAll().admit("any-2020", "victim-2020", None, {})


def test_router_bypass_streams_through_without_evicting():
    """Bypass-on-miss: a rejected one-shot key is served to the caller but
    never installs, and no resident is evicted."""
    sketch = FrequencySketch(width=256)
    r = PodLocalCacheRouter(["p0"], capacity_per_pod=1,
                            admission=TinyLFU(), sketch=sketch)
    for _ in range(3):
        sketch.touch("hot-2020")
    assert r.install("p0", "hot-2020", "HOT", 1)
    v, pod, hit = r.fetch("cold-2020", loader=lambda k: "COLD",
                          size_of=lambda v: 1)
    assert v == "COLD" and not hit          # value streamed through
    assert "hot-2020" in r.pods["p0"]       # resident untouched
    assert "cold-2020" not in r.pods["p0"]
    assert r.stats.bypassed == 1 and r.stats.admitted == 0
    # a hotter candidate is admitted and evicts
    for _ in range(5):
        sketch.touch("hotter-2020")
    assert r.install("p0", "hotter-2020", "H2", 1)
    assert "hotter-2020" in r.pods["p0"] and "hot-2020" not in r.pods["p0"]
    assert r.stats.admitted == 1


# ---------------------------------------------------------------------------
# GPT-driven admission vs programmatic (synthetic traces)
# ---------------------------------------------------------------------------

def test_llm_admission_agreement_on_synthetic_trace():
    """The prompted path reproduces the programmatic decision up to the
    calibrated error rate, and the grading counters record exactly the
    disagreements."""
    sketch = FrequencySketch(width=512)
    rng = random.Random(7)
    keys = [f"k{i}-2020" for i in range(40)]
    for k in keys:
        for _ in range(rng.randint(0, 6)):
            sketch.touch(k)
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=3)
    adm = LLMAdmission(TinyLFU(), llm)
    base = TinyLFU()
    ents = _entries(keys[:5])
    n, agree = 200, 0
    for _ in range(n):
        cand, victim = rng.choice(keys), rng.choice(keys[:5])
        agree += adm.admit(cand, victim, sketch, ents) == \
            base.admit(cand, victim, sketch, ents)
    assert adm.llm_total == n
    assert adm.llm_correct == agree
    # calibrated eps is 3.4%: agreement lands near 1 - eps
    assert 0.90 <= adm.agreement < 1.0


def test_llm_admission_deterministic_given_seed():
    def run():
        sketch = FrequencySketch(width=256)
        for i in range(10):
            for _ in range(i):
                sketch.touch(f"k{i}-2020")
        llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=11)
        adm = LLMAdmission(Doorkeeper(), llm)
        ents = _entries(["r-2020"])
        return [adm.admit(f"k{i}-2020", "r-2020", sketch, ents)
                for i in range(10)]
    assert run() == run()


def test_make_admission_llm_wrapper():
    llm = SimLLM(Profile("gpt-4-turbo", "cot", True), seed=0)
    adm = make_admission("tinylfu", impl="llm", llm=llm)
    assert isinstance(adm, LLMAdmission)
    assert adm.name == "llm-tinylfu"
    assert "STRICTLY HIGHER" in adm.describe()


# ---------------------------------------------------------------------------
# Engine integration: determinism + digest-locks
# ---------------------------------------------------------------------------

# same constants as tests/test_prefetch.py — the PR-1/PR-2 solo trace
PR1_SOLO_ANSWERS_DIGEST = "cd4fd32fdd08cba1"
PR1_SOLO_TIMES = [6.594662, 5.28551064, 7.052146, 5.4153324, 4.71128648,
                  5.17204584, 4.18810528, 4.27347752]


def test_admission_disabled_is_bit_identical_to_pr2():
    """The digest-lock: with admission disabled (the default), the solo
    trace replays PR 2 bit-identically — answers AND times. (Tables I-III
    run the same default path; their digests are locked in
    tests/test_tables_determinism.py.)"""
    s = run_episode(1, 8, n_pods=4, seed=0).sessions[0]
    assert _digest([t.answers for t in s.traces]) == PR1_SOLO_ANSWERS_DIGEST
    assert [round(t.time_s, 9) for t in s.traces] == PR1_SOLO_TIMES


def test_admission_shifts_time_never_answers():
    base = run_episode(6, 8, n_pods=4, reuse_rate=0.3, seed=2)
    tlfu = run_episode(6, 8, n_pods=4, reuse_rate=0.3, seed=2,
                       admission="tinylfu")
    for sb, st in zip(base.sessions, tlfu.sessions):
        assert [t.answers for t in sb.traces] == \
            [t.answers for t in st.traces]
        assert [t.success for t in sb.traces] == \
            [t.success for t in st.traces]


def test_admission_deterministic_at_fixed_seed():
    a = run_episode(8, 8, n_pods=4, reuse_rate=0.3, seed=4,
                    admission="tinylfu").metrics.row()
    b = run_episode(8, 8, n_pods=4, reuse_rate=0.3, seed=4,
                    admission="tinylfu").metrics.row()
    assert a == b
    assert a["bypassed"] > 0            # the gate actually fired


def test_admission_accounting_invariants():
    res = run_episode(8, 10, n_pods=2, reuse_rate=0.3, seed=1,
                      admission="tinylfu", prefetch=True)
    s = res.router.stats
    # the logical-access invariant gains the bypass-read bucket
    assert s.routed == (s.local_hits + s.remote_loads + s.joined_in_flight
                        + s.bypass_reads)
    m = res.metrics
    assert m.admitted == s.admitted and m.bypassed == s.bypassed
    # every logical access (and only those) touched the shared sketch
    assert res.router.sketch.touches == s.routed


def test_gpt_admission_engine_agreement_calibrated():
    m = run_episode(8, 10, n_pods=2, reuse_rate=0.3, seed=0,
                    admission="tinylfu",
                    admission_impl="llm").metrics
    assert m.admitted + m.bypassed > 0
    assert 0.88 <= m.admission_agreement <= 1.0


# ---------------------------------------------------------------------------
# Acceptance: TinyLFU lifts hit rate + p95 under contention; queueing-aware
# prefetch holds the tail at 4:1 saturation
# ---------------------------------------------------------------------------

def test_tinylfu_lifts_hit_rate_and_p95_at_16_sessions_low_reuse():
    base = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0).metrics
    tlfu = run_episode(16, 25, n_pods=4, reuse_rate=0.3, seed=0,
                       admission="tinylfu").metrics
    assert tlfu.local_hit_rate > base.local_hit_rate
    assert tlfu.p95_task_latency_s < base.p95_task_latency_s
    assert tlfu.total_stall_s < base.total_stall_s


def test_prefetch_no_worse_than_lazy_at_4to1_saturation():
    lazy = run_episode(16, 25, n_pods=4, seed=0).metrics
    pf = run_episode(16, 25, n_pods=4, seed=0, prefetch=True).metrics
    assert pf.p95_task_latency_s <= lazy.p95_task_latency_s
    assert pf.prefetch_skipped > 0      # the budget is actually gating


def test_prefetch_still_wins_at_2to1():
    lazy = run_episode(4, 25, n_pods=8, seed=0).metrics
    pf = run_episode(4, 25, n_pods=8, seed=0, prefetch=True).metrics
    assert pf.p95_task_latency_s < lazy.p95_task_latency_s
    assert pf.p50_task_latency_s < lazy.p50_task_latency_s


# ---------------------------------------------------------------------------
# Belady bisect refactor: identical victims, indexed lookup
# ---------------------------------------------------------------------------

def test_belady_bisect_matches_linear_rescan():
    rng = random.Random(13)
    keys = [f"k{i}" for i in range(8)]
    future = [rng.choice(keys) for _ in range(300)]

    def naive_victim(entries, cursor):
        def next_use(key):
            for i in range(cursor, len(future)):
                if future[i] == key:
                    return i
            return 1 << 30
        return max(entries.values(), key=lambda e: next_use(e.key)).key

    p = make_policy("belady", future=future)
    for cursor in range(0, 300, 7):
        p.cursor = cursor
        cached = _entries(rng.sample(keys, 5))
        assert p.victim(cached) == naive_victim(cached, cursor)


def test_belady_future_reassignment_resets_index():
    p = make_policy("belady", future=["a", "b"])
    p.cursor = 1
    p.future = ["c", "a"]
    assert p.cursor == 0
    ents = _entries(["a", "c"])
    assert p.victim(ents) == "a"        # c used first, a second -> evict a


# ---------------------------------------------------------------------------
# Scenario-diverse workload generator
# ---------------------------------------------------------------------------

def _key_draws(scenario, n=400, **kw):
    s = WorkloadSampler(0.3, seed=5, scenario=scenario, **kw)
    return [s._sample_key() for _ in range(n)]


def test_zipf_scenario_is_skewed_and_deterministic():
    a = _key_draws("zipf", zipf_a=1.5)
    b = _key_draws("zipf", zipf_a=1.5)
    assert a == b
    top = max(set(a), key=a.count)
    assert a.count(top) / len(a) > 0.15     # far above uniform 1/72


def test_scan_scenario_sweeps_key_space():
    from repro.agent.geollm.datastore import all_keys
    draws = _key_draws("scan", n=len(all_keys()))
    assert draws == all_keys()              # one full sequential sweep
    assert _key_draws("scan", n=80)[72:] == all_keys()[:8]  # wraps


def test_hotspot_scenario_shifts_phases():
    draws = _key_draws("hotspot", n=240, hot_k=3, hot_p=1.0, phase_len=60)
    phases = [set(draws[i:i + 60]) for i in range(0, 240, 60)]
    assert all(len(p) <= 3 for p in phases)
    assert len(set().union(*phases)) > 3    # the hot set actually moved


def test_working_scenario_unchanged_by_default():
    """The default sampler draws are untouched by the scenario machinery
    (Table I-III digests depend on this)."""
    a = WorkloadSampler(0.8, seed=1).sample(20)
    b = WorkloadSampler(0.8, seed=1, scenario="working").sample(20)
    assert [t.query for t in a] == [t.query for t in b]
    assert [t.required_keys for t in a] == [t.required_keys for t in b]

"""End-to-end behaviour tests: the paper's claims at benchmark-mini scale."""
import numpy as np
import pytest

from repro.agent import build_runtime, build_tasks


def run_cell(model, prompting, few_shot, use_cache, n=50, reuse=0.8,
             seed=0, **kw):
    rt = build_runtime(model=model, prompting=prompting, few_shot=few_shot,
                       use_cache=use_cache, seed=seed, **kw)
    tasks = build_tasks(n, reuse_rate=reuse, seed=11, store=rt.store)
    return rt.run_and_evaluate(tasks)


def test_claim_speedup_across_configs():
    """Table I: latency reduction across models x prompting, ~1.24x avg."""
    speedups = []
    for model in ("gpt-3.5-turbo", "gpt-4-turbo"):
        for prompting in ("cot", "react"):
            r0 = run_cell(model, prompting, True, use_cache=False)
            r1 = run_cell(model, prompting, True, use_cache=True)
            speedups.append(r0.avg_time_s / r1.avg_time_s)
    mean = float(np.mean(speedups))
    assert mean > 1.10, speedups
    assert all(s > 1.02 for s in speedups), speedups


def test_claim_no_agent_metric_degradation():
    r0 = run_cell("gpt-4-turbo", "cot", True, use_cache=False, n=60)
    r1 = run_cell("gpt-4-turbo", "cot", True, use_cache=True, n=60)
    assert abs(r1.success_rate - r0.success_rate) < 0.12
    assert abs(r1.obj_det_f1 - r0.obj_det_f1) < 0.12
    assert abs(r1.vqa_rouge - r0.vqa_rouge) < 0.12


def test_claim_speedup_grows_with_reuse_rate():
    """Table II: higher reuse -> bigger latency savings (per-rate speedup,
    since the reuse rate changes the sampled tasks themselves)."""
    speedups = {}
    for rr in (0.0, 0.8):
        r0 = run_cell("gpt-3.5-turbo", "cot", False, use_cache=False,
                      reuse=rr, n=60)
        r1 = run_cell("gpt-3.5-turbo", "cot", False, use_cache=True,
                      reuse=rr, n=60)
        speedups[rr] = r0.avg_time_s / r1.avg_time_s
    assert speedups[0.8] > speedups[0.0] + 0.1
    assert abs(speedups[0.0] - 1.0) < 0.1     # no reuse -> no gain


def test_claim_policies_similar_at_high_reuse():
    """Table II bottom: LRU/LFU/RR/FIFO within a small band at 80% reuse."""
    times = []
    for pol in ("lru", "lfu", "rr", "fifo"):
        r = run_cell("gpt-3.5-turbo", "cot", False, use_cache=True,
                     policy=pol, n=60)
        times.append(r.avg_time_s)
    # the paper's own Table II spread at 80% reuse is ~9% (4.92..5.36s)
    assert (max(times) - min(times)) / min(times) < 0.15


def test_claim_gpt_driven_matches_programmatic():
    """Table III: GPT-driven cache ops ~= programmatic upper bound."""
    rows = {}
    for read_impl, update_impl in (("python", "python"), ("llm", "python"),
                                   ("python", "llm"), ("llm", "llm")):
        r = run_cell("gpt-4-turbo", "cot", True, use_cache=True, n=60,
                     read_impl=read_impl, update_impl=update_impl)
        rows[(read_impl, update_impl)] = r
    base = rows[("python", "python")]
    for key, r in rows.items():
        assert abs(r.avg_time_s - base.avg_time_s) / base.avg_time_s < 0.06, \
            (key, r.avg_time_s, base.avg_time_s)
        if key != ("python", "python"):
            assert r.gpt_hit_rate > 0.93        # paper: ~96-98%
